"""Repo tooling: CI checkers that run before (and without) the dependency
install — everything in here is stdlib-only."""
