"""Markdown link checker (stdlib only — runs in CI before any pip install).

Scans the given markdown files/directories for inline links and images
(``[text](target)``), and fails when a *relative* target does not exist on
disk or names a missing ``#anchor`` in a markdown file. External links
(http/https/mailto) are not fetched — CI must not depend on the network.

Python sources are checked too: directories are also scanned for ``*.py``,
where only link targets ending in ``.md`` (before any ``#anchor``) are
validated — docstrings routinely contain ``foo[0](arg)``-shaped text that
the markdown link regex would otherwise flag.

Usage:
    python tools/check_links.py README.md docs src/repro/kernels
"""
from __future__ import annotations

import re
import sys
import unicodedata
from pathlib import Path

# target = first non-space run after "("; an optional "title" part follows
LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+[^)]*)?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (lowercase, spaces->dashes,
    punctuation dropped)."""
    text = re.sub(r"[*_`\[\]()]", "", heading.strip())
    text = unicodedata.normalize("NFKD", text)
    out = []
    for ch in text.lower():
        if ch.isalnum():
            out.append(ch)
        elif ch in (" ", "-"):
            out.append("-")
    return "".join(out)


def anchors_of(md_path: Path) -> set[str]:
    """All heading anchors a markdown file exposes."""
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    return {github_anchor(h) for h in HEADING_RE.findall(text)}


def check_file(md_path: Path) -> list[str]:
    """Return human-readable problems for one markdown or python file."""
    problems = []
    md_only = md_path.suffix == ".py"
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        if md_only and not path_part.endswith(".md"):
            continue  # a [x](y) in code is usually not a link at all
        dest = (md_path.parent / path_part).resolve() if path_part \
            else md_path.resolve()
        if not dest.exists():
            problems.append(f"{md_path}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in anchors_of(dest):
                problems.append(f"{md_path}: missing anchor -> {target}")
    return problems


def main(argv: list[str]) -> int:
    """Check every .md and .py under the given files/directories; 1 if broken."""
    files: list[Path] = []
    for arg in argv or ["README.md", "docs"]:
        p = Path(arg)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
            files.extend(sorted(p.rglob("*.py")))
        elif p.exists():
            files.append(p)
        else:
            print(f"check_links: no such path {arg}", file=sys.stderr)
            return 2
    problems = [msg for f in files for msg in check_file(f)]
    for msg in problems:
        print(msg, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(problems)} problems")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
