"""Shared AST helpers for the pgcheck passes (stdlib ``ast`` only)."""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple


def attr_chain(node: ast.AST) -> Optional[str]:
    """Dotted-name text of a Name/Attribute chain (``"self.dyn.traffic"``),
    or None when the chain roots in something else (a call, a subscript)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def self_attr(node: ast.AST) -> Optional[str]:
    """``field`` when ``node`` is exactly ``self.field``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Dotted text of a call's function (``"np.zeros"``, ``"len"``)."""
    return attr_chain(node.func)


def last_part(dotted: Optional[str]) -> Optional[str]:
    """Final component of a dotted name (``"np.zeros"`` -> ``"zeros"``)."""
    return dotted.rsplit(".", 1)[-1] if dotted else None


def const_str(node: ast.AST) -> Optional[str]:
    """The string value of a constant-string node, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def iter_class_defs(tree: ast.AST) -> Iterator[ast.ClassDef]:
    """Every class definition in the module (nested ones included)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            yield node


def class_methods(cls: ast.ClassDef) -> List[ast.FunctionDef]:
    """The class's directly defined (sync and async) methods."""
    return [stmt for stmt in cls.body
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))]


def class_attr_assign(cls: ast.ClassDef, name: str) -> Optional[ast.AST]:
    """The value node of a class-level ``name = ...`` assignment, if any."""
    for stmt in cls.body:
        if isinstance(stmt, ast.Assign):
            for target in stmt.targets:
                if isinstance(target, ast.Name) and target.id == name:
                    return stmt.value
        elif isinstance(stmt, ast.AnnAssign):
            if (isinstance(stmt.target, ast.Name) and stmt.target.id == name
                    and stmt.value is not None):
                return stmt.value
    return None


def literal_str_dict(node: ast.AST) -> Optional[Dict[str, str]]:
    """Parse an ``ast.Dict`` of string-constant keys/values, else None."""
    if not isinstance(node, ast.Dict):
        return None
    out: Dict[str, str] = {}
    for key, value in zip(node.keys, node.values):
        ks, vs = const_str(key), const_str(value)
        if ks is None or vs is None:
            return None
        out[ks] = vs
    return out


def scope_map(tree: ast.AST) -> Dict[int, str]:
    """Map ``id(node) -> "Class.method"``-style enclosing scope name.

    Module-level nodes map to ``"<module>"``; nested defs join with dots.
    Passes use this to stamp findings with a line-drift-stable scope.
    """
    out: Dict[int, str] = {}

    def visit(node: ast.AST, scope: str) -> None:
        """Record ``scope`` for every child, descending into defs."""
        for child in ast.iter_child_nodes(node):
            child_scope = scope
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                child_scope = (f"{scope}.{child.name}"
                               if scope != "<module>" else child.name)
            out[id(child)] = child_scope
            visit(child, child_scope)

    out[id(tree)] = "<module>"
    visit(tree, "<module>")
    return out


def with_self_locks(stmt: ast.With, lock_names: Set[str]) -> Set[str]:
    """Lock attribute names among a ``with`` statement's ``self.X`` items."""
    held: Set[str] = set()
    for item in stmt.items:
        name = self_attr(item.context_expr)
        if name is not None and name in lock_names:
            held.add(name)
    return held


#: method names whose receiver is mutated in place — used to classify
#: ``self.field.append(...)``-style writes for write-guarded fields
MUTATOR_METHODS = {
    "append", "appendleft", "extend", "insert", "pop", "popitem", "popleft",
    "remove", "clear", "update", "setdefault", "add", "discard", "sort",
    "reverse", "fill",
}


def written_attr_ids(fn: ast.AST) -> Set[int]:
    """``id()`` of every Attribute node that is written (not merely read).

    Covers rebinding (``self.x = ...``), deletion, subscript/augmented
    assignment through the attribute (``self.x[k] += v`` — the Attribute
    itself carries Load ctx there), loop targets, ``with ... as self.x``,
    and in-place mutator calls (``self.x.append(v)``).
    """
    written: Set[int] = set()

    def attr_roots(target: ast.AST) -> Iterator[ast.Attribute]:
        """Descend through subscripts/starred/tuples to attribute bases."""
        if isinstance(target, ast.Attribute):
            yield target
        elif isinstance(target, (ast.Subscript, ast.Starred)):
            yield from attr_roots(target.value)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                yield from attr_roots(elt)

    for node in ast.walk(fn):
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = list(node.targets)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            targets = [node.target]
        elif isinstance(node, (ast.With, ast.AsyncWith)):
            targets = [item.optional_vars for item in node.items
                       if item.optional_vars is not None]
        elif isinstance(node, ast.Call):
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in MUTATOR_METHODS):
                targets = [node.func.value]
        for target in targets:
            for attr in attr_roots(target):
                written.add(id(attr))
        # explicit Store/Del ctx attributes are writes wherever they appear
        if isinstance(node, ast.Attribute) and not isinstance(node.ctx,
                                                              ast.Load):
            written.add(id(node))
    return written


def module_jitted_names(tree: ast.AST) -> Set[str]:
    """Names bound to jitted callables anywhere in the module.

    Recognizes ``f = jax.jit(g)`` / ``f = jit(g)`` assignments and
    ``@jax.jit`` / ``@jit`` / ``@partial(jax.jit, ...)`` decorations.
    """
    jitted: Set[str] = set()

    def is_jit_call(node: ast.AST) -> bool:
        """True for ``jax.jit(...)`` / ``partial(jax.jit, ...)`` calls."""
        if not isinstance(node, ast.Call):
            return False
        name = call_name(node)
        if name in ("jax.jit", "jit"):
            return True
        # functools.partial(jax.jit, ...) decorator form
        if last_part(name) == "partial" and node.args:
            return call_name(node.args[0]) in ("jax.jit", "jit") \
                if isinstance(node.args[0], ast.Call) \
                else attr_chain(node.args[0]) in ("jax.jit", "jit")
        return False

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and is_jit_call(node.value):
            for target in node.targets:
                if isinstance(target, ast.Name):
                    jitted.add(target.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for deco in node.decorator_list:
                if (attr_chain(deco) in ("jax.jit", "jit")
                        or is_jit_call(deco)):
                    jitted.add(node.name)
    return jitted


def jitted_function_defs(tree: ast.AST) -> List[ast.FunctionDef]:
    """Function definitions decorated with ``jax.jit`` (or partial forms)."""
    out: List[ast.FunctionDef] = []
    jitted = module_jitted_names(tree)
    for node in ast.walk(tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name in jitted):
            out.append(node)
    return out


def expr_text(node: ast.AST) -> str:
    """Source-ish text of an expression (``ast.unparse`` convenience)."""
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - malformed synthetic nodes
        return "<expr>"
