"""PG004 — host synchronization points inside spans and jitted code.

``.item()``, ``float(x)`` and ``np.asarray(x)`` on a device value block the
host on the device stream. Inside a ``with trace.span(...)`` body that is a
*silent* serialization point: the span charges the wait to whatever happens
to synchronize first, and the fix — ``sp.fence(value)``, which blocks at
span exit *before* the clock read — exists precisely so device work is
attributed to the span that launched it. Inside a jitted function the same
calls are simply bugs (a tracer cannot be materialized).

Flagged, lexically inside a ``with trace.span(…)``/``with span(…)`` block:

* any ``….item()`` call;
* ``np.asarray(x)`` / ``np.array(x)`` / ``jax.device_get(x)`` where ``x``
  is a name or attribute that was **not** fenced (passed to ``….fence(…)``,
  possibly inside a tuple) earlier in the same function;
* ``float(x)`` / ``int(x)`` where ``x`` is a local name assigned from a
  ``jnp.*`` call (device-valued by construction).

Inside a ``jax.jit``-decorated function, ``.item()``/``np.asarray``/
``np.array`` are flagged unconditionally.

The check is per-function and lexical: a sync in a helper called from a
span body is not seen (the helper should carry its own span), and fencing
is matched by expression text (``sp.fence(cards)`` allows
``np.asarray(cards)``).
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..astutil import call_name, expr_text, jitted_function_defs, last_part
from ..model import Finding

PASS_ID = "PG004"
TITLE = "host sync inside trace.span / jitted code"

#: call names that copy a device value to host
HOST_COPY_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                   "jax.device_get"}


def _is_span_call(node: ast.AST) -> bool:
    """Is this expression a ``trace.span(…)`` / ``span(…)`` call?"""
    if not isinstance(node, ast.Call):
        return False
    return last_part(call_name(node)) == "span"


def _fenced_exprs(fn: ast.AST) -> Set[str]:
    """Expression texts passed to any ``….fence(…)`` call in the function
    (tuples unpacked: ``sp.fence((a, b))`` fences ``a`` and ``b``)."""
    fenced: Set[str] = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "fence"):
            for arg in node.args:
                elts = arg.elts if isinstance(arg, (ast.Tuple, ast.List)) \
                    else [arg]
                for elt in elts:
                    fenced.add(expr_text(elt))
    return fenced


def _device_names(fn: ast.AST) -> Set[str]:
    """Local names assigned from ``jnp.*`` calls — device-valued."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            name = call_name(node.value) or ""
            if name.split(".", 1)[0] in ("jnp", "jax"):
                names.add(node.targets[0].id)
    return names


def _check_sync_calls(body, fenced, device_names, in_span, ctx, findings,
                      jitted: bool) -> None:
    """Flag sync calls in ``body``; recurse, tracking span nesting."""
    for stmt in body:
        _scan(stmt, fenced, device_names, in_span, ctx, findings, jitted)


def _scan(node, fenced, device_names, in_span, ctx, findings,
          jitted: bool) -> None:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        return                     # nested defs are their own scan units
    if isinstance(node, (ast.With, ast.AsyncWith)):
        entered = in_span or any(_is_span_call(item.context_expr)
                                 for item in node.items)
        _check_sync_calls(node.body, fenced, device_names, entered, ctx,
                          findings, jitted)
        return
    if isinstance(node, ast.Call) and (in_span or jitted):
        where = ("a jitted function" if jitted
                 else "a trace.span body")
        name = call_name(node)
        if (isinstance(node.func, ast.Attribute)
                and node.func.attr == "item"):
            findings.append(ctx.finding(
                PASS_ID, node,
                f".item() inside {where} — a silent host-device "
                f"serialization point",
                hint="fence the device value on the span "
                     "(sp.fence(value)) and read it after the span, or "
                     "keep the reduction on device"))
        elif name in HOST_COPY_CALLS and node.args:
            arg = node.args[0]
            if isinstance(arg, (ast.Name, ast.Attribute)) \
                    and expr_text(arg) not in fenced:
                findings.append(ctx.finding(
                    PASS_ID, node,
                    f"{name}({expr_text(arg)}) inside {where} without a "
                    f"fence — the host blocks on the device stream and "
                    f"the wait is charged to whichever span syncs first",
                    hint="sp.fence(value) before the copy (span exit then "
                         "blocks before the clock read), or move the copy "
                         "out of the span"))
        elif (not jitted and isinstance(node.func, ast.Name)
              and node.func.id in ("float", "int") and node.args
              and isinstance(node.args[0], ast.Name)
              and node.args[0].id in device_names
              and expr_text(node.args[0]) not in fenced):
            findings.append(ctx.finding(
                PASS_ID, node,
                f"{node.func.id}({node.args[0].id}) inside {where} on a "
                f"jnp-computed value — a silent host-device "
                f"serialization point",
                hint="fence the value on the span or convert after the "
                     "span exits"))
    for child in ast.iter_child_nodes(node):
        _scan(child, fenced, device_names, in_span, ctx, findings, jitted)


def check(tree: ast.Module, ctx) -> List[Finding]:
    """Run PG004 over one parsed file."""
    findings: List[Finding] = []
    jitted_defs = {id(fn) for fn in jitted_function_defs(tree)}
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        fenced = _fenced_exprs(fn)
        device_names = _device_names(fn)
        _check_sync_calls(fn.body, fenced, device_names, False, ctx,
                          findings, jitted=id(fn) in jitted_defs)
    return findings
