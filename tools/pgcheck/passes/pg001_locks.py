"""PG001 — lock discipline for ``_GUARDED_BY``-annotated fields.

A class opts in by declaring a class-level map from field name to lock
spec::

    class Server:
        _GUARDED_BY = {
            "_queue": "_lock|_cond",     # either lock is acceptable
            "_serving": "write:_mutate_lock",  # writes only; reads are free
        }

Spec grammar: ``[write:]lock[|lock...]``. A guarded access is legal when it
is lexically inside a ``with self.<lock>:`` block for any lock in the spec,
or inside a method whose name ends in ``_locked`` (callers own the lock), or
inside ``__init__``/``__del__`` (the object is not shared yet / anymore).
``write:`` restricts checking to mutations — rebinding, subscript/augmented
assignment through the field, deletion, and in-place mutator calls
(``.append``/``.update``/…) — for fields whose unlocked *reads* are part of
the design (atomic published-reference reads, monotonic counters).

The analysis is lexical and conservative: code inside a nested ``def`` or
``lambda`` is treated as running without the enclosing ``with`` locks (a
closure can escape and run later, unlocked).
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from ..astutil import (class_attr_assign, class_methods, iter_class_defs,
                       literal_str_dict, self_attr, with_self_locks,
                       written_attr_ids)
from ..model import Finding

PASS_ID = "PG001"
TITLE = "lock discipline (_GUARDED_BY)"

#: methods exempt from checking: construction/destruction are single-owner
EXEMPT_METHODS = {"__init__", "__del__", "__post_init__"}


def _parse_spec(spec: str) -> Tuple[bool, Set[str]]:
    """``"write:_a|_b"`` -> ``(write_only, {"_a", "_b"})``."""
    write_only = spec.startswith("write:")
    if write_only:
        spec = spec[len("write:"):]
    locks = {part.strip() for part in spec.split("|") if part.strip()}
    return write_only, locks


def check(tree: ast.Module, ctx) -> List[Finding]:
    """Run PG001 over one parsed file."""
    findings: List[Finding] = []
    for cls in iter_class_defs(tree):
        guard_node = class_attr_assign(cls, "_GUARDED_BY")
        if guard_node is None:
            continue
        guards_raw = literal_str_dict(guard_node)
        if guards_raw is None:
            findings.append(ctx.finding(
                PASS_ID, guard_node,
                f"{cls.name}._GUARDED_BY must be a literal "
                "{'field': 'lockspec'} dict of string constants",
                hint="use e.g. {'_queue': '_lock'} or "
                     "{'_serving': 'write:_mutate_lock'}"))
            continue
        guards = {field: _parse_spec(spec)
                  for field, spec in guards_raw.items()}
        all_locks: Set[str] = set()
        for _, locks in guards.values():
            all_locks |= locks
        for method in class_methods(cls):
            if (method.name in EXEMPT_METHODS
                    or method.name.endswith("_locked")):
                continue
            written = written_attr_ids(method)
            _scan(method.body, frozenset(), guards, all_locks, written,
                  cls.name, method.name, ctx, findings)
    return findings


def _scan(stmts, held, guards, all_locks, written, cls_name, method_name,
          ctx, findings) -> None:
    """Walk statements tracking which ``self.*`` locks are held."""
    for stmt in stmts:
        _scan_node(stmt, held, guards, all_locks, written, cls_name,
                   method_name, ctx, findings)


def _scan_node(node, held, guards, all_locks, written, cls_name,
               method_name, ctx, findings) -> None:
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
        # a nested def/lambda may escape the with block: conservatively
        # re-scan its body with no locks held
        body = node.body if isinstance(node.body, list) else [node.body]
        _scan(body, frozenset(), guards, all_locks, written, cls_name,
              method_name, ctx, findings)
        return
    if isinstance(node, (ast.With, ast.AsyncWith)):
        newly = with_self_locks(node, all_locks)
        for item in node.items:       # the lock expressions themselves
            _scan_node(item.context_expr, held, guards, all_locks, written,
                       cls_name, method_name, ctx, findings)
        _scan(node.body, held | newly, guards, all_locks, written, cls_name,
              method_name, ctx, findings)
        return
    attr = self_attr(node)
    if attr is not None and attr in guards:
        write_only, locks = guards[attr]
        is_write = id(node) in written or not isinstance(node.ctx, ast.Load)
        if (is_write or not write_only) and not (held & locks):
            verb = "written" if is_write else "read"
            lock_list = " or ".join(f"`with self.{lk}:`"
                                    for lk in sorted(locks))
            findings.append(ctx.finding(
                PASS_ID, node,
                f"self.{attr} {verb} outside {lock_list} "
                f"(_GUARDED_BY in {cls_name})",
                hint=f"hold the lock around the access, or move it into a "
                     f"*_locked method whose callers own self."
                     f"{sorted(locks)[0]}"))
    for child in ast.iter_child_nodes(node):
        _scan_node(child, held, guards, all_locks, written, cls_name,
                   method_name, ctx, findings)
