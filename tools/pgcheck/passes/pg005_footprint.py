"""PG005 — footprint coverage for every server query kind.

ARCHITECTURE invariant 7: every cached answer's ``Footprint`` must cover
every vertex it read; a query kind served without one silently poisons the
result cache (its entries survive deltas that changed their inputs). The
enforced discipline: a serving class (any class with ``submit_*`` methods
that call ``self._submit("<kind>", …)``) must declare a class-level map

::

    _KIND_FOOTPRINTS = {
        "similarity": "exact",     # flush constructs Footprint.of(...)
        "tc": "whole_graph",       # flush marks Footprint.whole_graph()
    }

and the flush code must back the declaration:

* every kind submitted anywhere in the class must be a key of the map
  (**the ratchet**: adding ``submit_newthing`` without deciding its
  footprint is a finding, not a latent cache-poisoning bug);
* every declared kind must be submitted by some ``submit_*`` method (stale
  declarations rot);
* a ``"whole_graph"`` kind needs a ``Footprint.whole_graph()`` call inside
  an ``if``/``elif`` branch testing that kind's literal;
* an ``"exact"`` kind needs its literal to appear in some method that also
  constructs ``Footprint.of(...)`` (branch-level matching is not attempted
  for grouped batch paths — the declaration plus method-level
  co-occurrence is the enforced contract).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from ..astutil import (call_name, class_attr_assign, class_methods,
                       const_str, iter_class_defs, literal_str_dict)
from ..model import Finding

PASS_ID = "PG005"
TITLE = "footprint coverage (_KIND_FOOTPRINTS)"

VALID_DISCIPLINES = {"exact", "whole_graph"}


def _submitted_kinds(cls: ast.ClassDef) -> Dict[str, ast.AST]:
    """``kind -> submit-call node`` for every ``self._submit("kind", …)``."""
    kinds: Dict[str, ast.AST] = {}
    for method in class_methods(cls):
        if not method.name.startswith("submit_"):
            continue
        for node in ast.walk(method):
            if (isinstance(node, ast.Call)
                    and call_name(node) == "self._submit" and node.args):
                kind = const_str(node.args[0])
                if kind is not None:
                    kinds.setdefault(kind, node)
    return kinds


def _literals_in(node: ast.AST) -> Set[str]:
    """Every string constant in a subtree."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        value = const_str(sub)
        if value is not None:
            out.add(value)
    return out


def _footprint_calls(node: ast.AST) -> Set[str]:
    """``{"of", "whole_graph"}`` members called on ``Footprint`` within."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub) or ""
            if name.endswith("Footprint.of") or name == "Footprint.of":
                out.add("of")
            elif name.endswith("Footprint.whole_graph"):
                out.add("whole_graph")
    return out


def _kind_branch_has(cls: ast.ClassDef, kind: str, member: str) -> bool:
    """Is there an if/elif testing ``kind``'s literal whose body constructs
    ``Footprint.<member>``?"""
    for node in ast.walk(cls):
        if not isinstance(node, ast.If):
            continue
        if kind not in _literals_in(node.test):
            continue
        body = ast.Module(body=node.body, type_ignores=[])
        if member in _footprint_calls(body):
            return True
    return False


def _method_cooccurrence(cls: ast.ClassDef, kind: str, member: str) -> bool:
    """Does some method mention the kind literal and call
    ``Footprint.<member>``?"""
    for method in class_methods(cls):
        if kind in _literals_in(method) \
                and member in _footprint_calls(method):
            return True
    return False


def check(tree: ast.Module, ctx) -> List[Finding]:
    """Run PG005 over one parsed file."""
    findings: List[Finding] = []
    for cls in iter_class_defs(tree):
        submitted = _submitted_kinds(cls)
        if not submitted:
            continue
        map_node = class_attr_assign(cls, "_KIND_FOOTPRINTS")
        if map_node is None:
            findings.append(ctx.finding(
                PASS_ID, cls,
                f"{cls.name} submits query kinds "
                f"({', '.join(sorted(submitted))}) but declares no "
                f"_KIND_FOOTPRINTS map",
                hint="declare _KIND_FOOTPRINTS = {'<kind>': 'exact' | "
                     "'whole_graph', ...} — every query kind needs a "
                     "footprint or a whole-graph marker (invariant 7)"))
            continue
        declared = literal_str_dict(map_node)
        if declared is None:
            findings.append(ctx.finding(
                PASS_ID, map_node,
                f"{cls.name}._KIND_FOOTPRINTS must be a literal dict of "
                f"string constants",
                hint="use {'similarity': 'exact', 'tc': 'whole_graph', ...}"))
            continue
        for kind, discipline in declared.items():
            if discipline not in VALID_DISCIPLINES:
                findings.append(ctx.finding(
                    PASS_ID, map_node,
                    f"kind {kind!r} declares unknown footprint discipline "
                    f"{discipline!r}",
                    hint="valid disciplines: 'exact', 'whole_graph'"))
        for kind, node in sorted(submitted.items()):
            if kind not in declared:
                findings.append(ctx.finding(
                    PASS_ID, node,
                    f"query kind {kind!r} is submitted but missing from "
                    f"{cls.name}._KIND_FOOTPRINTS — its answers would "
                    f"enter the cache without a footprint contract",
                    hint="add it to _KIND_FOOTPRINTS and construct its "
                         "Footprint (or whole-graph marker) in the flush "
                         "path"))
                continue
            discipline = declared[kind]
            if discipline == "whole_graph":
                if not _kind_branch_has(cls, kind, "whole_graph"):
                    findings.append(ctx.finding(
                        PASS_ID, node,
                        f"kind {kind!r} is declared whole_graph but no "
                        f"flush branch testing it calls "
                        f"Footprint.whole_graph()",
                        hint="mark the answer in its kind branch: "
                             "fp = Footprint.whole_graph()"))
            elif discipline == "exact":
                if not _method_cooccurrence(cls, kind, "of"):
                    findings.append(ctx.finding(
                        PASS_ID, node,
                        f"kind {kind!r} is declared exact but no method "
                        f"mentioning it constructs Footprint.of(...)",
                        hint="build the answer's footprint where the kind "
                             "is served: fp = Footprint.of(<vertex sets>)"))
        stale = sorted(set(declared) - set(submitted))
        for kind in stale:
            findings.append(ctx.finding(
                PASS_ID, map_node,
                f"_KIND_FOOTPRINTS declares kind {kind!r} that no "
                f"submit_* method submits",
                hint="drop the stale declaration or add the submit path"))
    return findings
