"""PG003 — recompile guard: raw sizes must be pow2-bucketed at jit edges.

ARCHITECTURE invariant 5: any varying-size device work pads to
``engine.plan.pow2_bucket`` shapes, so XLA compiles a bounded program set
under arbitrary traffic. The bug class this catches (shipped twice, fixed in
PR 5 and PR 9): a buffer sized directly by ``len(requests)`` / ``arr.shape[0]``
is handed to a jitted entry point, and every distinct traffic size compiles
a fresh program.

Per-function (intraprocedural, two-pass taint over local assignments):

1. a name is *size-tainted* when assigned from an expression containing
   ``len(…)``, ``….shape[…]``/``….shape``, ``….size`` or another tainted
   name — unless the value passes through a recognized bucket helper
   (``pow2_bucket``, ``frontier_cap_for``), which cleanses the subtree;
2. a name is a *raw-sized buffer* when assigned from an array constructor
   (``np/jnp`` ``zeros``/``full``/``empty``/``ones``/``arange``) whose size
   argument is tainted;
3. a finding fires when a raw-sized buffer (or a tainted-size constructor
   expression directly) is passed to a **device boundary**: ``jnp.asarray``,
   a ``…traffic.put``/``…meter.put`` upload, a name bound via ``jax.jit``,
   or one of the engine's batch entry methods (``map_edges``/``fold_edges``/
   ``local_cluster``/``membership``/``similarity``).

Honest limits: flows through helper functions, containers, or attributes are
not tracked — the pass enforces the *local* discipline "bucket at the point
you build the padded buffer", which is how every compliant call site in the
repo is written.
"""
from __future__ import annotations

import ast
from typing import List, Set

from ..astutil import call_name, last_part, module_jitted_names
from ..model import Finding

PASS_ID = "PG003"
TITLE = "recompile guard (pow2 bucketing at jit edges)"

#: calls that cleanse a size expression (its subtree is bucket-disciplined)
BUCKET_HELPERS = {"pow2_bucket", "frontier_cap_for"}

#: array constructors whose first argument is a shape/size
ARRAY_CTORS = {"zeros", "full", "empty", "ones", "arange"}
ARRAY_CTOR_ROOTS = {"np", "numpy", "jnp"}

#: engine batch entry methods — their array args feed jitted programs
ENGINE_ENTRY_METHODS = {"map_edges", "fold_edges", "local_cluster",
                        "membership", "similarity"}


def _is_raw_size(node: ast.AST, tainted: Set[str]) -> bool:
    """Does the expression carry a raw (unbucketed) size?"""
    if isinstance(node, ast.Call):
        name = call_name(node)
        if last_part(name) in BUCKET_HELPERS:
            return False            # cleansed subtree: do not descend
        if name == "len":
            return True
        return any(_is_raw_size(arg, tainted) for arg in node.args)
    if isinstance(node, ast.Attribute):
        if node.attr in ("shape", "size"):
            return True
        return _is_raw_size(node.value, tainted)
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Subscript):
        return (_is_raw_size(node.value, tainted)
                or _is_raw_size(node.slice, tainted))
    if isinstance(node, ast.BinOp):
        return (_is_raw_size(node.left, tainted)
                or _is_raw_size(node.right, tainted))
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_is_raw_size(elt, tainted) for elt in node.elts)
    if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
        return _is_raw_size(node.elt, tainted)
    if isinstance(node, ast.IfExp):
        return (_is_raw_size(node.body, tainted)
                or _is_raw_size(node.orelse, tainted))
    return False


def _is_raw_sized_ctor(node: ast.AST, tainted: Set[str]) -> bool:
    """Is this an array-constructor call with a tainted size argument?"""
    if not isinstance(node, ast.Call):
        return False
    name = call_name(node)
    if last_part(name) not in ARRAY_CTORS:
        return False
    root = (name or "").split(".", 1)[0]
    if root not in ARRAY_CTOR_ROOTS:
        return False
    return bool(node.args) and _is_raw_size(node.args[0], tainted)


def _boundary_kind(node: ast.Call, jitted: Set[str]) -> str:
    """Non-empty description when the call crosses into device/jit land."""
    name = call_name(node)
    if name in ("jnp.asarray", "jax.numpy.asarray"):
        return name
    tail = last_part(name)
    if tail == "put" and name and any(
            part in ("traffic", "meter") for part in name.split(".")):
        return name
    if tail in ENGINE_ENTRY_METHODS and name and "." in name:
        return name
    if isinstance(node.func, ast.Name) and node.func.id in jitted:
        return f"{node.func.id} (jax.jit)"
    return ""


def check(tree: ast.Module, ctx) -> List[Finding]:
    """Run PG003 over one parsed file."""
    findings: List[Finding] = []
    jitted = module_jitted_names(tree)
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        tainted: Set[str] = set()
        raw_buffers: Set[str] = set()
        for _ in range(2):        # two passes: forward refs in loops settle
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    continue
                target = node.targets[0].id
                if _is_raw_sized_ctor(node.value, tainted):
                    raw_buffers.add(target)
                elif _is_raw_size(node.value, tainted):
                    tainted.add(target)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            boundary = _boundary_kind(node, jitted)
            if not boundary:
                continue
            for arg in node.args:
                bad = ((isinstance(arg, ast.Name) and arg.id in raw_buffers)
                       or _is_raw_sized_ctor(arg, tainted))
                if bad:
                    what = (arg.id if isinstance(arg, ast.Name)
                            else "a buffer")
                    findings.append(ctx.finding(
                        PASS_ID, arg,
                        f"{what} is sized by a raw len()/.shape/.size value "
                        f"and flows into {boundary} — every distinct "
                        f"traffic size compiles a fresh XLA program",
                        hint="pad the size through engine.plan.pow2_bucket "
                             "(or frontier_cap_for) before building the "
                             "device buffer"))
    return findings
