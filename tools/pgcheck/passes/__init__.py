"""Pass registry: every pgcheck pass module, in id order.

A pass module exposes ``PASS_ID``, ``TITLE`` and
``check(tree, ctx) -> list[Finding]`` where ``ctx`` is the driver's
:class:`~tools.pgcheck.driver.FileContext`. Adding a pass = adding a module
here and a fixture pair under ``tests/lint_fixtures/`` (see
``docs/STATIC_ANALYSIS.md``).
"""
from . import (pg001_locks, pg002_publish, pg003_recompile, pg004_hostsync,
               pg005_footprint)

#: in-order pass pipeline the driver runs over every file
ALL_PASSES = (pg001_locks, pg002_publish, pg003_recompile, pg004_hostsync,
              pg005_footprint)

__all__ = ["ALL_PASSES"]
