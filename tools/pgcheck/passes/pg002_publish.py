"""PG002 — publish-after-invalidate ordering in serving-view mutators.

ARCHITECTURE invariant 9: mutations follow fork–invalidate–publish. The
invalidation feed (``self._publish_invalid*(…)``) must fire *before* the
single serving-view publication (a call to ``self._publish_view*()`` or a
direct store to ``self._serving``), and a mutator may publish at most once —
a second publication store means some readers can capture a half-mutated
generation between the two swaps.

Detection is convention-driven, so it applies to any class using the
repo's naming scheme (``_publish_invalid…`` / ``_publish_view…`` /
``_serving``), fixtures included:

* **PG002a** more than one publication in one method;
* **PG002b** a publication at or before the first invalidation call in a
  method that performs both.

Methods with a publication but *no* invalidation call are legal — e.g.
``restore()`` re-publishing a checkpoint into a listener-free session, or a
``_publish_view`` helper owning the single ``self._serving`` store. The
check is line-ordered, not path-sensitive: a conditional invalidation
followed by an unconditional publication (the no-op-delta shape) passes,
which matches the invariant — a no-op publication has nothing to
invalidate.
"""
from __future__ import annotations

import ast
from typing import List, Tuple

from ..astutil import call_name, class_methods, iter_class_defs, self_attr
from ..model import Finding

PASS_ID = "PG002"
TITLE = "publish-after-invalidate (serving-view mutators)"

#: naming conventions that mark the three primitives
INVALIDATE_PREFIX = "self._publish_invalid"
PUBLISH_PREFIX = "self._publish_view"
SERVING_ATTR = "_serving"


def _collect(method: ast.AST) -> Tuple[List[ast.AST], List[ast.AST]]:
    """``(publications, invalidations)`` nodes inside one method, in source
    order (nested defs excluded — a closure publishes on its own clock)."""
    pubs: List[ast.AST] = []
    invals: List[ast.AST] = []

    def visit(node: ast.AST) -> None:
        """Collect publications/invalidations, skipping nested defs."""
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(child, ast.Call):
                dotted = call_name(child)
                if dotted:
                    if dotted.startswith(INVALIDATE_PREFIX):
                        invals.append(child)
                    elif dotted.startswith(PUBLISH_PREFIX):
                        pubs.append(child)
            if (isinstance(child, ast.Attribute)
                    and not isinstance(child.ctx, ast.Load)
                    and self_attr(child) == SERVING_ATTR):
                pubs.append(child)
            visit(child)

    visit(method)
    key = lambda n: (n.lineno, n.col_offset)  # noqa: E731 - tiny sort key
    return sorted(pubs, key=key), sorted(invals, key=key)


def check(tree: ast.Module, ctx) -> List[Finding]:
    """Run PG002 over one parsed file."""
    findings: List[Finding] = []
    for cls in iter_class_defs(tree):
        for method in class_methods(cls):
            if method.name in ("__init__", "__post_init__"):
                continue      # construction publishes the first view freely
            pubs, invals = _collect(method)
            if len(pubs) > 1:
                for extra in pubs[1:]:
                    findings.append(ctx.finding(
                        PASS_ID, extra,
                        f"{cls.name}.{method.name} publishes the serving "
                        f"view more than once (invariant 9: one atomic "
                        f"publication per mutation)",
                        hint="fold the mutation into one fork, fire the "
                             "invalidation feed, then publish exactly once"))
            if pubs and invals:
                first_inval = invals[0].lineno
                for pub in pubs:
                    if pub.lineno <= first_inval:
                        findings.append(ctx.finding(
                            PASS_ID, pub,
                            f"{cls.name}.{method.name} publishes the "
                            f"serving view before firing the invalidation "
                            f"feed (line {first_inval})",
                            hint="call self._publish_invalid(...) before "
                                 "the view swap: once a flush can capture "
                                 "the new view, every stale cache entry "
                                 "must already be gone"))
    return findings
