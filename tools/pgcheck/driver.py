"""pgcheck driver: file discovery, the per-file pass pipeline, reporting.

``run_paths`` is the single programmatic entry point (the CLI in
``__main__`` and the tests both call it): discover ``.py`` files, parse each
once, run every selected pass over the shared tree, drop line-suppressed
findings, and return the rest sorted by location. Baseline splitting is the
caller's job (:func:`tools.pgcheck.model.split_findings`) so tests can
assert on raw findings.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Sequence

from .model import Finding, is_suppressed, suppressed_lines
from .passes import ALL_PASSES
from . import astutil


def pass_ids() -> List[str]:
    """The registered pass ids, in pipeline order."""
    return [p.PASS_ID for p in ALL_PASSES]


class FileContext:
    """Per-file state handed to every pass's ``check(tree, ctx)``.

    Owns the parsed tree, the ``id(node) -> scope`` map and the path; passes
    build findings through :meth:`finding` so location/scope stamping lives
    in one place.
    """

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.tree = tree
        self.scopes = astutil.scope_map(tree)
        self.suppressions = suppressed_lines(source)

    def finding(self, pass_id: str, node: ast.AST, message: str,
                hint: str = "") -> Finding:
        """Build a :class:`Finding` anchored at ``node``."""
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        scope = self.scopes.get(id(node), "<module>")
        return Finding(pass_id=pass_id, path=self.path, line=line, col=col,
                       scope=scope, message=message, hint=hint)


def discover_files(paths: Sequence[str]) -> List[Path]:
    """Expand files/directories into a sorted, de-duplicated ``.py`` list."""
    seen = {}
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            candidates: Iterable[Path] = sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            candidates = [p]
        else:
            continue
        for c in candidates:
            if "__pycache__" in c.parts:
                continue
            seen[str(c)] = c
    return [seen[k] for k in sorted(seen)]


def _rel_posix(path: Path, root: Optional[Path]) -> str:
    """Repo-relative posix path when possible (stable baseline keys)."""
    try:
        base = root if root is not None else Path.cwd()
        return path.resolve().relative_to(base.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def check_source(path: str, source: str,
                 select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the (selected) passes over one in-memory source file.

    Returns findings sorted by location with line suppressions applied.
    Syntax errors yield a single ``PG000`` finding rather than a crash —
    pgcheck runs in CI before any other gate, on files ruff may not have
    seen yet.
    """
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(pass_id="PG000", path=path,
                        line=exc.lineno or 1, col=(exc.offset or 1) - 1,
                        scope="<module>",
                        message=f"file does not parse: {exc.msg}")]
    ctx = FileContext(path, source, tree)
    wanted = {p.upper() for p in select} if select else None
    findings: List[Finding] = []
    for pass_mod in ALL_PASSES:
        if wanted is not None and pass_mod.PASS_ID not in wanted:
            continue
        findings.extend(pass_mod.check(tree, ctx))
    findings = [f for f in findings
                if not is_suppressed(f, ctx.suppressions)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.pass_id))


def run_paths(paths: Sequence[str],
              select: Optional[Sequence[str]] = None,
              root: Optional[str] = None) -> List[Finding]:
    """Check every ``.py`` file under ``paths``; return sorted findings.

    ``root`` (default: cwd) anchors the repo-relative paths findings and
    baseline entries are keyed on.
    """
    root_path = Path(root) if root is not None else None
    findings: List[Finding] = []
    for file_path in discover_files(paths):
        source = file_path.read_text(encoding="utf-8")
        rel = _rel_posix(file_path, root_path)
        findings.extend(check_source(rel, source, select=select))
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.pass_id))
