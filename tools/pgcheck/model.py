"""Finding / baseline / suppression model shared by every pgcheck pass.

A :class:`Finding` is one violation: pass id, location, the enclosing scope
(``Class.method`` — what the baseline keys on, so line drift does not churn
it), a message, and a fix hint. Suppression is per line
(``# pgcheck: disable=PG001`` trailing comment); the baseline is a checked-in
JSON file keyed by ``(pass, path, scope)`` that grandfathers pre-existing
findings without letting new ones in.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import List, Optional, Sequence, Set, Tuple

#: trailing-comment suppression: ``# pgcheck: disable=PG001[,PG004]``
_SUPPRESS_RE = re.compile(r"#\s*pgcheck:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One invariant violation at a source location.

    Attributes:
      pass_id: ``"PG001"`` … ``"PG005"`` (or ``"PG000"`` for config errors).
      path:    repo-relative posix path of the offending file.
      line:    1-based source line.
      col:     0-based column.
      scope:   enclosing ``Class.method`` / ``function`` / ``<module>`` —
               the stable baseline key component.
      message: what is wrong, in one sentence.
      hint:    how to fix it (shown indented under the finding).
    """

    pass_id: str
    path: str
    line: int
    col: int
    scope: str
    message: str
    hint: str = ""

    @property
    def baseline_key(self) -> Tuple[str, str, str]:
        """The line-drift-stable identity: ``(pass, path, scope)``."""
        return (self.pass_id, self.path, self.scope)

    def render(self) -> str:
        """``path:line:col: PGnnn message [scope]`` plus an indented hint."""
        out = f"{self.path}:{self.line}:{self.col}: {self.pass_id} " \
              f"{self.message} [{self.scope}]"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out


def suppressed_lines(source: str) -> dict:
    """Map line number -> set of pass ids disabled on that line.

    The marker is a trailing comment: ``# pgcheck: disable=PG001`` (several
    ids comma-separated; ``disable=all`` kills every pass on the line). The
    scan is purely textual — a marker inside a string literal also counts,
    which is harmless (strings do not produce findings on their own line).
    """
    out: dict = {}
    for lineno, text in enumerate(source.splitlines(), start=1):
        match = _SUPPRESS_RE.search(text)
        if match:
            ids = {p.strip().upper() for p in match.group(1).split(",")}
            out[lineno] = ids
    return out


def is_suppressed(finding: Finding, suppressions: dict) -> bool:
    """Does a line-level ``disable=`` marker cover this finding?"""
    ids = suppressions.get(finding.line)
    if not ids:
        return False
    return "ALL" in ids or finding.pass_id.upper() in ids


class Baseline:
    """Checked-in set of grandfathered findings (``pgcheck_baseline.json``).

    Entries are ``{"pass", "path", "scope"}`` dicts; a finding whose
    ``baseline_key`` matches an entry is reported as baselined (not a
    failure). The file is a *ratchet*: the current repo ships it empty —
    ``src/repro/stream`` + ``src/repro/engine`` must stay clean — and any
    future entry needs review to land.
    """

    def __init__(self, entries: Optional[Sequence[dict]] = None):
        self._keys: Set[Tuple[str, str, str]] = {
            (e["pass"], e["path"], e["scope"]) for e in (entries or [])}

    @classmethod
    def load(cls, path: str) -> "Baseline":
        """Read a baseline JSON file (``{"version": 1, "entries": [...]}``)."""
        doc = json.loads(Path(path).read_text(encoding="utf-8"))
        if doc.get("version") != 1:
            raise ValueError(f"{path}: unsupported baseline version "
                             f"{doc.get('version')!r}")
        return cls(doc.get("entries", []))

    @classmethod
    def write(cls, path: str, findings: Sequence[Finding]) -> None:
        """Emit the current findings as a fresh baseline file."""
        entries = sorted({f.baseline_key for f in findings})
        doc = {"version": 1, "entries": [
            {"pass": p, "path": fp, "scope": s} for (p, fp, s) in entries]}
        Path(path).write_text(json.dumps(doc, indent=2) + "\n",
                              encoding="utf-8")

    def covers(self, finding: Finding) -> bool:
        """Is this finding grandfathered?"""
        return finding.baseline_key in self._keys

    def __len__(self) -> int:
        """Number of grandfathered ``(pass, path, scope)`` keys."""
        return len(self._keys)


def split_findings(findings: Sequence[Finding], baseline: Baseline
                   ) -> Tuple[List[Finding], List[Finding]]:
    """Partition findings into ``(new, baselined)`` against a baseline."""
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        (old if baseline.covers(f) else new).append(f)
    return new, old
