"""CLI for pgcheck: ``python -m tools.pgcheck [paths...] [--baseline F]``.

Exit status is 0 when every finding is grandfathered (or none exist) and 1
when any *new* finding is reported — which is what the CI lint job keys on.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .driver import pass_ids, run_paths
from .model import Baseline, split_findings
from .passes import ALL_PASSES

DEFAULT_PATHS = ["src/repro", "tools"]


def _build_parser() -> argparse.ArgumentParser:
    """The argparse CLI surface."""
    parser = argparse.ArgumentParser(
        prog="python -m tools.pgcheck",
        description="AST-based invariant checker for this repo's "
                    "concurrency, recompile, and footprint disciplines "
                    "(see docs/STATIC_ANALYSIS.md).")
    parser.add_argument(
        "paths", nargs="*", default=None,
        help=f"files or directories to check (default: {DEFAULT_PATHS})")
    parser.add_argument(
        "--baseline", metavar="FILE", default=None,
        help="baseline JSON; findings whose (pass, path, scope) key is "
             "listed are reported but do not fail the run")
    parser.add_argument(
        "--write-baseline", metavar="FILE", default=None,
        help="write the current findings to FILE as a fresh baseline and "
             "exit 0 (use sparingly: the baseline is a ratchet)")
    parser.add_argument(
        "--select", metavar="IDS", default=None,
        help="comma-separated pass ids to run (e.g. PG001,PG004)")
    parser.add_argument(
        "--list-passes", action="store_true",
        help="print the pass catalog and exit")
    parser.add_argument(
        "-q", "--quiet", action="store_true",
        help="suppress the summary line (findings still print)")
    return parser


def main(argv: Optional[List[str]] = None) -> int:
    """Entry point; returns the process exit status."""
    args = _build_parser().parse_args(argv)

    if args.list_passes:
        for mod in ALL_PASSES:
            print(f"{mod.PASS_ID}  {mod.TITLE}")
        return 0

    paths = args.paths or DEFAULT_PATHS
    select = ([p.strip() for p in args.select.split(",") if p.strip()]
              if args.select else None)
    if select:
        unknown = sorted(set(p.upper() for p in select) - set(pass_ids()))
        if unknown:
            print(f"pgcheck: unknown pass id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2

    findings = run_paths(paths, select=select)

    if args.write_baseline:
        Baseline.write(args.write_baseline, findings)
        print(f"pgcheck: wrote {len(findings)} finding(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = Baseline.load(args.baseline) if args.baseline else Baseline()
    new, grandfathered = split_findings(findings, baseline)

    for f in new:
        print(f.render())
    if not args.quiet:
        extra = (f", {len(grandfathered)} baselined"
                 if grandfathered else "")
        status = "FAIL" if new else "OK"
        print(f"pgcheck: {status} — {len(new)} new finding(s){extra}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
