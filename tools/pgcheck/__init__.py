"""pgcheck: AST-based invariant checker for the repo's load-bearing disciplines.

The serving tier ships aggressive concurrency (snapshot-isolated flushes,
async workers, donation gating) and aggressive compilation hygiene (pow2
bucketing, device-resident deltas) — and `docs/ARCHITECTURE.md` documents the
invariants that make those safe. pgcheck turns the documented disciplines
into machine-checked ones: five stdlib-``ast`` passes walk the source and
fail CI on a violation, so a dropped ``with self._lock:`` or an unbucketed
device buffer is a red lint job, not a debugging session three PRs later.

Passes (see ``docs/STATIC_ANALYSIS.md`` for the full catalog and the
annotation syntax):

* **PG001 lock-discipline** — fields declared in a per-class ``_GUARDED_BY``
  map may only be touched under their lock (or in ``*_locked`` methods).
* **PG002 publish-after-invalidate** — in mutators, the invalidation feed
  fires before the single serving-view publication (invariant 9).
* **PG003 recompile guard** — raw ``len()``/``.shape`` sizes must pass
  through a pow2-bucket helper before reaching a jit/device boundary.
* **PG004 host-sync-in-span** — ``.item()`` / ``np.asarray`` on unfenced
  device values inside ``trace.span`` bodies or jitted functions.
* **PG005 footprint coverage** — every server query kind declares its
  ``Footprint`` discipline in ``_KIND_FOOTPRINTS`` (invariant 7).

Stdlib-only on purpose: the CI lint job runs ``python -m tools.pgcheck``
before any dependency install, next to ruff and ``tools/check_links.py``.
"""
from .driver import run_paths  # noqa: F401
from .model import Finding     # noqa: F401

__all__ = ["Finding", "run_paths"]
