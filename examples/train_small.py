"""End-to-end training driver demo: a small qwen3-family model trained for a
few hundred steps on the synthetic Markov corpus, with checkpointing and an
injected fault to exercise the recovery path.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 200]
      PYTHONPATH=src python examples/train_small.py --large   # ~100M params

Default is a ~25M-param config sized for this CPU container; --large uses
the ~100M config (d_model 512, 8 layers, vocab 8192). The same driver runs
the full pod-scale configs (launch/train.py).
"""
import argparse
import tempfile

from repro.distributed.fault import FaultInjector
from repro.launch.train import TrainRunConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--large", action="store_true",
                    help="~100M params instead of ~25M")
    ap.add_argument("--inject-fault", action="store_true", default=True)
    args = ap.parse_args()

    d_model, layers, vocab = (512, 8, 8192) if args.large else (256, 6, 4096)
    with tempfile.TemporaryDirectory() as ckpt:
        run = TrainRunConfig(
            arch="qwen3_8b", use_reduced=True,
            d_model=d_model, layers=layers, vocab_size=vocab,
            steps=args.steps, global_batch=args.batch, seq_len=128,
            lr=3e-3, warmup=20,
            ckpt_dir=ckpt, ckpt_every=50)
        fault = FaultInjector(fail_at_steps=[args.steps // 2]) \
            if args.inject_fault else None
        _, hist = train(run, fault=fault)

    losses = [h["loss"] for h in hist]
    print(f"\nsteps run (incl. replay after fault): {len(hist)}")
    print(f"loss: first={losses[0]:.4f}  "
          f"mid={losses[len(losses) // 2]:.4f}  last={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training did not learn"
    print("OK: loss decreased; fault recovery exercised" if fault else "OK")


if __name__ == "__main__":
    main()
