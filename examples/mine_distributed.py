"""Distributed ProbGraph mining demo (the paper's workload on a device mesh).

Spawns 8 host devices, builds Bloom sketches with a vertex-sharded
shard_map, runs edge-sharded triangle counting with psum combining, and
compares against the exact count. The same code path targets the 16×16 pod
mesh (launch/mine.py).

Run:  PYTHONPATH=src python examples/mine_distributed.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402

from repro.core import graph as G  # noqa: E402
from repro.core import exact as X  # noqa: E402
from repro.launch.mine import mine  # noqa: E402


def main():
    g = G.kronecker(12, 16, seed=1)
    print(f"graph: n={g.n} m={g.m} d_max={g.d_max}")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = mine(g, mesh, storage_budget=0.25, num_hashes=1)
    print(f"devices={out['devices']} words/vertex={out['words']}")
    print(f"sketch build: {out['build_s']:.2f}s   mining: {out['mine_s']:.2f}s")
    t0 = time.time()
    tc = int(X.exact_triangle_count(g))
    t_exact = time.time() - t0
    rel = abs(out["tc_estimate"] - tc) / max(tc, 1)
    print(f"TC: estimate={out['tc_estimate']:.0f} exact={tc} "
          f"rel_err={rel:.3f} (exact took {t_exact:.2f}s)")


if __name__ == "__main__":
    main()
