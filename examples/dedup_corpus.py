"""MinHash near-duplicate dedup in the LM data pipeline (DESIGN.md §4.1).

The ProbGraph technique applied where production LM stacks actually use it:
k-Hash sketches over document shingles + the paper's exponential bound
(Prop IV.2) to size k for a target false-match rate, then banded LSH to find
candidates.

Run:  PYTHONPATH=src python examples/dedup_corpus.py
"""
import numpy as np

from repro.data import minhash_dedup
from repro.data.dedup import k_for


def make_corpus(rng, n_docs=60, n_dups=20):
    docs = [rng.integers(0, 5000, size=rng.integers(200, 800)).astype(np.int64)
            for _ in range(n_docs)]
    # near-duplicates: 3% token noise over random originals
    for i in rng.choice(n_docs, size=n_dups, replace=False):
        d = docs[i].copy()
        idx = rng.choice(len(d), size=max(1, len(d) // 33), replace=False)
        d[idx] = rng.integers(0, 5000, size=len(idx))
        docs.append(d)
    return docs, n_docs


def main():
    rng = np.random.default_rng(0)
    docs, n_orig = make_corpus(rng)
    # Prop IV.2: sketch size for ±0.1 Jaccard resolution at 1% failure prob
    k = k_for(j_gap=0.1, delta=0.01)
    print(f"corpus: {len(docs)} docs ({len(docs) - n_orig} planted near-dups)")
    print(f"Prop IV.2 says k={k} for |Ĵ−J| < 0.1 w.p. 99%")

    keep, stats = minhash_dedup(docs, threshold=0.7, k=max(64, k))
    dropped = (~keep).sum()
    dropped_planted = (~keep[n_orig:]).sum()
    print(f"dropped {dropped} docs ({dropped_planted} of the planted dups); "
          f"checked {stats['checked_pairs']} candidate pairs via LSH")
    for a, b, j in stats["dropped_pairs"][:5]:
        print(f"  doc{b} ≈ doc{a} (Ĵ={j:.2f})")
    kept_tokens = sum(len(docs[i]) for i in range(len(docs)) if keep[i])
    print(f"tokens kept: {kept_tokens}")


if __name__ == "__main__":
    main()
