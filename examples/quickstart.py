"""Quickstart: ProbGraph in five minutes (paper Listing 6, JAX edition).

Builds a graph, constructs probabilistic set representations, estimates
set-intersection cardinalities and triangle counts, and compares against the
exact baselines — including the concentration bounds that make the accuracy
knob quantitative.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (bounds, erdos_renyi, build, make_pair_cardinality_fn,
                        triangle_count, jarvis_patrick, pair_similarity)
from repro.core.exact import exact_triangle_count, exact_pair_cardinalities


def main():
    # 1) a graph (paper: CSRGraph g = CSRGraph(G))
    g = erdos_renyi(500, 0.4, seed=1)   # econ-like density: the paper regime
    print(f"graph: n={g.n} m={g.m} d_max={g.d_max}")

    # 2) ProbGraph representations at a 25% storage budget
    #    (paper: ProbGraph pg = ProbGraph(g, BF, 0.25))
    pg_bf = build(g, "bf", storage_budget=0.25, num_hashes=1)
    pg_kh = build(g, "kh", storage_budget=0.25)

    # 3) |N_u ∩ N_v|: exact vs estimators
    pairs = g.edges[:8]
    exact = exact_pair_cardinalities(g, pairs)
    est_bf = make_pair_cardinality_fn(g, pg_bf)(pairs)
    est_kh = make_pair_cardinality_fn(g, pg_kh)(pairs)
    print("\n|N_u ∩ N_v|  exact:", exact.tolist())
    print("             BF-AND:", [round(float(x), 1) for x in est_bf])
    print("             k-Hash:", [round(float(x), 1) for x in est_kh])

    # 4) the paper's quantitative accuracy knob (Prop IV.2):
    k = bounds.minhash_k_for_accuracy(size_x=200, size_y=200, t=30, delta=0.05)
    print(f"\nProp IV.2: k={k} guarantees P(|err| ≥ 30) ≤ 5% for |X|=|Y|=200")

    # 5) graph mining: triangle counting + clustering
    tc_exact = int(exact_triangle_count(g))
    tc_bf = float(triangle_count(g, pg_bf))
    tc_kh = float(triangle_count(g, pg_kh))
    print(f"\nTC exact={tc_exact}  BF={tc_bf:.0f} "
          f"({100 * abs(tc_bf - tc_exact) / tc_exact:.1f}% err)  "
          f"kH={tc_kh:.0f} ({100 * abs(tc_kh - tc_exact) / tc_exact:.1f}% err)")

    # clustering wants separated similarities: use a planted-community graph
    from repro.core.graph import random_bipartite_community
    gc = random_bipartite_community(400, 4, 0.25, 0.002, seed=2)
    pg_c = build(gc, "bf", storage_budget=0.5, num_hashes=2)
    _, n_exact = jarvis_patrick(gc, None, "jaccard", 0.05)
    _, n_bf = jarvis_patrick(gc, pg_c, "jaccard", 0.05)
    print(f"Jarvis-Patrick clusters (4 planted communities): "
          f"exact={int(n_exact)} BF={int(n_bf)}")

    # 6) vertex similarity (Listing 3)
    jac = pair_similarity(g, pairs, "jaccard", pg_bf)
    print("Jaccard (BF):", [round(float(x), 3) for x in jac])

    # 7) the batched mining engine: one sketch build + one per-edge pass
    #    shared across TC, LCC and clustering (repro.engine.session)
    from repro import engine
    sess = engine.session(g, pg_bf)
    tc_sess = float(sess.triangle_count())          # reuses the shared pass
    lcc_mean = float(jnp.mean(sess.local_clustering()))
    cc4 = float(sess.four_clique_count())
    print(f"\nengine session: TC={tc_sess:.0f} mean-LCC={lcc_mean:.3f} "
          f"4-cliques={cc4:.0f} (one sketch, one edge pass)")

    # 8) local clustering: PPR push + sweep cut around seed vertices, the
    #    |N(v) ∩ S| cut increments served by Bloom prefix-filter popcounts
    import numpy as np
    seeds = np.array([0, 7, 42])
    sess_c = engine.session(gc, "bf", storage_budget=2.0)
    lc = sess_c.local_cluster(seeds, alpha=0.15, eps=1e-4)
    for i, seed in enumerate(seeds):
        print(f"local cluster around seed {seed}: |C|={int(lc.best_size[i])} "
              f"phi={float(lc.best_conductance[i]):.3f}")


if __name__ == "__main__":
    main()
