"""Paper §VIII-D: comparison against guarantee-free heuristics —
"Reduced Execution" (truncate the outer loop) and "Partial Graph
Processing" (random neighbor subsets) [Singh & Nasre].

PG's pitch: at similar speedups the sketch estimators keep provable accuracy
while the heuristics drift (paper reports PG better by 25–75%).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import graph as G, sketches as S
from repro.core import exact as X
from repro.core import triangle_count

from .common import emit, timeit


def reduced_execution(g: G.Graph, fraction: float) -> float:
    """Process the first `fraction` of edges, scale the partial sum."""
    m_red = max(1, int(g.m * fraction))
    part = X.exact_pair_cardinalities(g, g.edges[:m_red])
    return float(jnp.sum(part)) / fraction / 3.0


def partial_processing(g: G.Graph, keep: float, seed: int = 0) -> float:
    """Random neighbor subsets: drop (1-keep) of each row, rescale."""
    rng = np.random.default_rng(seed)
    adj = np.asarray(g.adj)
    mask = rng.random(adj.shape) < keep
    adj_red = np.where(mask, adj, g.n)
    adj_red = np.sort(adj_red, axis=1)
    g_red = G.Graph(indptr=g.indptr, indices=g.indices,
                    adj=jnp.asarray(adj_red), deg=g.deg, edges=g.edges,
                    n_vertices=g.n, n_edges=g.m, d_max=g.d_max)
    part = X.exact_pair_cardinalities(g_red, g.edges)
    # each shared neighbor survives with prob keep^2? both rows independent:
    return float(jnp.sum(part)) / (keep * keep) / 3.0


def run():
    g = G.kronecker(12, 16, seed=2)
    tc = float(X.exact_triangle_count(g))
    for frac in (0.25, 0.5):
        import time as _t
        t0 = _t.perf_counter()
        est = reduced_execution(g, frac)
        emit(f"heur_reduced_{frac}", (_t.perf_counter() - t0) * 1e6,
             f"rel_err={abs(est - tc) / tc:.3f}")
    for keep in (0.5,):
        import time as _t
        t0 = _t.perf_counter()
        est = partial_processing(g, keep)
        emit(f"heur_partial_{keep}", (_t.perf_counter() - t0) * 1e6,
             f"rel_err={abs(est - tc) / tc:.3f}")
    for kind, b in [("bf", 2), ("1h", 1)]:
        sk = S.build(g, kind, 0.25, num_hashes=b, seed=7)
        fn = jax.jit(triangle_count)
        emit(f"heur_pg_{kind}", timeit(fn, g, sk, iters=3),
             f"rel_err={abs(float(fn(g, sk)) - tc) / tc:.3f}")


if __name__ == "__main__":
    run()
