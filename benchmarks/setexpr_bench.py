"""Set-expression compiler: fused single-pass vs chained two-pass.

The point of ``engine.setexpr`` is that a k-way expression runs as ONE
gather→eval→popcount pass instead of materializing intermediate AND rows
in HBM. This suite measures that on the 3-way AND (the 4-clique / cliques5
inner loop shape): the fused compiled expression against the chained
baseline that materializes ``r_uv = rows[u] & rows[v]`` and then popcounts
``r_uv & rows[w]`` in a second pass. On CPU both lower through XLA (the
compiled expression's jnp path — identical integers to the Pallas kernel);
the derived column reports HBM bytes the chain writes+rereads that the
fused pass never touches.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.engine import setexpr
from .common import emit, timeit


def _chained_and3(bloom, triples):
    """Two-pass baseline: materialize the pairwise AND, then popcount."""
    ru = jnp.take(bloom, triples[:, 0], axis=0)
    rv = jnp.take(bloom, triples[:, 1], axis=0)
    r_uv = ru & rv                       # materialized intermediate rows
    rw = jnp.take(bloom, triples[:, 2], axis=0)
    return jnp.sum(jax.lax.population_count(r_uv & rw), axis=-1)


def run():
    """Emit fused-vs-chained rows for the 3-way AND at mining shapes."""
    rng = np.random.default_rng(0)
    ce = setexpr.compile_expr(setexpr.and_all(*setexpr.rows(3)),
                              use_kernel=False)
    for n, t, w in [(8192, 65536, 32), (8192, 16384, 128)]:
        bloom = jnp.asarray(
            rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
        triples = jnp.asarray(
            rng.integers(0, n, size=(t, 3), dtype=np.int32))

        fused = jax.jit(ce.ones).lower(bloom, triples).compile()
        chain = jax.jit(_chained_and3).lower(bloom, triples).compile()
        np.testing.assert_array_equal(np.asarray(fused(bloom, triples)),
                                      np.asarray(chain(bloom, triples)))

        us_f = timeit(lambda: fused(bloom, triples), iters=5)
        us_c = timeit(lambda: chain(bloom, triples), iters=5)
        inter_bytes = t * w * 4          # the r_uv rows the chain round-trips
        emit(f"setexpr_and3_fused_t{t}_w{w}", us_f,
             f"speedup_vs_chained={us_c / us_f:.2f}x")
        emit(f"setexpr_and3_chained_t{t}_w{w}", us_c,
             f"intermediate_MB={inter_bytes / 1e6:.1f}")


if __name__ == "__main__":
    run()
