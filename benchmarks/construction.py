"""Paper §VIII-F / Table V: sketch construction cost vs one mining pass.

Claim to validate: construction is cheap relative to a single algorithm
execution (and amortizes across algorithms)."""
from __future__ import annotations

import functools

import jax

from repro.core import graph as G, sketches as S
from repro.core import triangle_count

from .common import emit, timeit


def run(budget: float = 0.25):
    g = G.kronecker(12, 16, seed=2)
    words = S.bloom_words_for_budget(g.n, g.m, budget)
    k = S.minhash_k_for_budget(g.n, g.m, budget)

    builders = {
        "bf_b1": (jax.jit(functools.partial(S.build_bloom, words=words,
                                            num_hashes=1, seed=7))),
        "bf_b4": (jax.jit(functools.partial(S.build_bloom, words=words,
                                            num_hashes=4, seed=7))),
        "kh": jax.jit(functools.partial(S.build_khash, k=k, seed=7)),
        "1h": jax.jit(functools.partial(S.build_1hash, k=k, seed=7)),
        "kmv": jax.jit(functools.partial(S.build_kmv, k=k, seed=7)),
    }
    times = {}
    for name, fn in builders.items():
        times[name] = timeit(fn, g, iters=3)

    sk = S.build(g, "bf", budget, num_hashes=1, seed=7)
    tc_fn = jax.jit(triangle_count)
    t_tc = timeit(tc_fn, g, sk, iters=3)
    for name, t in times.items():
        emit(f"tableV_construct_{name}", t, f"vs_one_tc_pass={t / t_tc:.2f}x")


if __name__ == "__main__":
    run()
