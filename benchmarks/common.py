"""Shared benchmark utilities: timing, warmup spans, structured records."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.obs import trace

ROWS = []
RECORDS = []


def reset_records() -> None:
    """Start a fresh row/record set (benchmarks.run calls this per suite)."""
    ROWS.clear()
    RECORDS.clear()


def _parse_derived(derived: str) -> dict:
    """``k1=v1;k2=v2`` derived strings -> a flat metrics dict.

    Values float-coerce where possible (trailing ``x`` ratio suffixes are
    stripped); everything else stays a string. Bare tokens become ``True``.
    """
    out = {}
    for part in filter(None, derived.split(";")):
        if "=" not in part:
            out[part] = True
            continue
        k, _, v = part.partition("=")
        try:
            out[k] = float(v[:-1] if v.endswith("x") else v)
        except ValueError:
            out[k] = v
    return out


def dress_rehearsal(fn: Callable, label: str = "bench.warmup"):
    """Run ``fn`` once as an explicit, span-marked warmup.

    Hoists the shared warm-up discipline out of individual suites: the call
    compiles/warms whatever the benchmark is about to time, is excluded from
    reported stats by construction, and shows up in traces as its own
    ``bench.warmup`` span instead of polluting iteration 0.
    """
    with trace.span(label) as sp:
        out = fn()
        sp.fence(out)
    jax.block_until_ready(out)
    return out


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall microseconds per call of a (jit'd) function."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str = ""):
    """Print/record one result row.

    Keeps the human CSV line and additionally appends a schema-consistent
    record — ``{"name", "wall_s", "metrics"}`` — to :data:`RECORDS` so
    benchmarks.run can write machine-diffable ``BENCH_*.json`` files.
    """
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "wall_s": us_per_call * 1e-6,
                    "metrics": _parse_derived(derived)})
    print(row, flush=True)
