"""Shared benchmark utilities: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

ROWS = []


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall microseconds per call of a (jit'd) function."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)
