"""Paper Figs. 4–6: speedup / accuracy / memory of PG-enhanced algorithms
vs the tuned exact baselines (TC, 4-clique, clustering, vertex similarity).

Speedup = exact_time / pg_time on identical jit'd paths; accuracy =
|count_PG − count_EX|/count_EX (the paper's metric); memory = sketch bytes
relative to CSR bytes.
"""
from __future__ import annotations

import functools

import numpy as np
import jax

from repro.core import graph as G, sketches as S
from repro.core import exact as X
from repro.core import triangle_count, four_clique_count, jarvis_patrick
from repro.core.intersect import make_pair_cardinality_fn

from .common import emit, timeit


def _sketch_bytes(sk: S.SketchSet) -> int:
    return sk.data.size * sk.data.dtype.itemsize


def _csr_bytes(g: G.Graph) -> int:
    return (2 * g.m + g.n + 1) * 4


def run(budget: float = 0.25):
    graphs = {
        "kron_s12": G.kronecker(12, 16, seed=2),
        "community": G.random_bipartite_community(2000, 8, 0.08, 0.002, seed=4),
    }
    for gname, g in graphs.items():
        # --- Triangle counting (graph/sketch passed as args: no folding)
        tc_exact_fn = jax.jit(X.exact_triangle_count)
        t_exact = timeit(tc_exact_fn, g, iters=3)
        tc_exact = float(tc_exact_fn(g))
        for kind, b in [("bf", 2), ("kh", 1), ("1h", 1)]:
            sk = S.build(g, kind, budget, num_hashes=b, seed=7)
            fn = jax.jit(triangle_count)
            t_pg = timeit(fn, g, sk, iters=3)
            acc = abs(float(fn(g, sk)) - tc_exact) / max(tc_exact, 1)
            emit(f"fig4_tc_{gname}_{kind}", t_pg,
                 f"speedup={t_exact / t_pg:.2f};rel_err={acc:.3f};"
                 f"mem_ratio={_sketch_bytes(sk) / _csr_bytes(g):.3f}")

        # --- Clustering (common neighbors + jaccard + overlap)
        for sim, thr in [("common", 2.0), ("jaccard", 0.05), ("overlap", 0.3)]:
            ex_fn = jax.jit(functools.partial(jarvis_patrick, similarity=sim,
                                              threshold=thr))
            t_ex = timeit(ex_fn, g, iters=3)
            n_ex = int(ex_fn(g)[1])
            sk = S.build(g, "bf", budget, num_hashes=2, seed=7)
            pg_fn = jax.jit(functools.partial(jarvis_patrick, similarity=sim,
                                              threshold=thr))
            t_pg = timeit(pg_fn, g, sk, iters=3)
            n_pg = int(pg_fn(g, sk)[1])
            emit(f"fig4_cluster_{sim}_{gname}_bf", t_pg,
                 f"speedup={t_ex / t_pg:.2f};rel_count={n_pg / max(n_ex, 1):.2f}")

    # --- 4-clique counting (smaller graph: wedge enumeration is heavy)
    g4 = G.kronecker(9, 10, seed=5)
    ex4 = jax.jit(functools.partial(four_clique_count, edge_chunk=512))
    t_ex4 = timeit(ex4, g4, iters=2)
    c_ex = float(ex4(g4))
    for kind, b in [("bf", 2), ("kh", 1)]:
        sk = S.build(g4, kind, budget, num_hashes=b, seed=7)
        pg4 = jax.jit(functools.partial(four_clique_count, edge_chunk=512))
        t_pg4 = timeit(pg4, g4, sk, iters=2)
        acc = abs(float(pg4(g4, sk)) - c_ex) / max(c_ex, 1)
        emit(f"fig5_4clique_{kind}", t_pg4,
             f"speedup={t_ex4 / t_pg4:.2f};rel_err={acc:.3f}")


if __name__ == "__main__":
    run()
