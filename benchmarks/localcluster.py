"""Local clustering benchmark: PPR push + sweep cuts, sketch vs exact.

Rows (``name,us_per_call,derived``):
  * ``localcluster/push``        — batched PPR forward push alone.
  * ``localcluster/sweep_exact`` — sweep-cut scan, exact rank-compare
                                   increments (O(S·k·d_max) gathers).
  * ``localcluster/sweep_bf``    — sweep-cut scan, Bloom prefix-filter
                                   increments (O(S·k·words) popcounts).
  * ``localcluster/e2e_*``       — full push+sweep, with seeds/sec and the
                                   sketch-vs-exact accuracy of the best
                                   conductance (mean |Δφ| over the batch).

The sketch path's win grows with degree skew: the exact sweep pays d_max per
step, the filter pays a fixed word count (the ProbGraph trade applied to the
conductance numerator).
"""
from __future__ import annotations

import numpy as np

from repro.core import bounds, graph as G, sketches as SK
from repro.core.algorithms import localcluster as LC
from repro import engine as ENG

from .common import emit, timeit

SCALE = 10
SEEDS = 8
ALPHA = 0.15
EPS = 1e-4


def run() -> None:
    """Emit the localcluster suite's CSV rows (see module docstring)."""
    g = G.kronecker(SCALE, 8, seed=1)
    sk = SK.build(g, "bf", storage_budget=2.0)
    plan = ENG.plan_for(g, sk)
    rng = np.random.default_rng(3)
    seeds = rng.integers(0, g.n, size=SEEDS).astype(np.int32)

    p, _, _ = LC.ppr_push(g, seeds, ALPHA, EPS)
    us = timeit(lambda: LC.ppr_push(g, seeds, ALPHA, EPS)[0])
    emit("localcluster/push", us, f"n={g.n},m={g.m},seeds={SEEDS}")

    us_exact = timeit(lambda: LC.sweep_cut(g, p, None, plan)[1])
    emit("localcluster/sweep_exact", us_exact,
         f"k={plan.sweep_cap},d_max={g.d_max}")
    us_bf = timeit(lambda: LC.sweep_cut(g, p, sk, plan)[1])
    emit("localcluster/sweep_bf", us_bf,
         f"k={plan.sweep_cap},words={sk.data.shape[1]},"
         f"speedup={us_exact / max(us_bf, 1e-9):.2f}x")

    res_e = LC.local_cluster(g, seeds, ALPHA, EPS, None, plan)
    res_b = LC.local_cluster(g, seeds, ALPHA, EPS, sk, plan)
    us_e2e = timeit(
        lambda: LC.local_cluster(g, seeds, ALPHA, EPS, sk, plan).conductance)
    phi_e = np.asarray(res_e.best_conductance)
    phi_b = np.asarray(res_b.best_conductance)
    ok = np.isfinite(phi_e) & np.isfinite(phi_b)
    dphi = float(np.mean(np.abs(phi_e[ok] - phi_b[ok]))) if ok.any() else 0.0
    # bound check at the worst (longest) sweep of the batch
    deg = np.asarray(g.deg)
    order = np.asarray(res_e.order)
    sup = int(np.asarray(res_e.support).max())
    s_worst = int(np.asarray(res_e.support).argmax())
    degs = deg[order[s_worst, :sup]]
    vol = np.cumsum(degs)
    half = bounds.sweep_conductance_interval(
        degs, np.minimum(vol, 2 * g.m - vol), sk.total_bits, sk.num_hashes)
    emit("localcluster/e2e_bf", us_e2e,
         f"seeds_per_s={SEEDS / (us_e2e / 1e6):.0f},mean_dphi={dphi:.4f},"
         f"bound_last={half[-1]:.3f}")
