"""Local clustering benchmark: PPR push + sweep cuts, sketch vs exact.

Rows (``name,us_per_call,derived``):
  * ``localcluster/push``        — batched PPR forward push alone.
  * ``localcluster/sweep_exact`` — sweep-cut scan, exact rank-compare
                                   increments (O(S·k·d_max) gathers).
  * ``localcluster/sweep_bf``    — sweep-cut scan, Bloom prefix-filter
                                   increments (O(S·k·words) popcounts).
  * ``localcluster/e2e_*``       — full push+sweep, with seeds/sec and the
                                   sketch-vs-exact accuracy of the best
                                   conductance (mean |Δφ| over the batch).
  * ``localcluster/push_dense_s12`` / ``push_sparse_s12`` /
    ``e2e_sparse_s12``           — dense-vs-sparse frontier phase at scale
                                   12: peak residual-buffer bytes per path
                                   (dense ``[S, n]`` vs capped ``[S, cap]``,
                                   ratio asserted ≥ 10x), seeds/sec, and the
                                   equivalence checks (no spill, sweep
                                   profiles bit-identical on the shared
                                   support, mean |Δφ| ≈ 0).

The sketch path's win grows with degree skew: the exact sweep pays d_max per
step, the filter pays a fixed word count (the ProbGraph trade applied to the
conductance numerator).
"""
from __future__ import annotations

import numpy as np

from repro.core import bounds, graph as G, sketches as SK
from repro.core.algorithms import localcluster as LC
from repro import engine as ENG

from .common import emit, timeit

SCALE = 10
SEEDS = 8
ALPHA = 0.15
EPS = 1e-4

# sparse-frontier phase: large enough that dense [S, n] residuals dwarf the
# capped buffers (cap = pow2(1/(ALPHA·EPS_SPARSE)) = 256 vs n = 4096), eps
# loose enough that the support provably fits the cap (no spill)
SCALE_SPARSE = 12
SEEDS_SPARSE = 8
EPS_SPARSE = 3e-2


def run() -> None:
    """Emit the localcluster suite's CSV rows (see module docstring)."""
    g = G.kronecker(SCALE, 8, seed=1)
    sk = SK.build(g, "bf", storage_budget=2.0)
    plan = ENG.plan_for(g, sk)
    rng = np.random.default_rng(3)
    seeds = rng.integers(0, g.n, size=SEEDS).astype(np.int32)

    p, _, _ = LC.ppr_push(g, seeds, ALPHA, EPS)
    us = timeit(lambda: LC.ppr_push(g, seeds, ALPHA, EPS)[0])
    emit("localcluster/push", us, f"n={g.n},m={g.m},seeds={SEEDS}")

    us_exact = timeit(lambda: LC.sweep_cut(g, p, None, plan)[1])
    emit("localcluster/sweep_exact", us_exact,
         f"k={plan.sweep_cap},d_max={g.d_max}")
    us_bf = timeit(lambda: LC.sweep_cut(g, p, sk, plan)[1])
    emit("localcluster/sweep_bf", us_bf,
         f"k={plan.sweep_cap},words={sk.data.shape[1]},"
         f"speedup={us_exact / max(us_bf, 1e-9):.2f}x")

    res_e = LC.local_cluster(g, seeds, ALPHA, EPS, None, plan)
    res_b = LC.local_cluster(g, seeds, ALPHA, EPS, sk, plan)
    us_e2e = timeit(
        lambda: LC.local_cluster(g, seeds, ALPHA, EPS, sk, plan).conductance)
    phi_e = np.asarray(res_e.best_conductance)
    phi_b = np.asarray(res_b.best_conductance)
    ok = np.isfinite(phi_e) & np.isfinite(phi_b)
    dphi = float(np.mean(np.abs(phi_e[ok] - phi_b[ok]))) if ok.any() else 0.0
    # bound check at the worst (longest) sweep of the batch
    deg = np.asarray(g.deg)
    order = np.asarray(res_e.order)
    sup = int(np.asarray(res_e.support).max())
    s_worst = int(np.asarray(res_e.support).argmax())
    degs = deg[order[s_worst, :sup]]
    vol = np.cumsum(degs)
    half = bounds.sweep_conductance_interval(
        degs, np.minimum(vol, 2 * g.m - vol), sk.total_bits, sk.num_hashes)
    emit("localcluster/e2e_bf", us_e2e,
         f"seeds_per_s={SEEDS / (us_e2e / 1e6):.0f},mean_dphi={dphi:.4f},"
         f"bound_last={half[-1]:.3f}")

    _sparse_phase()


def _sparse_phase() -> None:
    """Dense-vs-sparse frontier rows at scale ≥ 12 (see module docstring).

    Asserts the phase's claims instead of just printing them: the capped
    buffers undercut the dense residuals by ≥ 10x, the sparse path did not
    spill, and the two sweep profiles are bit-identical on the shared
    support — so a regression in the sparse push fails the nightly bench
    run, not just a dashboard.
    """
    g = G.kronecker(SCALE_SPARSE, 6, seed=2)
    rng = np.random.default_rng(5)
    seeds = rng.integers(0, g.n, size=SEEDS_SPARSE).astype(np.int32)
    plan_d = ENG.plan_for(g, frontier_mode="dense")
    plan_s = ENG.plan_for(g, frontier_mode="sparse")

    p, r, _ = LC.ppr_push(g, seeds, ALPHA, EPS_SPARSE)
    us_d = timeit(lambda: LC.ppr_push(g, seeds, ALPHA, EPS_SPARSE)[0])
    dense_bytes = p.nbytes + r.nbytes
    emit("localcluster/push_dense_s12", us_d,
         f"n={g.n},seeds={SEEDS_SPARSE},res_bytes={dense_bytes}")

    fr = LC.ppr_push_sparse(g, seeds, ALPHA, EPS_SPARSE)
    assert not bool(fr.overflowed), "sparse phase spilled; retune EPS_SPARSE"
    us_s = timeit(lambda: LC.ppr_push_sparse(g, seeds, ALPHA, EPS_SPARSE).p)
    sparse_bytes = fr.idx.nbytes + fr.p.nbytes + fr.r.nbytes
    ratio = dense_bytes / sparse_bytes
    assert ratio >= 10.0, f"memory ratio {ratio:.1f}x below the 10x floor"
    emit("localcluster/push_sparse_s12", us_s,
         f"cap={fr.cap},res_bytes={sparse_bytes},mem_ratio={ratio:.1f}x,"
         f"seeds_per_s={SEEDS_SPARSE / (us_s / 1e6):.0f}")

    res_d = LC.local_cluster(g, seeds, ALPHA, EPS_SPARSE, None, plan_d)
    res_s = LC.local_cluster(g, seeds, ALPHA, EPS_SPARSE, None, plan_s)
    us_e2e = timeit(
        lambda: LC.local_cluster(g, seeds, ALPHA, EPS_SPARSE, None,
                                 plan_s).conductance)
    k = min(res_d.order.shape[1], res_s.order.shape[1])
    ord_d, ord_s = np.asarray(res_d.order)[:, :k], np.asarray(res_s.order)[:, :k]
    phi_d = np.asarray(res_d.conductance)[:, :k]
    phi_s = np.asarray(res_s.conductance)[:, :k]
    shared = ord_d == ord_s
    assert np.array_equal(phi_d[shared], phi_s[shared]), \
        "sparse sweep profile diverged from dense on the shared support"
    bd, bs = np.asarray(res_d.best_conductance), \
        np.asarray(res_s.best_conductance)
    ok = np.isfinite(bd) & np.isfinite(bs)
    dphi = float(np.mean(np.abs(bd[ok] - bs[ok]))) if ok.any() else 0.0
    emit("localcluster/e2e_sparse_s12", us_e2e,
         f"seeds_per_s={SEEDS_SPARSE / (us_e2e / 1e6):.0f},"
         f"mean_dphi={dphi:.4f},shared_frac={shared.mean():.3f}")
