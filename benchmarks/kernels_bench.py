"""Kernel microbenchmarks.

The Pallas kernels are TPU-target; on CPU they run in interpret mode (Python
— correctness only, no speed). The numbers that matter on this host are the
XLA-compiled jnp reference paths, which share the exact op structure the
TPU kernel implements (AND+popcount / k² compare). We report those, plus the
arithmetic intensity that drives the §Perf roofline for the mining step.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ref
from .common import emit, timeit


def run():
    rng = np.random.default_rng(0)
    for e, w in [(4096, 32), (16384, 32), (16384, 128)]:
        a = jnp.asarray(rng.integers(0, 2**32, size=(e, w), dtype=np.uint32))
        b = jnp.asarray(rng.integers(0, 2**32, size=(e, w), dtype=np.uint32))
        fn = jax.jit(ref.bf_intersect_pairs).lower(a, b).compile()
        us = timeit(lambda: fn(a, b), iters=5)
        bytes_moved = 2 * e * w * 4
        emit(f"kern_bf_intersect_e{e}_w{w}", us,
             f"GBps={bytes_moved / us / 1e3:.2f};ai=0.75flops/byte")

    n, e, w = 8192, 65536, 32
    bloom = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    edges = jnp.asarray(rng.integers(0, n, size=(e, 2), dtype=np.int32))
    fn = jax.jit(ref.bf_edge_intersect).lower(bloom, edges).compile()
    us = timeit(lambda: fn(bloom, edges), iters=5)
    emit(f"kern_bf_edge_gather_e{e}", us,
         f"GBps={2 * e * w * 4 / us / 1e3:.2f}")

    for e, k in [(16384, 32), (2048, 128)]:
        a = jnp.asarray(np.sort(rng.integers(0, 10**6, size=(e, k)), axis=1).astype(np.int32))
        b = jnp.asarray(np.sort(rng.integers(0, 10**6, size=(e, k)), axis=1).astype(np.int32))
        fn = jax.jit(lambda x, y: ref.mh_intersect_pairs(x, y, 10**6)
                     ).lower(a, b).compile()
        us = timeit(lambda: fn(a, b), iters=5)
        emit(f"kern_mh_intersect_e{e}_k{k}", us,
             f"pairs_per_s={e / us * 1e6 / 1e6:.1f}M")


if __name__ == "__main__":
    run()
