"""Serving-tier result cache benchmark: Zipf-skewed replay traffic.

Real serving traffic is heavily repeated and skewed, so the win after
device-resident deltas is not recomputing answers whose inputs did not
change. This suite replays the *same* Zipf(s)-ranked request stream —
similarity / membership / link-prediction / local-cluster / triangle-count
mix, with edge deltas interleaved at fixed positions — twice over freshly
built, identical sessions: once with the footprint-invalidated result cache
off, once on. It reports hit rate, mean and p95 per-request latency,
throughput, the cache's eviction breakdown, and (the point of the exercise)
the mean-latency improvement; it also asserts the two replays' answers are
bit-identical, because a cache that changes answers is not a cache.

  PYTHONPATH=src python -m benchmarks.serving --smoke --json BENCH_serving.json

The last line printed is a machine-readable JSON summary (written to
``--json PATH`` as well, for the nightly-CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro.core import graph as G
from repro.obs import trace
from repro.stream import BatchedQueryServer, DynamicGraph, StreamSession

from .common import dress_rehearsal, emit

# request mix: pair scoring dominates real lookalike/recommendation traffic;
# tc is the rare whole-graph dashboard query that no delta lets survive
_KIND_WEIGHTS = (("similarity", 0.50), ("membership", 0.22),
                 ("linkpred", 0.15), ("localcluster", 0.10), ("tc", 0.03))


def build_population(n: int, distinct: int, pairs_per_req: int, seed: int):
    """The distinct-request universe the Zipf ranks index into.

    Returns a list of ``(kind, payload)`` submit specs; rank 0 is the
    hottest request.
    """
    rng = np.random.default_rng(seed)
    kinds = rng.choice([k for k, _ in _KIND_WEIGHTS], size=distinct,
                       p=[w for _, w in _KIND_WEIGHTS])
    population = []
    for kind in kinds:
        if kind == "similarity":
            population.append((kind, {
                "pairs": rng.integers(0, n, size=(pairs_per_req, 2)
                                      ).astype(np.int32),
                "measure": str(rng.choice(["jaccard", "common", "overlap"]))}))
        elif kind == "membership":
            population.append((kind, {
                "u": int(rng.integers(0, n)),
                "candidates": rng.integers(0, n, size=16).astype(np.int32)}))
        elif kind == "linkpred":
            population.append((kind, {"u": int(rng.integers(0, n)),
                                      "top_k": 8}))
        elif kind == "localcluster":
            # eps 1e-2 keeps PPR supports local: the answer's footprint is
            # a neighborhood, not half the graph, so deltas elsewhere let
            # cached clusters survive (and the volume guard rarely trips)
            population.append((kind, {"seed": int(rng.integers(0, n)),
                                      "alpha": 0.15, "eps": 1e-2}))
        else:
            population.append(("tc", {}))
    return population


def zipf_ranks(distinct: int, s: float, total: int, seed: int) -> np.ndarray:
    """``total`` population ranks drawn from Zipf(s) over ``distinct`` items
    (s == 1.0 works, unlike numpy's own sampler)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, distinct + 1, dtype=np.float64) ** s
    return rng.choice(distinct, size=total, p=p / p.sum())


def _submit(server: BatchedQueryServer, kind: str, payload: dict) -> int:
    if kind == "similarity":
        return server.submit_similarity(payload["pairs"], payload["measure"])
    if kind == "membership":
        return server.submit_membership(payload["u"], payload["candidates"])
    if kind == "linkpred":
        return server.submit_link_prediction(payload["u"], payload["top_k"])
    if kind == "localcluster":
        return server.submit_local_cluster(payload["seed"], payload["alpha"],
                                           payload["eps"])
    return server.submit_triangle_count()


def _fresh_session(scale: int, edge_factor: int, budget: float, seed: int,
                   stream_frac: float):
    """Identical (graph, withheld delta stream) for every replay mode."""
    g = G.kronecker(scale, edge_factor, seed=seed)
    rng = np.random.default_rng(seed + 1)
    edges = np.asarray(g.edges)
    order = rng.permutation(edges.shape[0])
    split = int((1.0 - stream_frac) * edges.shape[0])
    st = StreamSession(DynamicGraph.from_edges(g.n, edges[order[:split]]),
                       kind="bf", storage_budget=budget)
    return st, edges[order[split:]]


def replay(st: StreamSession, arrivals: np.ndarray, population, ranks,
           use_cache: bool, delta_every: int, delta_edges: int,
           min_batch: int, flush_every: int):
    """Drive one request stream; returns (results_by_index, wall_s, server)."""
    server = BatchedQueryServer(st, min_batch=min_batch, cache=use_cache,
                                max_batch=flush_every)
    rid_to_idx = {}
    results = {}
    next_delta = 0
    t0 = time.perf_counter()
    for i, rank in enumerate(ranks):
        if delta_every and i % delta_every == 0 and arrivals.shape[0]:
            take = min(delta_edges, arrivals.shape[0])
            st.apply_delta(arrivals[next_delta:next_delta + take]
                           if next_delta + take <= arrivals.shape[0]
                           else arrivals[-take:])
            next_delta += take
        kind, payload = population[rank]
        rid_to_idx[_submit(server, kind, payload)] = i
        for rid, res in server.poll().items():
            results[rid_to_idx[rid]] = res
    for rid, res in server.flush().items():
        results[rid_to_idx[rid]] = res
    wall = time.perf_counter() - t0
    stats = server.stats()        # before close(), which drops the cache
    server.close()
    return results, wall, stats


def _values_equal(a, b) -> bool:
    if isinstance(a, dict):
        return set(a) == set(b) and all(_values_equal(a[k], b[k]) for k in a)
    if isinstance(a, np.ndarray):
        return bool(np.array_equal(a, np.asarray(b)))
    return a == b


def run(scale: int = 10, edge_factor: int = 8, distinct: int = 128,
        total: int = 2048, zipf_s: float = 1.0, delta_every: int = 256,
        delta_edges: int = 16, min_batch: int = 16, flush_every: int = 2,
        budget: float = 0.5, seed: int = 3, json_path=None,
        check_speedup: float = 0.0, trace_json=None,
        check_trace_overhead: float = 0.0) -> dict:
    """One full cache-off vs cache-on replay; returns the summary dict."""
    st0, _ = _fresh_session(scale, edge_factor, budget, seed, 0.2)
    n = st0.dyn.n
    population = build_population(n, distinct, pairs_per_req=16, seed=seed)
    ranks = zipf_ranks(distinct, zipf_s, total, seed + 7)

    modes = {}
    for timed in (False, True):
        # pass 0 is a full dress rehearsal: the two modes produce different
        # miss compositions, hence different pow2 batch shapes — replaying
        # the identical stream first pushes every remaining compile out of
        # the timed pass (XLA's in-process cache persists across sessions)
        for use_cache in (False, True):
            st, arrivals = _fresh_session(scale, edge_factor, budget, seed,
                                          0.2)

            def one_replay(st=st, arrivals=arrivals, use_cache=use_cache):
                return replay(st, arrivals, population, ranks, use_cache,
                              delta_every, delta_edges, min_batch,
                              flush_every)

            if not timed:
                dress_rehearsal(one_replay)
                continue
            results, wall, stats = one_replay()
            lat = np.asarray([results[i].latency_s
                              for i in range(len(ranks))])
            modes[use_cache] = (results, wall, stats, lat)

    off, on = modes[False], modes[True]

    # optional traced replay: one extra cache-on pass with span recording
    # enabled, to (a) export the nightly Perfetto artifact and (b) measure
    # the enabled-path tracing overhead against the untraced cache-on pass
    trace_overhead = None
    if trace_json or check_trace_overhead:
        was_enabled = trace.enabled()
        trace.enable()
        trace.clear()
        st, arrivals = _fresh_session(scale, edge_factor, budget, seed, 0.2)
        results_t, _, _ = replay(st, arrivals, population, ranks, True,
                                 delta_every, delta_edges, min_batch,
                                 flush_every)
        lat_t = np.asarray([results_t[i].latency_s
                            for i in range(len(ranks))])
        if trace_json:
            trace.export(trace_json)
        if not was_enabled:
            trace.disable()
        trace_overhead = float(lat_t.mean() / max(on[3].mean(), 1e-12) - 1.0)
    mismatch = sum(
        not _values_equal(off[0][i].value, on[0][i].value)
        for i in range(len(ranks)))
    cache_stats = on[2]["cache"]
    summary = {
        "event": "serving_bench",
        "n": n, "distinct": distinct, "requests": int(len(ranks)),
        "zipf_s": zipf_s,
        "hit_rate": round(cache_stats["hit_rate"], 4),
        "evicted_footprint": cache_stats["evicted_footprint"],
        "evicted_whole": cache_stats["evicted_whole"],
        "evicted_guard": cache_stats["evicted_guard"],
        "mean_latency_s_off": float(off[3].mean()),
        "mean_latency_s_on": float(on[3].mean()),
        "p95_latency_s_off": float(np.percentile(off[3], 95)),
        "p95_latency_s_on": float(np.percentile(on[3], 95)),
        "speedup_mean": float(off[3].mean() / max(on[3].mean(), 1e-12)),
        "speedup_p95": float(np.percentile(off[3], 95)
                             / max(np.percentile(on[3], 95), 1e-12)),
        "throughput_qps_off": float(len(ranks) / off[1]),
        "throughput_qps_on": float(len(ranks) / on[1]),
        "answers_bit_identical": mismatch == 0,
        "mismatches": mismatch,
    }
    if trace_overhead is not None:
        summary["trace_overhead_mean"] = round(trace_overhead, 4)
    if trace_json:
        summary["trace_json"] = trace_json
    emit(f"serving_replay_s{scale}_zipf{zipf_s}", on[3].mean() * 1e6,
         f"hit_rate={summary['hit_rate']:.2f};"
         f"speedup_mean={summary['speedup_mean']:.1f}x;"
         f"p95_on_us={summary['p95_latency_s_on'] * 1e6:.0f};"
         f"qps_on={summary['throughput_qps_on']:.0f}")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(summary, fh, indent=2)
    print(json.dumps(summary))
    # raise (not sys.exit): benchmarks.run treats a raising suite as failed
    # and keeps going; main() below turns this into a nonzero exit code
    if mismatch:
        raise RuntimeError(
            f"{mismatch} cached answers differ from cache-off")
    if check_speedup and summary["speedup_mean"] < check_speedup:
        raise RuntimeError(
            f"mean-latency speedup {summary['speedup_mean']:.2f}x "
            f"< required {check_speedup:.1f}x")
    if check_trace_overhead and trace_overhead is not None \
            and trace_overhead > check_trace_overhead / 100.0:
        raise RuntimeError(
            f"tracing-enabled mean-latency overhead "
            f"{trace_overhead * 100:.1f}% > allowed "
            f"{check_trace_overhead:.1f}%")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration (nightly CI)")
    ap.add_argument("--scale", type=int, default=None, help="Kronecker scale")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--distinct", type=int, default=None)
    ap.add_argument("--zipf", type=float, default=1.0)
    ap.add_argument("--json", type=str, default=None,
                    help="also write the JSON summary to this path")
    ap.add_argument("--check-speedup", type=float, default=3.0,
                    help="exit nonzero below this mean-latency improvement "
                         "(0 disables)")
    ap.add_argument("--trace-json", type=str, default=None,
                    help="run one extra traced cache-on replay and write its "
                         "Chrome-trace/Perfetto JSON to this path")
    ap.add_argument("--check-trace-overhead", type=float, default=0.0,
                    help="exit nonzero if the traced replay's mean latency "
                         "exceeds the untraced one by more than this many "
                         "percent (0 disables; implies the traced replay)")
    args = ap.parse_args()
    kw = {}
    if args.smoke:
        kw.update(scale=10, total=1536, distinct=128, delta_every=256)
    if args.scale is not None:
        kw["scale"] = args.scale
    if args.requests is not None:
        kw["total"] = args.requests
    if args.distinct is not None:
        kw["distinct"] = args.distinct
    try:
        run(zipf_s=args.zipf, json_path=args.json,
            check_speedup=args.check_speedup, trace_json=args.trace_json,
            check_trace_overhead=args.check_trace_overhead, **kw)
    except RuntimeError as exc:
        print(f"# FAIL: {exc}")
        sys.exit(1)


if __name__ == "__main__":
    main()
