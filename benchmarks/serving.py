"""Serving-tier result cache benchmark: Zipf-skewed replay traffic.

Real serving traffic is heavily repeated and skewed, so the win after
device-resident deltas is not recomputing answers whose inputs did not
change. This suite replays the *same* Zipf(s)-ranked request stream —
similarity / membership / link-prediction / local-cluster / triangle-count
mix, with edge deltas interleaved at fixed positions — twice over freshly
built, identical sessions: once with the footprint-invalidated result cache
off, once on. It reports hit rate, mean and p95 per-request latency,
throughput, the cache's eviction breakdown, and (the point of the exercise)
the mean-latency improvement; it also asserts the two replays' answers are
bit-identical, because a cache that changes answers is not a cache.

A second, multi-tenant phase replays the same universe with requests
rotating through tenants carrying SLO deadlines (gold/silver/bronze),
synchronously and then with ``async_flush`` + a concurrent delta driver
thread. It reports per-tenant p50/p95/p99, deadline misses, and the
wall-clock overlap win snapshot-isolated serving buys; ``--check-p99``
gates the async tail against the synchronous one in nightly CI.

  PYTHONPATH=src python -m benchmarks.serving --smoke --json BENCH_serving.json

The last line printed is a machine-readable JSON summary (written to
``--json PATH`` as well, for the nightly-CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np

from repro.core import graph as G
from repro.obs import trace
from repro.stream import BatchedQueryServer, DynamicGraph, StreamSession

from .common import dress_rehearsal, emit

# request mix: pair scoring dominates real lookalike/recommendation traffic;
# tc is the rare whole-graph dashboard query that no delta lets survive
_KIND_WEIGHTS = (("similarity", 0.50), ("membership", 0.22),
                 ("linkpred", 0.15), ("localcluster", 0.10), ("tc", 0.03))

# multi-tenant mix: (name, SLO deadline in seconds) — gold is latency-
# sensitive, bronze is best-effort batch traffic with no deadline
_TENANTS = (("gold", 0.25), ("silver", 1.0), ("bronze", None))


def build_population(n: int, distinct: int, pairs_per_req: int, seed: int):
    """The distinct-request universe the Zipf ranks index into.

    Returns a list of ``(kind, payload)`` submit specs; rank 0 is the
    hottest request.
    """
    rng = np.random.default_rng(seed)
    kinds = rng.choice([k for k, _ in _KIND_WEIGHTS], size=distinct,
                       p=[w for _, w in _KIND_WEIGHTS])
    population = []
    for kind in kinds:
        if kind == "similarity":
            population.append((kind, {
                "pairs": rng.integers(0, n, size=(pairs_per_req, 2)
                                      ).astype(np.int32),
                "measure": str(rng.choice(["jaccard", "common", "overlap"]))}))
        elif kind == "membership":
            population.append((kind, {
                "u": int(rng.integers(0, n)),
                "candidates": rng.integers(0, n, size=16).astype(np.int32)}))
        elif kind == "linkpred":
            population.append((kind, {"u": int(rng.integers(0, n)),
                                      "top_k": 8}))
        elif kind == "localcluster":
            # eps 1e-2 keeps PPR supports local: the answer's footprint is
            # a neighborhood, not half the graph, so deltas elsewhere let
            # cached clusters survive (and the volume guard rarely trips)
            population.append((kind, {"seed": int(rng.integers(0, n)),
                                      "alpha": 0.15, "eps": 1e-2}))
        else:
            population.append(("tc", {}))
    return population


def zipf_ranks(distinct: int, s: float, total: int, seed: int) -> np.ndarray:
    """``total`` population ranks drawn from Zipf(s) over ``distinct`` items
    (s == 1.0 works, unlike numpy's own sampler)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, distinct + 1, dtype=np.float64) ** s
    return rng.choice(distinct, size=total, p=p / p.sum())


def _submit(server: BatchedQueryServer, kind: str, payload: dict,
            **submit_kw) -> int:
    if kind == "similarity":
        return server.submit_similarity(payload["pairs"], payload["measure"],
                                        **submit_kw)
    if kind == "membership":
        return server.submit_membership(payload["u"], payload["candidates"],
                                        **submit_kw)
    if kind == "linkpred":
        return server.submit_link_prediction(payload["u"], payload["top_k"],
                                             **submit_kw)
    if kind == "localcluster":
        return server.submit_local_cluster(payload["seed"], payload["alpha"],
                                           payload["eps"], **submit_kw)
    return server.submit_triangle_count(**submit_kw)


def _fresh_session(scale: int, edge_factor: int, budget: float, seed: int,
                   stream_frac: float):
    """Identical (graph, withheld delta stream) for every replay mode."""
    g = G.kronecker(scale, edge_factor, seed=seed)
    rng = np.random.default_rng(seed + 1)
    edges = np.asarray(g.edges)
    order = rng.permutation(edges.shape[0])
    split = int((1.0 - stream_frac) * edges.shape[0])
    st = StreamSession(DynamicGraph.from_edges(g.n, edges[order[:split]]),
                       kind="bf", storage_budget=budget)
    return st, edges[order[split:]]


def replay(st: StreamSession, arrivals: np.ndarray, population, ranks,
           use_cache: bool, delta_every: int, delta_edges: int,
           min_batch: int, flush_every: int):
    """Drive one request stream; returns (results_by_index, wall_s, server)."""
    server = BatchedQueryServer(st, min_batch=min_batch, cache=use_cache,
                                max_batch=flush_every)
    rid_to_idx = {}
    results = {}
    next_delta = 0
    t0 = time.perf_counter()
    for i, rank in enumerate(ranks):
        if delta_every and i % delta_every == 0 and arrivals.shape[0]:
            take = min(delta_edges, arrivals.shape[0])
            st.apply_delta(arrivals[next_delta:next_delta + take]
                           if next_delta + take <= arrivals.shape[0]
                           else arrivals[-take:])
            next_delta += take
        kind, payload = population[rank]
        rid_to_idx[_submit(server, kind, payload)] = i
        for rid, res in server.poll().items():
            results[rid_to_idx[rid]] = res
    for rid, res in server.flush().items():
        results[rid_to_idx[rid]] = res
    wall = time.perf_counter() - t0
    stats = server.stats()        # before close(), which drops the cache
    server.close()
    return results, wall, stats


def multi_tenant_replay(st: StreamSession, arrivals: np.ndarray, population,
                        ranks, async_mode: bool, delta_every: int,
                        delta_edges: int, min_batch: int, flush_every: int,
                        pace_s: float = 0.0005):
    """One multi-tenant pass over the Zipf stream with interleaved deltas.

    Requests rotate through :data:`_TENANTS` (tenant + SLO deadline on every
    submit). With ``async_mode`` the deltas run on a separate driver thread
    while the server's background worker flushes — the overlap the
    double-buffered serving views make safe; without it, deltas and flushes
    serialize on the submitting thread at the same stream positions.

    Submits follow an *open-loop* schedule (request ``i`` is released at
    ``t0 + i * pace_s``, never early, with no catch-up sleep when behind):
    latency is measured against an arrival process the server does not
    control, so a backlog shows up as tail latency instead of silently
    stretching the arrival times. Returns
    ``(results_by_rid, wall_s, server_stats, delta_ms_max)`` where the
    last is the largest inline ``apply_delta`` wall time (0.0 in async
    mode — the driver thread owns the deltas there).
    """
    server = BatchedQueryServer(st, min_batch=min_batch, cache=True,
                                max_batch=flush_every, max_wait_s=0.05,
                                async_flush=async_mode)
    chunks = []
    if delta_every:
        next_delta = 0
        for _ in range(len(ranks) // delta_every):
            take = min(delta_edges, arrivals.shape[0])
            chunks.append(arrivals[next_delta:next_delta + take]
                          if next_delta + take <= arrivals.shape[0]
                          else arrivals[-take:])
            next_delta += take
    stop = threading.Event()

    driver = None
    results = {}
    t0 = time.perf_counter()

    def _drive():
        # same stream positions as the sync replay: chunk ci lands where
        # request ci*delta_every sits on the arrival schedule — back-to-back
        # chunks would stack stalls the sync baseline never pays
        for ci, chunk in enumerate(chunks):
            if stop.is_set():
                break
            gap = t0 + ci * delta_every * pace_s - time.perf_counter()
            if gap > 0:
                time.sleep(gap)
            st.apply_delta(chunk)

    if async_mode and chunks:
        driver = threading.Thread(target=_drive, daemon=True)
        driver.start()
    ci = 0
    delta_times = []
    for i, rank in enumerate(ranks):
        if not async_mode and delta_every and i % delta_every == 0 \
                and ci < len(chunks):
            td = time.perf_counter()
            st.apply_delta(chunks[ci])
            delta_times.append(time.perf_counter() - td)
            ci += 1
        gap = t0 + i * pace_s - time.perf_counter()
        if gap > 0:
            time.sleep(gap)
        tenant, deadline = _TENANTS[i % len(_TENANTS)]
        kind, payload = population[rank]
        _submit(server, kind, payload, tenant=tenant, deadline_s=deadline)
        results.update(server.poll())
    results.update(server.flush())
    while len(results) < len(ranks):        # worker may still be flushing
        time.sleep(0.001)
        results.update(server.drain())
    wall = time.perf_counter() - t0
    stop.set()
    if driver is not None:
        driver.join()
    stats = server.stats()
    server.close()
    delta_ms = float(np.max(delta_times) * 1e3) if delta_times else 0.0
    return results, wall, stats, delta_ms


def _per_tenant(results) -> dict:
    """p50/p95/p99 latency + deadline misses, grouped by ``result.tenant``."""
    out = {}
    for tenant, _ in _TENANTS:
        lats = np.asarray([r.latency_s for r in results.values()
                           if r.tenant == tenant])
        if not lats.size:
            continue
        out[tenant] = {
            "requests": int(lats.size),
            "p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p95_ms": float(np.percentile(lats, 95) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "deadline_missed": int(sum(r.deadline_missed
                                       for r in results.values()
                                       if r.tenant == tenant)),
        }
    return out


def _values_equal(a, b) -> bool:
    if isinstance(a, dict):
        return set(a) == set(b) and all(_values_equal(a[k], b[k]) for k in a)
    if isinstance(a, np.ndarray):
        return bool(np.array_equal(a, np.asarray(b)))
    return a == b


def run(scale: int = 10, edge_factor: int = 8, distinct: int = 128,
        total: int = 2048, zipf_s: float = 1.0, delta_every: int = 256,
        delta_edges: int = 16, min_batch: int = 16, flush_every: int = 2,
        budget: float = 0.5, seed: int = 3, json_path=None,
        check_speedup: float = 0.0, trace_json=None,
        check_trace_overhead: float = 0.0, check_p99: float = 0.0) -> dict:
    """One full cache-off vs cache-on replay; returns the summary dict."""
    st0, _ = _fresh_session(scale, edge_factor, budget, seed, 0.2)
    n = st0.dyn.n
    population = build_population(n, distinct, pairs_per_req=16, seed=seed)
    ranks = zipf_ranks(distinct, zipf_s, total, seed + 7)

    modes = {}
    for timed in (False, True):
        # pass 0 is a full dress rehearsal: the two modes produce different
        # miss compositions, hence different pow2 batch shapes — replaying
        # the identical stream first pushes every remaining compile out of
        # the timed pass (XLA's in-process cache persists across sessions)
        for use_cache in (False, True):
            st, arrivals = _fresh_session(scale, edge_factor, budget, seed,
                                          0.2)

            def one_replay(st=st, arrivals=arrivals, use_cache=use_cache):
                return replay(st, arrivals, population, ranks, use_cache,
                              delta_every, delta_edges, min_batch,
                              flush_every)

            if not timed:
                dress_rehearsal(one_replay)
                continue
            results, wall, stats = one_replay()
            lat = np.asarray([results[i].latency_s
                              for i in range(len(ranks))])
            modes[use_cache] = (results, wall, stats, lat)

    off, on = modes[False], modes[True]

    # optional traced replay: one extra cache-on pass with span recording
    # enabled, to (a) export the nightly Perfetto artifact and (b) measure
    # the enabled-path tracing overhead against the untraced cache-on pass
    trace_overhead = None
    if trace_json or check_trace_overhead:
        was_enabled = trace.enabled()
        trace.enable()
        trace.clear()
        st, arrivals = _fresh_session(scale, edge_factor, budget, seed, 0.2)
        results_t, _, _ = replay(st, arrivals, population, ranks, True,
                                 delta_every, delta_edges, min_batch,
                                 flush_every)
        lat_t = np.asarray([results_t[i].latency_s
                            for i in range(len(ranks))])
        if trace_json:
            trace.export(trace_json)
        if not was_enabled:
            trace.disable()
        trace_overhead = float(lat_t.mean() / max(on[3].mean(), 1e-12) - 1.0)
    # multi-tenant phase: the same Zipf universe, requests rotating through
    # tenants with SLO deadlines, replayed sync (deltas inline on the
    # submitting thread) then async (delta driver thread + background flush
    # worker over snapshot-isolated views) — the wall-clock ratio is the
    # delta/query overlap win
    mt = {}
    for async_mode in (False, True):
        st, arrivals = _fresh_session(scale, edge_factor, budget, seed, 0.2)
        mt[async_mode] = multi_tenant_replay(
            st, arrivals, population, ranks, async_mode, delta_every,
            delta_edges, min_batch, flush_every)
    overlap_win = mt[False][1] / max(mt[True][1], 1e-12)

    mismatch = sum(
        not _values_equal(off[0][i].value, on[0][i].value)
        for i in range(len(ranks)))
    cache_stats = on[2]["cache"]
    summary = {
        "event": "serving_bench",
        "n": n, "distinct": distinct, "requests": int(len(ranks)),
        "zipf_s": zipf_s,
        "hit_rate": round(cache_stats["hit_rate"], 4),
        "evicted_footprint": cache_stats["evicted_footprint"],
        "evicted_whole": cache_stats["evicted_whole"],
        "evicted_guard": cache_stats["evicted_guard"],
        "mean_latency_s_off": float(off[3].mean()),
        "mean_latency_s_on": float(on[3].mean()),
        "p95_latency_s_off": float(np.percentile(off[3], 95)),
        "p95_latency_s_on": float(np.percentile(on[3], 95)),
        "speedup_mean": float(off[3].mean() / max(on[3].mean(), 1e-12)),
        "speedup_p95": float(np.percentile(off[3], 95)
                             / max(np.percentile(on[3], 95), 1e-12)),
        "throughput_qps_off": float(len(ranks) / off[1]),
        "throughput_qps_on": float(len(ranks) / on[1]),
        "answers_bit_identical": mismatch == 0,
        "mismatches": mismatch,
        "multi_tenant": {
            "tenants_sync": _per_tenant(mt[False][0]),
            "tenants_async": _per_tenant(mt[True][0]),
            "wall_s_sync": float(mt[False][1]),
            "wall_s_async": float(mt[True][1]),
            "overlap_win": float(overlap_win),
            "shed": mt[True][2].get("shed", 0),
            "delta_ms_max_sync": round(mt[False][3], 3),
        },
    }
    if trace_overhead is not None:
        summary["trace_overhead_mean"] = round(trace_overhead, 4)
    if trace_json:
        summary["trace_json"] = trace_json
    emit(f"serving_replay_s{scale}_zipf{zipf_s}", on[3].mean() * 1e6,
         f"hit_rate={summary['hit_rate']:.2f};"
         f"speedup_mean={summary['speedup_mean']:.1f}x;"
         f"p95_on_us={summary['p95_latency_s_on'] * 1e6:.0f};"
         f"qps_on={summary['throughput_qps_on']:.0f}")
    gold = summary["multi_tenant"]["tenants_async"].get("gold", {})
    emit(f"serving_multitenant_s{scale}", mt[True][1] * 1e6,
         f"overlap_win={overlap_win:.2f}x;"
         f"gold_p99_ms={gold.get('p99_ms', 0.0):.1f};"
         f"deadline_missed={gold.get('deadline_missed', 0)};"
         f"shed={summary['multi_tenant']['shed']}")
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(summary, fh, indent=2)
    print(json.dumps(summary))
    # raise (not sys.exit): benchmarks.run treats a raising suite as failed
    # and keeps going; main() below turns this into a nonzero exit code
    if mismatch:
        raise RuntimeError(
            f"{mismatch} cached answers differ from cache-off")
    if check_speedup and summary["speedup_mean"] < check_speedup:
        raise RuntimeError(
            f"mean-latency speedup {summary['speedup_mean']:.2f}x "
            f"< required {check_speedup:.1f}x")
    if check_trace_overhead and trace_overhead is not None \
            and trace_overhead > check_trace_overhead / 100.0:
        raise RuntimeError(
            f"tracing-enabled mean-latency overhead "
            f"{trace_overhead * 100:.1f}% > allowed "
            f"{check_trace_overhead:.1f}%")
    if check_p99:
        # async serving must not blow up the per-tenant tail. The sync
        # baseline applies deltas *between* submits, so its p99 excludes
        # delta time entirely, while an async request can legitimately land
        # behind one in-flight delta — the unit of acceptable async tail is
        # therefore one delta stall, and the bound's denominator is
        # max(sync p99, largest inline delta time, 1ms): the gate catches
        # the unbounded-backlog pathology (p99 ~ wall, every answer at the
        # final drain), not the inherent single-delta overlap
        delta_ms = summary["multi_tenant"]["delta_ms_max_sync"]
        for tenant, sync_row in \
                summary["multi_tenant"]["tenants_sync"].items():
            async_row = summary["multi_tenant"]["tenants_async"].get(tenant)
            if async_row is None:
                continue
            base = max(sync_row["p99_ms"], delta_ms, 1.0)
            bound = check_p99 * base
            if async_row["p99_ms"] > bound:
                raise RuntimeError(
                    f"tenant {tenant!r} async p99 {async_row['p99_ms']:.1f}ms"
                    f" > {check_p99:.1f}x max(sync p99, delta stall) "
                    f"{base:.1f}ms")
    return summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fast configuration (nightly CI)")
    ap.add_argument("--scale", type=int, default=None, help="Kronecker scale")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--distinct", type=int, default=None)
    ap.add_argument("--zipf", type=float, default=1.0)
    ap.add_argument("--json", type=str, default=None,
                    help="also write the JSON summary to this path")
    ap.add_argument("--check-speedup", type=float, default=3.0,
                    help="exit nonzero below this mean-latency improvement "
                         "(0 disables)")
    ap.add_argument("--trace-json", type=str, default=None,
                    help="run one extra traced cache-on replay and write its "
                         "Chrome-trace/Perfetto JSON to this path")
    ap.add_argument("--check-trace-overhead", type=float, default=0.0,
                    help="exit nonzero if the traced replay's mean latency "
                         "exceeds the untraced one by more than this many "
                         "percent (0 disables; implies the traced replay)")
    ap.add_argument("--check-p99", type=float, default=0.0,
                    help="exit nonzero if any tenant's async-serving p99 "
                         "latency exceeds this multiple of its synchronous "
                         "replay p99 (0 disables)")
    args = ap.parse_args()
    kw = {}
    if args.smoke:
        kw.update(scale=10, total=1536, distinct=128, delta_every=256)
    if args.scale is not None:
        kw["scale"] = args.scale
    if args.requests is not None:
        kw["total"] = args.requests
    if args.distinct is not None:
        kw["distinct"] = args.distinct
    try:
        run(zipf_s=args.zipf, json_path=args.json,
            check_speedup=args.check_speedup, trace_json=args.trace_json,
            check_trace_overhead=args.check_trace_overhead,
            check_p99=args.check_p99, **kw)
    except RuntimeError as exc:
        print(f"# FAIL: {exc}")
        sys.exit(1)


if __name__ == "__main__":
    main()
