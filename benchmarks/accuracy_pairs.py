"""Paper Fig. 3: accuracy of |N_u ∩ N_v| estimators across graphs.

For each graph we compute the relative error of every estimator on all
adjacent pairs and report median / p90 (the paper's boxplots), at the
paper's storage budget s=33% and b ∈ {1, 4}.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import graph as G, sketches as S
from repro.core.exact import exact_pair_cardinalities
from repro.core.intersect import make_pair_cardinality_fn

from .common import emit, timeit

GRAPHS = {
    # econ-beacxc-like density (n≈500, m≈50K, 40% fill): the paper's regime
    # where |N∩N| is large and estimators shine
    "econ_like": lambda: G.erdos_renyi(500, 0.4, seed=1),
    "er_sparse": lambda: G.erdos_renyi(800, 0.08, seed=1),
    "kron_s11": lambda: G.kronecker(11, 16, seed=2),
    "ba_power": lambda: G.barabasi_albert(1200, 8, seed=3),
    "community": lambda: G.random_bipartite_community(800, 6, 0.15, 0.003, seed=4),
}


def run(budget: float = 0.33):
    for gname, builder in GRAPHS.items():
        g = builder()
        pairs = g.edges
        exact = np.asarray(exact_pair_cardinalities(g, pairs)).astype(float)
        nz = exact > 0
        for kind, b, est_kw in [("bf", 1, {}), ("bf", 4, {}),
                                ("bf_l", 1, dict(estimator="bf_l")),
                                ("bf_or", 1, dict(estimator="bf_or")),
                                ("kh", 1, {}), ("1h", 1, {}), ("kmv", 1, {})]:
            base = kind if not kind.startswith("bf_") else "bf"
            sk = S.build(g, base, budget, num_hashes=b, seed=7)
            fn = jax.jit(make_pair_cardinality_fn(g, sk, **est_kw))
            us = timeit(fn, pairs, iters=3)
            est = np.asarray(fn(pairs)).astype(float)
            rel = np.abs(est[nz] - exact[nz]) / exact[nz]
            name = f"fig3_{gname}_{kind}_b{b}"
            emit(name, us,
                 f"median_rel={np.median(rel):.3f};p90_rel={np.quantile(rel,0.9):.3f}")


if __name__ == "__main__":
    run()
