"""Render the dry-run roofline results (experiments/dryrun/*.json) into the
EXPERIMENTS.md tables. `python -m benchmarks.roofline [--tag TAG]` prints
markdown; run.py emits one summary CSV row per cell.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if (r.get("tag") or "") != tag:
            continue
        rows.append(r)
    return rows


def markdown_table(rows, mesh: str) -> str:
    out = ["| arch | shape | mem/dev GB | compute s | memory s | collective s | "
           "bottleneck | useful | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['per_device_memory_gb']:.2f} | "
            f"{ro['compute_s']:.3f} | {ro['memory_s']:.3f} | "
            f"{ro['collective_s']:.3f} | {ro['bottleneck']} | "
            f"{ro['useful_ratio']:.2f} | {ro['peak_fraction']:.3f} |")
    return "\n".join(out)


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | compile s | HLO TFLOP/dev | coll GB/dev | "
           "mem/dev GB | fits 16GB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ro = r["roofline"]
        fits = "yes" if ro["per_device_memory_gb"] <= 16.0 else "NO"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compile_s']:.0f} | "
            f"{ro['hlo_flops_per_device'] / 1e12:.2f} | "
            f"{ro['link_bytes_per_device'] / 2**30:.2f} | "
            f"{ro['per_device_memory_gb']:.2f} | {fits} |")
    return "\n".join(out)


def run(tag: str = ""):
    rows = load(tag)
    for r in rows:
        ro = r["roofline"]
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             ro["compute_s"] * 1e6,
             f"bottleneck={ro['bottleneck']};frac={ro['peak_fraction']:.3f};"
             f"mem_gb={ro['per_device_memory_gb']:.1f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    ap.add_argument("--table", choices=["roofline", "dryrun"], default="roofline")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load(args.tag)
    if args.table == "roofline":
        print(markdown_table(rows, args.mesh))
    else:
        print(dryrun_table(rows))


if __name__ == "__main__":
    main()
