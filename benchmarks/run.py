"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see common.emit). Heavy
roofline cells come from the dry-run artifacts (benchmarks.roofline), not
recomputed here.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (accuracy_pairs, adaptive_bloom, algo_speedup, construction,
                   heuristics, kernels_bench, roofline, scaling, tc_estimators)
    suites = [
        ("kernels", kernels_bench.run),
        ("fig3_accuracy", accuracy_pairs.run),
        ("fig4-6_speedup", algo_speedup.run),
        ("table7_tc", tc_estimators.run),
        ("heuristics", heuristics.run),
        ("tableV_construction", construction.run),
        ("fig8_scaling", scaling.run),
        ("adaptive_bloom", adaptive_bloom.run),
        ("roofline", roofline.run),
    ]
    failed = []
    for name, fn in suites:
        print(f"# --- {name}", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
