"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see common.emit) and writes one
machine-diffable ``BENCH_<suite>.json`` per suite to ``--json-dir``: the
suite's schema-consistent records (``{"name", "wall_s", "metrics"}``) plus a
per-stage span breakdown aggregated from the observability tracer (delta
apply, sketch maintenance, cache, flush, kernel execute — see
docs/OBSERVABILITY.md). Heavy roofline cells come from the dry-run artifacts
(benchmarks.roofline), not recomputed here.

``--smoke`` runs the fast subset (kernel micro + engine suites) — the
nightly-CI sanity pass; ``--only NAME`` runs a single suite by name.

Run as a module so relative imports resolve:
  PYTHONPATH=src python -m benchmarks.run [--smoke | --only NAME]

The last line printed is a machine-readable ``bench_run`` JSON summary.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset only (nightly CI sanity pass)")
    ap.add_argument("--only", type=str, default=None,
                    help="run a single suite by name")
    ap.add_argument("--json-dir", type=str, default=".",
                    help="directory for the per-suite BENCH_<suite>.json")
    args = ap.parse_args()

    from repro.obs import trace

    from . import (accuracy_pairs, adaptive_bloom, algo_speedup, common,
                   construction, engine_bench, heuristics, kernels_bench,
                   localcluster, roofline, scaling, serving, setexpr_bench,
                   stream_bench, tc_estimators)
    suites = [
        ("kernels", kernels_bench.run),
        ("setexpr", setexpr_bench.run),
        ("engine", engine_bench.run),
        ("stream", stream_bench.run),
        ("localcluster", localcluster.run),
        ("serving", serving.run),
        ("fig3_accuracy", accuracy_pairs.run),
        ("fig4-6_speedup", algo_speedup.run),
        ("table7_tc", tc_estimators.run),
        ("heuristics", heuristics.run),
        ("tableV_construction", construction.run),
        ("fig8_scaling", scaling.run),
        ("adaptive_bloom", adaptive_bloom.run),
        ("roofline", roofline.run),
    ]
    smoke_suites = {"kernels", "setexpr", "engine", "stream", "localcluster"}
    if args.only is not None:
        suites = [s for s in suites if s[0] == args.only]
        if not suites:
            raise SystemExit(f"unknown suite {args.only!r}")
    elif args.smoke:
        suites = [s for s in suites if s[0] in smoke_suites]

    os.makedirs(args.json_dir, exist_ok=True)
    trace.enable()
    failed = []
    suite_rows = []
    for name, fn in suites:
        print(f"# --- {name}", flush=True)
        common.reset_records()
        trace.clear()
        t0 = time.perf_counter()
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
        wall = time.perf_counter() - t0
        doc = {"suite": name, "wall_s": round(wall, 3), "ok": name not in failed,
               "records": list(common.RECORDS), "spans": trace.aggregate()}
        path = os.path.join(args.json_dir, f"BENCH_{name}.json")
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=2)
        suite_rows.append({"suite": name, "wall_s": doc["wall_s"],
                           "ok": doc["ok"], "records": len(doc["records"]),
                           "json": path})
    trace.disable()
    if failed:
        print(f"# FAILED suites: {failed}")
    else:
        print("# all benchmark suites completed")
    print(json.dumps({"event": "bench_run", "failed": failed,
                      "suites": suite_rows}))
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
