"""Benchmark harness entry point: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (see common.emit). Heavy
roofline cells come from the dry-run artifacts (benchmarks.roofline), not
recomputed here.

``--smoke`` runs the fast subset (kernel micro + engine suites) — the
nightly-CI sanity pass; ``--only NAME`` runs a single suite by name.

Run as a module so relative imports resolve:
  PYTHONPATH=src python -m benchmarks.run [--smoke | --only NAME]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset only (nightly CI sanity pass)")
    ap.add_argument("--only", type=str, default=None,
                    help="run a single suite by name")
    args = ap.parse_args()

    from . import (accuracy_pairs, adaptive_bloom, algo_speedup, construction,
                   engine_bench, heuristics, kernels_bench, localcluster,
                   roofline, scaling, serving, setexpr_bench, stream_bench,
                   tc_estimators)
    suites = [
        ("kernels", kernels_bench.run),
        ("setexpr", setexpr_bench.run),
        ("engine", engine_bench.run),
        ("stream", stream_bench.run),
        ("localcluster", localcluster.run),
        ("serving", serving.run),
        ("fig3_accuracy", accuracy_pairs.run),
        ("fig4-6_speedup", algo_speedup.run),
        ("table7_tc", tc_estimators.run),
        ("heuristics", heuristics.run),
        ("tableV_construction", construction.run),
        ("fig8_scaling", scaling.run),
        ("adaptive_bloom", adaptive_bloom.run),
        ("roofline", roofline.run),
    ]
    smoke_suites = {"kernels", "setexpr", "engine", "stream", "localcluster"}
    if args.only is not None:
        suites = [s for s in suites if s[0] == args.only]
        if not suites:
            raise SystemExit(f"unknown suite {args.only!r}")
    elif args.smoke:
        suites = [s for s in suites if s[0] in smoke_suites]
    failed = []
    for name, fn in suites:
        print(f"# --- {name}", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}")
        sys.exit(1)
    print("# all benchmark suites completed")


if __name__ == "__main__":
    main()
