"""Batched mining engine benchmarks: session amortization + edge layout.

Measures (a) the multi-query session win — TC + LCC + clustering over ONE
shared sketch build and ONE per-edge cardinality pass vs three independent
runs — and (b) the degree-ordered edge layout's effect on the fold. Kernel
speed itself is a TPU number (CPU runs interpret mode); here we time the
XLA-compiled jnp paths that share the engine's op structure.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import engine as eng
from repro.analysis import live
from repro.core import graph as G, sketches as S
from repro.core import triangle_count, pair_similarity
from repro.core.algorithms.tc import local_clustering_coefficient
from .common import emit, timeit


def run(scale: int = 12, budget: float = 1.0):
    # budget 1.0 makes the per-edge pass the dominant cost, so the session's
    # pass-sharing is what the number measures (not Python dispatch)
    g = G.kronecker(scale, 12, seed=3)
    sk = S.build(g, "bf", budget, num_hashes=2, seed=0)
    jax.block_until_ready(sk.data)

    # independent runs: each query recomputes the per-edge cardinality pass
    def independent():
        a = triangle_count(g, sk)
        b = local_clustering_coefficient(g, sk)
        c = pair_similarity(g, g.edges, "jaccard", sk)
        return a, b, c

    us_indep = timeit(independent, iters=5)

    # session: one shared per-edge pass feeds all three queries
    def shared():
        sess = eng.session(g, sk)
        a = sess.triangle_count()
        b = sess.local_clustering()
        c = sess.edge_similarity("jaccard")
        return a, b, c

    us_sess = timeit(shared, iters=5)
    emit(f"engine_session_tc_lcc_sim_s{scale}", us_sess,
         f"independent_us={us_indep:.1f};amortization={us_indep / us_sess:.2f}x")

    # degree-ordered vs natural edge layout for the fold (jnp path); each
    # compiled fold also reports its achieved fraction of the HLO-cost
    # roofline bound (recorded as a gauge in the global metrics registry)
    for order in (False, True):
        plan = eng.EnginePlan(edge_chunk=16384, degree_order=order)
        fn = jax.jit(lambda: eng.sum_edge_cardinalities(g, sk, plan)
                     ).lower().compile()
        us = timeit(lambda: fn(), iters=3)
        rf = live.record_roofline(f"engine_fold_order{int(order)}", fn,
                                  us * 1e-6)
        emit(f"engine_fold_s{scale}_order{int(order)}", us,
             f"edges={g.m};roofline_frac={rf['fraction']:.3g}")

    # one-shot session wall time including sketch build (serving cold start)
    t0 = time.perf_counter()
    sess = eng.session(g, "bf", storage_budget=budget)
    jax.block_until_ready(sess.edge_cardinalities())
    emit(f"engine_cold_session_s{scale}", (time.perf_counter() - t0) * 1e6,
         f"sketch_mb={sess.stats()['sketch_bytes'] / 1e6:.2f}")


if __name__ == "__main__":
    run()
