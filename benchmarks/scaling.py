"""Paper Figs. 8–9 (scaling). The container has one core, so thread-count
strong scaling is not measurable; we report the two scaling axes we can:

  * work scaling: wall time vs edge count on Kronecker graphs (weak-scaling
    proxy; the paper grows m with threads). Exact galloping degrades with
    the d_max growth of power-law graphs while PG stays ~linear in m —
    the load-balance argument of Fig. 1 panel 5 in measurable form.
  * device scaling: shard_map mining on 1..8 fake host devices (launch.mine)
    is exercised in tests/test_system.py; on real hardware that path is the
    strong-scaling story.
"""
from __future__ import annotations

import functools

import jax

from repro.core import graph as G, sketches as S
from repro.core import exact as X
from repro.core import triangle_count

from .common import emit, timeit


def run():
    for scale in (10, 11, 12, 13):
        g = G.kronecker(scale, 16, seed=2)
        ex = jax.jit(X.exact_triangle_count)
        t_ex = timeit(ex, g, iters=2)
        sk = S.build(g, "bf", 0.25, num_hashes=2, seed=7)
        pg = jax.jit(triangle_count)
        t_pg = timeit(pg, g, sk, iters=2)
        emit(f"fig8_weak_s{scale}", t_pg,
             f"m={g.m};dmax={g.d_max};exact_us={t_ex:.0f};speedup={t_ex/t_pg:.2f}")


if __name__ == "__main__":
    run()
