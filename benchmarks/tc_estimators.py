"""Paper Table VII / Fig. 6 comparisons: PG TC estimators vs established
approximate-TC baselines — Doulion (edge sampling) and Colorful TC
(color-based sparsification) — at matched time/space budgets.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import graph as G, sketches as S
from repro.core import exact as X
from repro.core import triangle_count
from repro.core.hashing import np_hash_u32

from .common import emit, timeit


def doulion(g: G.Graph, p: float, seed: int = 0) -> float:
    """Tsourakakis et al.: keep each edge with prob p, count, scale 1/p^3."""
    rng = np.random.default_rng(seed)
    edges = np.asarray(g.edges)
    kept = edges[rng.random(len(edges)) < p]
    gs = G.from_edge_array(g.n, kept)
    return float(X.exact_triangle_count(gs)) / p**3


def colorful(g: G.Graph, colors: int, seed: int = 0) -> float:
    """Pagh–Tsourakakis: keep edges with same-colored endpoints; scale N²."""
    col = np_hash_u32(np.arange(g.n, dtype=np.uint32), seed) % colors
    edges = np.asarray(g.edges)
    kept = edges[col[edges[:, 0]] == col[edges[:, 1]]]
    gs = G.from_edge_array(g.n, kept)
    return float(X.exact_triangle_count(gs)) * colors**2


def run():
    g = G.kronecker(12, 16, seed=2)
    tc = float(X.exact_triangle_count(g))
    emit("table7_exact_tc", timeit(jax.jit(X.exact_triangle_count), g, iters=3),
         f"tc={tc:.0f}")

    for p in (0.25, 0.5):
        import time as _t
        t0 = _t.perf_counter()
        est = doulion(g, p)
        us = (_t.perf_counter() - t0) * 1e6
        emit(f"table7_doulion_p{p}", us, f"rel_err={abs(est-tc)/tc:.3f}")

    for c in (2, 4):
        import time as _t
        t0 = _t.perf_counter()
        est = colorful(g, c)
        us = (_t.perf_counter() - t0) * 1e6
        emit(f"table7_colorful_c{c}", us, f"rel_err={abs(est-tc)/tc:.3f}")

    for kind, b in [("bf", 2), ("kh", 1), ("1h", 1)]:
        sk = S.build(g, kind, 0.25, num_hashes=b, seed=7)
        fn = jax.jit(triangle_count)
        us = timeit(fn, g, sk, iters=3)
        emit(f"table7_pg_{kind}", us, f"rel_err={abs(float(fn(g, sk))-tc)/tc:.3f}")


if __name__ == "__main__":
    run()
