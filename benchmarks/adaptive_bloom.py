"""Beyond-paper: degree-adaptive (fold-compatible) Bloom filters vs the
paper's fixed-size filters at equal storage budget (core/adaptive.py).

Expected regime split (measured): adaptive wins where hub saturation breaks
BF-AND (dense skewed graphs — kron), is neutral-to-slightly-worse when the
budget is so small that low-degree collision noise dominates (ba at s=33%).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.core import graph as G, sketches as S
from repro.core import exact as X
from repro.core import triangle_count
from repro.core.adaptive import build_adaptive_bloom, adaptive_triangle_count
from repro.core.intersect import make_pair_cardinality_fn
from repro.core.adaptive import adaptive_pair_cardinalities
from repro.core.exact import exact_pair_cardinalities

from .common import emit, timeit


def run(budget: float = 0.33):
    for name, g in [("kron_s11", G.kronecker(11, 16, seed=2)),
                    ("econ_like", G.erdos_renyi(500, 0.4, seed=1))]:
        fixed = S.build(g, "bf", budget, num_hashes=1, seed=7)
        adap = build_adaptive_bloom(g, budget, num_hashes=1, seed=7)
        pairs = g.edges
        exact = np.asarray(exact_pair_cardinalities(g, pairs)).astype(float)
        nz = exact > 0
        ef = np.asarray(make_pair_cardinality_fn(g, fixed)(pairs))
        ea = np.asarray(adaptive_pair_cardinalities(adap, pairs))
        rf = np.median(np.abs(ef[nz] - exact[nz]) / exact[nz])
        ra = np.median(np.abs(ea[nz] - exact[nz]) / exact[nz])
        tc = float(X.exact_triangle_count(g))
        tf = abs(float(triangle_count(g, fixed)) - tc) / tc
        ta = abs(float(adaptive_triangle_count(g, adap)) - tc) / tc
        us = timeit(jax.jit(adaptive_pair_cardinalities), adap, pairs, iters=3)
        emit(f"adaptive_bf_{name}", us,
             f"median_fixed={rf:.3f};median_adaptive={ra:.3f};"
             f"tc_err_fixed={tf:.3f};tc_err_adaptive={ta:.3f}")


if __name__ == "__main__":
    run()
