"""Streaming subsystem benchmarks: incremental maintenance + batched serving.

Measures (a) the incremental win — absorbing an edge-delta batch through
per-row sketch merges + selective rebuild vs the full O(b·Σd_v) from-scratch
build a static pipeline would need, (b) host → device traffic per delta (the
device-resident contract: bytes scale with the delta, not with n·d_max+m)
and the before/after cost of the per-delta snapshot the device-resident
path eliminated, and (c) batched query-server throughput.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import graph as G, sketches as S
from repro.stream import BatchedQueryServer, DynamicGraph, StreamSession
from .common import dress_rehearsal, emit


def _time_deltas(st: StreamSession, batches) -> float:
    """Median seconds per applied delta batch (stateful, so no warm repeats)."""
    ts = []
    for ins, dels in batches:
        t0 = time.perf_counter()
        st.apply_delta(ins, dels)
        jax.block_until_ready(st.session.edge_cardinalities())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(scale: int = 11, budget: float = 0.5, batch_edges: int = 128):
    g = G.kronecker(scale, 8, seed=2)
    edges = np.asarray(g.edges)
    rng = np.random.default_rng(0)
    order = rng.permutation(edges.shape[0])
    # withhold 9 delta batches: batch 0 is the span-marked dress rehearsal
    # (compiles the delta path), batches 1-8 are the ones actually timed
    split = edges.shape[0] - 9 * batch_edges
    st = StreamSession(DynamicGraph.from_edges(g.n, edges[order[:split]]),
                       kind="bf", storage_budget=budget)
    jax.block_until_ready(st.session.edge_cardinalities())

    # from-scratch cost a static pipeline pays per delta: rebuild sketch +
    # full per-edge cardinality pass
    def full_rebuild():
        gs = st.dyn.snapshot()
        sk = S.build(gs, "bf", budget, num_hashes=2, seed=0)
        import repro.engine as eng
        return eng.edge_cardinalities(gs, sk, st.session.plan)

    dress_rehearsal(full_rebuild)
    t0 = time.perf_counter()
    jax.block_until_ready(full_rebuild())
    us_full = (time.perf_counter() - t0) * 1e6

    # deletes are drawn once without replacement and partitioned so batches
    # never target an already-deleted edge (a repeat would canonicalize to a
    # no-op and shrink the measured delta)
    cur = st.dyn.edge_array()
    n_del = batch_edges // 8
    del_idx = rng.choice(cur.shape[0], size=9 * n_del, replace=False)
    batches = []
    for b in range(9):
        ins = edges[order[split + b * batch_edges:
                          split + (b + 1) * batch_edges]]
        dels = cur[del_idx[b * n_del:(b + 1) * n_del]]
        batches.append((ins, dels))
    warm_ins, warm_dels = batches[0]
    dress_rehearsal(lambda: (st.apply_delta(warm_ins, warm_dels),
                             st.session.edge_cardinalities()))
    us_delta = _time_deltas(st, batches[1:]) * 1e6
    stats = st.stats()
    ms = stats["maintenance"]
    tr = stats["traffic"]
    emit(f"stream_delta_s{scale}_e{batch_edges}", us_delta,
         f"full_rebuild_us={us_full:.1f};speedup={us_full / us_delta:.2f}x;"
         f"rows_rebuilt={ms['rows_rebuilt']};incr={ms['rows_incremental']};"
         f"bytes_per_delta={tr['bytes_per_delta_mean']:.0f}")

    # the device-resident win itself: bytes a delta uploads vs what the
    # killed per-delta snapshot paid (the actual arrays a snapshot ships),
    # plus the wall-clock the old snapshot-per-delta path would add back
    t0 = time.perf_counter()
    for _ in range(4):
        snap = st.dyn.snapshot()
        jax.block_until_ready(snap.adj)
    us_snapshot = (time.perf_counter() - t0) / 4 * 1e6
    full_bytes = sum(
        np.asarray(getattr(snap, f)).nbytes
        for f in ("indptr", "indices", "adj", "deg", "edges"))
    emit(f"stream_traffic_s{scale}_e{batch_edges}",
         tr["bytes_per_delta_mean"],
         f"full_upload_bytes={full_bytes};"
         f"traffic_ratio={full_bytes / max(tr['bytes_per_delta_mean'], 1):.1f}x;"
         f"snapshot_us={us_snapshot:.1f};"
         f"delta_vs_old_snapshot={(us_delta + us_snapshot) / us_delta:.2f}x")

    # batched query serving throughput: flushes of 8 requests × 128 pairs;
    # one extra warm flush (same shapes) compiles ahead of the timed eight
    server = BatchedQueryServer(st)
    qpairs = rng.integers(0, g.n, size=(72, 128, 2)).astype(np.int32)

    def warm_flush():
        for q in qpairs[64:]:
            server.submit_similarity(q, "jaccard")
        return server.flush()

    dress_rehearsal(warm_flush)
    n_scores = 0
    dt = 0.0
    for fl in range(8):
        for q in qpairs[fl * 8:(fl + 1) * 8]:
            server.submit_similarity(q, "jaccard")
        t0 = time.perf_counter()
        served = server.flush()
        dt += time.perf_counter() - t0
        n_scores += sum(r.value.shape[0] for r in served.values())
    emit(f"stream_serve_s{scale}", dt / (8 * 8) * 1e6,
         f"pairs_per_s={n_scores / dt:.0f};"
         f"staleness={server.stats()['staleness_mean']:.2f}")


if __name__ == "__main__":
    run()
