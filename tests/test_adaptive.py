"""Degree-adaptive Bloom filters: folding identity + accuracy properties."""
import numpy as np
import jax.numpy as jnp

from repro.core import graph as G
from repro.core.adaptive import (AdaptiveBloom, _fold_to, build_adaptive_bloom,
                                 adaptive_pair_cardinalities, size_for_budget)
from repro.core.hashing import np_hash_u32


def test_folding_identity():
    """OR-folding h mod 2^a down to 2^b equals building with h mod 2^b."""
    rng = np.random.default_rng(0)
    elems = rng.integers(0, 10_000, size=60, dtype=np.uint32)
    for a_words, b_words in [(16, 4), (8, 8), (32, 2)]:
        big = np.zeros(a_words, np.uint32)
        small = np.zeros(b_words, np.uint32)
        for arr, w in [(big, a_words), (small, b_words)]:
            pos = np_hash_u32(elems, 3) % (w * 32)
            np.bitwise_or.at(arr, pos >> 5, np.uint32(1) << (pos & 31))
        folded = np.asarray(_fold_to(jnp.asarray(np.pad(big, (0, 32 - a_words))),
                                     jnp.int32(a_words), jnp.int32(b_words), 32))
        assert np.array_equal(folded[:b_words], small)


def test_budget_respected():
    g = G.kronecker(10, 16, seed=1)
    for s in (0.2, 0.4):
        words = size_for_budget(g, s)
        total_bits = int(words.sum()) * 32
        budget_bits = s * (2 * g.m + g.n + 1) * 32
        assert total_bits <= 1.6 * budget_bits
        assert np.all((words & (words - 1)) == 0), "power-of-two sizes"


def test_hub_filters_bigger():
    g = G.barabasi_albert(800, 6, seed=2)
    sk = build_adaptive_bloom(g, 0.33, num_hashes=1, seed=7)
    deg = np.asarray(g.deg)
    words = np.asarray(sk.words)
    hub, leaf = deg.argmax(), deg.argmin()
    assert words[hub] >= words[leaf]


def test_adaptive_beats_fixed_on_saturated_graph():
    from repro.core import sketches as S
    from repro.core.exact import exact_pair_cardinalities
    from repro.core.intersect import make_pair_cardinality_fn
    g = G.kronecker(10, 16, seed=2)
    fixed = S.build(g, "bf", 0.33, num_hashes=1, seed=7)
    adap = build_adaptive_bloom(g, 0.33, num_hashes=1, seed=7)
    pairs = g.edges
    exact = np.asarray(exact_pair_cardinalities(g, pairs)).astype(float)
    nz = exact > 0
    ef = np.asarray(make_pair_cardinality_fn(g, fixed)(pairs))
    ea = np.asarray(adaptive_pair_cardinalities(adap, pairs))
    rf = np.median(np.abs(ef[nz] - exact[nz]) / exact[nz])
    ra = np.median(np.abs(ea[nz] - exact[nz]) / exact[nz])
    assert ra < rf, (ra, rf)
