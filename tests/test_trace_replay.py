"""Acceptance: one traced ``launch.stream`` replay exports a Perfetto-loadable
Chrome trace whose span tree covers every hot seam.

Runs the real CLI ``main()`` in-process with ``--trace`` (and ``--metrics``),
parses the exported JSON, and asserts the trace-event schema plus the
required span names and their nesting — delta apply, sketch maintenance,
cache lookup/evict, batch flush, kernel execute — and that every
``server.flush`` span carries its cache/coalesce provenance (architecture
invariant 8).
"""
import json
import sys

import pytest

from repro.launch import stream as launch_stream
from repro.obs import trace

REQUIRED_SPANS = {
    # delta apply
    "stream.apply_delta", "graph.apply_delta", "graph.device_delta",
    # sketch maintenance
    "sketch.insert",
    # cache lookup / evict
    "cache.lookup", "cache.invalidate",
    # batch flush
    "server.flush",
    # kernel execute
    "engine.pair_cards",
}


@pytest.fixture(scope="module")
def replay(tmp_path_factory):
    """One tiny traced replay; returns (trace doc, summary dict)."""
    path = tmp_path_factory.mktemp("trace") / "out.json"
    argv = ["stream", "--scale", "8", "--batches", "2", "--queries", "8",
            "--seed", "1", "--trace", str(path), "--metrics"]
    old_argv, old_stdout = sys.argv, sys.stdout
    import io
    sys.argv = argv
    sys.stdout = io.StringIO()
    try:
        launch_stream.main()
        printed = sys.stdout.getvalue()
    finally:
        sys.argv = old_argv
        sys.stdout = old_stdout
        trace.disable()
        trace.clear()
    summary = json.loads(printed.strip().splitlines()[-1])
    return json.loads(path.read_text()), summary


def test_chrome_trace_schema(replay):
    doc, _ = replay
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert len(evs) > 0
    for ev in evs:
        assert ev["ph"] == "X" and ev["cat"] == "repro"
        assert isinstance(ev["name"], str)
        assert ev["dur"] >= 0.0 and ev["ts"] >= 0.0
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert "parent" in ev["args"] and "depth" in ev["args"]


def test_required_spans_cover_hot_seams(replay):
    doc, _ = replay
    names = {e["name"] for e in doc["traceEvents"]}
    missing = REQUIRED_SPANS - names
    assert not missing, f"replay trace missing spans: {sorted(missing)}"


def test_span_tree_nesting(replay):
    doc, _ = replay
    # expected (child -> parent) edges of the span tree; args carry the
    # recorded parent, so no timestamp-containment heuristics needed
    expected = {
        "graph.apply_delta": "stream.apply_delta",
        "graph.device_delta": "graph.apply_delta",
        "graph.scatter_rows": "graph.device_delta",
        "graph.splice_edges": "graph.device_delta",
        "sketch.insert": "stream.apply_delta",
        "engine.refresh": "stream.apply_delta",
        "cache.invalidate": "stream.apply_delta",
        "cache.lookup": "server.flush",
        "server.pair_batch": "server.flush",
        "engine.pair_cards": "server.pair_batch",
        "server.localcluster_batch": "server.flush",
    }
    for ev in doc["traceEvents"]:
        want = expected.get(ev["name"])
        if want is not None:
            assert ev["args"]["parent"] == want, ev["name"]
            assert ev["args"]["depth"] >= 1
    roots = [e for e in doc["traceEvents"]
             if e["name"] in ("stream.apply_delta", "server.flush")]
    assert roots and all(e["args"]["depth"] == 0 for e in roots)


def test_flush_spans_carry_provenance(replay):
    doc, _ = replay
    flushes = [e for e in doc["traceEvents"] if e["name"] == "server.flush"]
    assert len(flushes) >= 2                     # one per replayed batch
    for ev in flushes:
        args = ev["args"]
        assert args["requests"] == 5             # the per-batch query mix
        assert args["unique_keys"] + args["coalesced"] == args["requests"]
        assert 0 <= args["cache_hits"] <= args["unique_keys"]
        assert args["version"] >= 1
    batches = [e for e in doc["traceEvents"]
               if e["name"] in ("server.pair_batch",
                                "server.localcluster_batch")]
    assert batches
    for ev in batches:
        real = ev["args"].get("pairs", ev["args"].get("seeds"))
        assert ev["args"]["padded"] >= real > 0  # pad provenance


def test_deltas_carry_maintenance_attrs(replay):
    doc, _ = replay
    deltas = [e for e in doc["traceEvents"]
              if e["name"] == "stream.apply_delta"]
    assert len(deltas) == 2
    for ev in deltas:
        args = ev["args"]
        assert args["inserted"] > 0
        assert args["bytes_uploaded"] > 0
        assert args["cards_recomputed"] + args["cards_carried"] > 0


def test_summary_embeds_metrics_and_trace_path(replay):
    doc, summary = replay
    assert summary["event"] == "stream_replay"
    assert summary["trace"].endswith("out.json")
    snaps = summary["metrics"]
    assert set(snaps) == {"global", "stream", "server"}
    assert snaps["server"]["server_flushes_total"] == 2
    assert snaps["stream"]["traffic_steps"] == 2
    assert snaps["stream"]["sketch_fill_ratio{kind=bf}"] > 0.0
    assert snaps["server"]["accuracy_err_rmse{kind=bf}"] > 0.0
    assert any(k.startswith("setexpr_compile_total") for k in snaps["global"])
