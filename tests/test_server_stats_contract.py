"""server.stats() field semantics, asserted through the MetricsRegistry view.

The stats dict is a *view* over ``server.metrics`` instruments; these tests
pin the contract of each field — per-kind pad_overhead, the coalesced
counter, latency/staleness percentile omission until something was served —
and that every number agrees with the backing registry instrument.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.stream import BatchedQueryServer, DynamicGraph, StreamSession


@pytest.fixture(scope="module")
def session():
    g = G.kronecker(7, 8, seed=5)
    return StreamSession(DynamicGraph.from_edges(g.n, np.asarray(g.edges)),
                         kind="bf", storage_budget=0.5)


def _pairs(n, k, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, n, size=(k, 2)).astype(np.int32)


def test_percentiles_omitted_until_served(session):
    srv = BatchedQueryServer(session, cache=False)
    s0 = srv.stats()
    assert s0["served"] == 0 and s0["flushes"] == 0
    for key in ("latency_mean_s", "latency_p95_s", "staleness_mean"):
        assert key not in s0
    srv.submit_similarity(_pairs(session.dyn.n, 4), "jaccard")
    srv.flush()
    s1 = srv.stats()
    assert s1["served"] == 1 and s1["flushes"] == 1
    assert s1["latency_mean_s"] > 0.0
    assert s1["latency_p95_s"] >= 0.0
    assert s1["staleness_mean"] == 0.0
    # ...and each comes from the registry histogram's raw window
    lat = srv.metrics.histogram("server_latency_s").values()
    assert s1["latency_mean_s"] == float(lat.mean())
    assert s1["latency_p95_s"] == float(np.percentile(lat, 95))


def test_pad_overhead_per_kind_from_registry(session):
    srv = BatchedQueryServer(session, cache=False)
    n = session.dyn.n
    srv.submit_similarity(_pairs(n, 3), "jaccard")     # pairs path
    srv.submit_membership(1, np.arange(5, dtype=np.int32))  # membership path
    srv.submit_local_cluster(2, alpha=0.15, eps=1e-2)  # localcluster path
    srv.flush()
    st = srv.stats()
    assert set(st["pad_overhead"]) == {"pairs", "membership", "localcluster"}
    for name, (real, padded) in srv._pad.items():
        # registry counters mirror the per-path [real, padded] tallies
        assert srv.metrics.value("server_pad_rows", path=name,
                                 rows="real") == real
        assert srv.metrics.value("server_pad_rows", path=name,
                                 rows="padded") == padded
        expect = padded / real - 1.0 if real else 0.0
        assert st["pad_overhead"][name] == pytest.approx(expect)
    # real rows ran: padding can only add, never shrink
    assert srv._pad["pairs"][1] >= srv._pad["pairs"][0] > 0
    assert srv._pad["localcluster"][1] >= srv._pad["localcluster"][0] == 1
    assert st["pad_overhead"]["localcluster"] > 0.0   # pow2-padded singleton


def test_coalesced_counter_counts_deduped_requests(session):
    srv = BatchedQueryServer(session, cache=False)
    p = _pairs(session.dyn.n, 4, seed=3)
    r1 = srv.submit_similarity(p, "jaccard")
    r2 = srv.submit_similarity(p, "jaccard")          # identical -> coalesces
    r3 = srv.submit_triangle_count()
    out = srv.flush()
    st = srv.stats()
    assert st["served"] == 3                          # every request answered
    assert st["coalesced"] == 1                       # but one key deduped
    assert st["coalesced"] == srv.metrics.value("server_coalesced_total")
    np.testing.assert_array_equal(np.asarray(out[r1].value),
                                  np.asarray(out[r2].value))
    assert out[r3].value > 0


def test_by_kind_and_counters_are_registry_views(session):
    srv = BatchedQueryServer(session, cache=False)
    n = session.dyn.n
    srv.submit_similarity(_pairs(n, 4), "jaccard")
    srv.submit_membership(0, np.arange(4, dtype=np.int32))
    srv.submit_link_prediction(1, top_k=2)
    srv.flush()
    srv.submit_triangle_count()
    srv.flush()
    st = srv.stats()
    assert st["by_kind"] == {"similarity": 1, "membership": 1,
                             "linkpred": 1, "tc": 1}
    assert sum(st["by_kind"].values()) == st["served"] == 4
    assert st["flushes"] == 2
    # the same numbers straight from the instruments the view reads
    assert st["served"] == srv.metrics.value("server_served_total")
    assert st["flushes"] == srv.metrics.value("server_flushes_total")
    for kind, count in st["by_kind"].items():
        assert srv.metrics.value("server_served_total", kind=kind) == count
    # servers own their registries: a fresh one starts from zero
    assert BatchedQueryServer(session, cache=False).stats()["served"] == 0
