"""Serving-tier result cache: bit-identical hits across randomized
delta/query interleavings (all sketch kinds + exact), delta-precise
footprint eviction, request coalescing, the flush-time link-prediction
candidate fix, per-kind server stats, and the admission/auto-flush policy."""
import time

import numpy as np
import pytest

from repro.core import graph as G
from repro.engine import Footprint
from repro.stream import (BatchedQueryServer, ErrorBudgetPolicy,
                          stream_session)

KINDS = ("bf", "kh", "1h", "kmv", None)
SKETCH_KW = dict(words=4, k=6, num_hashes=2, seed=3)


def _kw(kind):
    return dict(SKETCH_KW, policy=ErrorBudgetPolicy(0.0)) if kind else {}


def _assert_value_equal(a, b, msg=""):
    if isinstance(a, dict):
        assert set(a) == set(b), msg
        for k in a:
            _assert_value_equal(a[k], b[k], f"{msg}[{k}]")
    elif isinstance(a, np.ndarray):
        np.testing.assert_array_equal(a, np.asarray(b), msg)
    else:
        assert a == b, msg


def _pair_graph():
    """A small fixed graph whose footprints are known exactly."""
    edges = np.array([[0, 1], [1, 2], [2, 3], [3, 4], [4, 5], [5, 6],
                      [6, 0], [10, 11], [11, 12], [12, 13], [2, 14]])
    return G.from_edge_array(20, edges)


# ---------------------------------------------------------------------------
# footprint metadata
# ---------------------------------------------------------------------------

def test_footprint_of_union_and_intersection():
    fp = Footprint.of(np.array([[3, 1], [7, 3]]), 9, None)
    np.testing.assert_array_equal(fp.vertices, [1, 3, 7, 9])
    assert fp.intersects([7]) and not fp.intersects([2, 8])
    assert not fp.is_whole_graph
    whole = Footprint.whole_graph()
    assert whole.is_whole_graph and whole.intersects([0])
    assert not Footprint.of().intersects([0])


def test_localcluster_result_carries_residual_footprint():
    g = G.kronecker(7, 6, seed=2)
    st = stream_session(g, "bf", storage_budget=0.5)
    res = st.local_cluster(np.array([5], np.int32), alpha=0.15, eps=1e-2)
    fp = res.footprint(0)
    assert fp.size >= 1 and 5 in fp              # the seed always holds mass
    p = np.asarray(res.ppr[0])
    r = np.asarray(res.residual[0])
    np.testing.assert_array_equal(fp, np.nonzero((p > 0) | (r > 0))[0])


# ---------------------------------------------------------------------------
# property: cache-hit answers ≡ cache-off answers under interleaved deltas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS)
def test_cached_answers_bit_identical_across_interleavings(kind):
    """Randomized delta/query interleavings: every answer from the cached
    server (second submission of a key is a guaranteed hit when no delta
    intervened; earlier rounds exercise eviction) equals the cache-off
    server's answer, bit for bit, for every sketch kind and exact."""
    rng = np.random.default_rng(11 if kind is None else hash(kind) % 997)
    g = G.erdos_renyi(72, 0.08, seed=5)
    st = stream_session(g, kind, **_kw(kind))
    cached = BatchedQueryServer(st, min_batch=8)
    plain = BatchedQueryServer(st, min_batch=8, cache=False)

    population = (
        [("sim", rng.integers(0, g.n, size=(4, 2)).astype(np.int32),
          m) for m in ("jaccard", "common", "overlap")]
        + [("mem", int(rng.integers(0, g.n)),
            rng.integers(0, g.n, size=8).astype(np.int32)) for _ in range(2)]
        + [("lp", int(rng.integers(0, g.n))) for _ in range(2)]
        + [("lc", int(rng.integers(0, g.n))) for _ in range(2)]
        + [("tc",)])

    def submit(server, item):
        if item[0] == "sim":
            return server.submit_similarity(item[1], item[2])
        if item[0] == "mem":
            return server.submit_membership(item[1], item[2])
        if item[0] == "lp":
            return server.submit_link_prediction(item[1], top_k=5)
        if item[0] == "lc":
            return server.submit_local_cluster(item[1], 0.15, 1e-2)
        return server.submit_triangle_count()

    for _ in range(3):
        ins = rng.integers(0, g.n, size=(int(rng.integers(2, 10)), 2))
        cur = st.dyn.edge_array()
        dels = cur[rng.choice(cur.shape[0], size=3, replace=False)]
        st.apply_delta(ins, dels)
        # two flushes per round: the second submission of every key is a
        # guaranteed cache hit (no delta in between)
        for _ in range(2):
            sample = [population[i] for i in
                      rng.choice(len(population), size=6)]
            rids = [(submit(cached, it), submit(plain, it)) for it in sample]
            out_c, out_p = cached.flush(), plain.flush()
            for (rc, rp), it in zip(rids, sample):
                _assert_value_equal(out_c[rc].value, out_p[rp].value,
                                    f"{kind}:{it[0]}")
    assert cached.cache.hits > 0
    assert plain.cache is None


# ---------------------------------------------------------------------------
# delta-precise eviction (the footprint invariant, via stats counters)
# ---------------------------------------------------------------------------

def test_delta_evicts_exactly_footprint_intersecting_entries():
    st = stream_session(_pair_graph(), "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8)
    srv.submit_similarity(np.array([[0, 1]]), "jaccard")    # fp {0, 1}
    srv.submit_similarity(np.array([[3, 4]]), "jaccard")    # fp {3, 4}
    srv.submit_membership(5, np.array([10, 11]))            # fp {5}
    srv.submit_triangle_count()                             # whole graph
    srv.flush()
    assert len(srv.cache) == 4 and srv.cache.inserts == 4

    st.apply_delta([[0, 10]])          # touches exactly {0, 10}
    # evicted: sim(0,1) (footprint hit) + tc (whole graph); nothing else
    assert srv.cache.evicted_footprint == 1
    assert srv.cache.evicted_whole == 1
    assert len(srv.cache) == 2

    # survivors serve as hits and still equal a live recomputation
    r34 = srv.submit_similarity(np.array([[3, 4]]), "jaccard")
    rm = srv.submit_membership(5, np.array([10, 11]))
    out = srv.flush()
    assert srv.cache.hits == 2
    np.testing.assert_array_equal(
        out[r34].value, np.asarray(st.similarity(np.array([[3, 4]]),
                                                 "jaccard")))
    np.testing.assert_array_equal(
        out[rm].value, np.asarray(st.membership(5, np.array([10, 11]))))


def test_lazy_policy_flush_rebuild_evicts_dependent_entries():
    """A deferred-rebuild flush changes sketch rows without a delta: the
    session must publish the rebuilt set so dependent entries die too."""
    g = G.erdos_renyi(60, 0.12, seed=7)
    st = stream_session(g, "bf", policy=ErrorBudgetPolicy(rel_tolerance=50.0),
                        **SKETCH_KW)
    srv = BatchedQueryServer(st, min_batch=8)
    edge = st.dyn.edge_array()[0]
    st.apply_delta(None, [edge])                 # rows go dirty, deferred
    a = int(edge[0])
    rid = srv.submit_membership(a, np.arange(8))
    stale_val = srv.flush()[rid].value           # cached against stale row
    assert ("membership", a, 8, np.arange(8, dtype=np.int32).tobytes()) \
        in srv.cache
    before = srv.cache.evicted_footprint
    rebuilt = st.flush()                         # rebuild replaces row a
    assert rebuilt > 0
    assert srv.cache.evicted_footprint > before
    rid2 = srv.submit_membership(a, np.arange(8))
    fresh = srv.flush()[rid2].value              # recomputed, not served stale
    np.testing.assert_array_equal(
        fresh, np.asarray(st.membership(a, np.arange(8))))
    assert stale_val is not fresh


def test_capacity_eviction_cleans_the_vertex_index():
    """LRU eviction must unindex the dead key: a leaked index entry would
    re-count phantom evictions and kill re-inserted keys via footprints
    they no longer have."""
    from repro.stream import ResultCache
    c = ResultCache(capacity=2)
    c.put(("a",), 1, Footprint.of(1), 0)
    c.put(("b",), 2, Footprint.of(2), 0)
    c.put(("c",), 3, Footprint.of(3), 0)          # LRU-evicts ("a",)
    assert c.evicted_capacity == 1 and len(c) == 2
    assert c.invalidate([1]) == 0                 # dead key: not re-counted
    assert c.evicted_footprint == 0
    c.put(("a",), 4, Footprint.of(7), 1)          # back, different footprint
    c.invalidate([1])                             # old footprint: must miss
    assert ("a",) in c and c.evicted_footprint == 0
    c.invalidate([7])
    assert ("a",) not in c and c.evicted_footprint == 1


def test_dropped_server_unsubscribes_from_delta_feed():
    import gc
    st = stream_session(G.erdos_renyi(60, 0.1, seed=2), "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8)
    assert len(st._delta_listeners) == 1
    del srv
    gc.collect()
    st.apply_delta([[0, 1], [2, 3]])          # publish prunes the dead ref
    assert len(st._delta_listeners) == 0
    # close() detaches an alive server immediately AND drops its cache —
    # without the feed, cached entries could silently go stale
    srv2 = BatchedQueryServer(st, min_batch=8)
    rid = srv2.submit_triangle_count()
    srv2.close()                       # flush-then-detach: rid is answered
    assert len(st._delta_listeners) == 0 and srv2.cache is None
    assert rid in srv2.drain()
    with pytest.raises(RuntimeError):  # a closed server rejects new work
        srv2.submit_triangle_count()


def test_oversized_localcluster_is_not_cached():
    # eps 1e-4 on a small dense graph sweeps more than half the volume: the
    # conductance then reads min(vol, 2m - vol) on the far side, which any
    # delta shifts — such answers are not cacheable and must recompute
    g = G.erdos_renyi(50, 0.15, seed=3)
    st = stream_session(g, "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8)
    rid = srv.submit_local_cluster(7, alpha=0.15, eps=1e-4)
    out = srv.flush()
    key = ("localcluster", 7, 0.15, 1e-4)
    if key in srv.cache:          # cacheable only if the cluster stayed small
        entry = srv.cache.get(key, 2.0 * st.dyn.m)
        assert entry.max2vol <= entry.vol_total
    else:
        rid2 = srv.submit_local_cluster(7, alpha=0.15, eps=1e-4)
        out2 = srv.flush()
        _assert_value_equal(out2[rid2].value, out[rid].value)


# ---------------------------------------------------------------------------
# coalescing: identical requests compute once, fan out per request id
# ---------------------------------------------------------------------------

def test_identical_requests_coalesce_in_one_flush():
    g = G.erdos_renyi(60, 0.1, seed=2)
    st = stream_session(g, "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8)
    pairs = np.array([[1, 2], [3, 4], [5, 6]], np.int32)
    ra = srv.submit_similarity(pairs, "jaccard")
    rb = srv.submit_similarity(pairs, "jaccard")
    rc1 = srv.submit_local_cluster(7, 0.15, 1e-2)
    rc2 = srv.submit_local_cluster(7, 0.15, 1e-2)
    rc3 = srv.submit_local_cluster(9, 0.15, 1e-2)
    out = srv.flush()
    stats = srv.stats()
    assert stats["coalesced"] == 2               # one sim + one lc duplicate
    # the shared pair pass saw the pairs block once, the seed batch two
    # unique seeds — duplicates dedup *before* padding
    assert srv._pad["pairs"][0] == 3
    assert srv._pad["localcluster"][0] == 2
    assert out[ra].value is out[rb].value        # fanned out, one compute
    _assert_value_equal(out[rc1].value, out[rc2].value)
    assert out[rc3].value["size"] >= 0
    assert srv.cache.inserts <= 4                # one entry per unique key


# ---------------------------------------------------------------------------
# link prediction: candidates materialize at flush, not submit
# ---------------------------------------------------------------------------

def test_linkpred_candidates_reflect_deltas_between_submit_and_flush():
    # path graph: N(0) = {1, 3}; distance-2 candidates of 0 are {2, 4}
    edges = np.array([[0, 1], [1, 2], [0, 3], [3, 4]])
    st = stream_session(G.from_edge_array(8, edges), "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8)
    rid = srv.submit_link_prediction(0, top_k=4)
    # interleaved delta: 2 becomes a neighbor of 0 (no longer a candidate),
    # 5 attaches to neighbor 1 (a brand-new candidate)
    st.apply_delta([[0, 2], [5, 1]])
    res = srv.flush()[rid]
    got = set(int(c) for c in res.value["candidates"])
    assert 2 not in got and 5 in got and 4 in got
    assert res.staleness == 1
    # bit-identical to a fresh cache-off submission at the same version
    ref_srv = BatchedQueryServer(st, min_batch=8, cache=False)
    ref_rid = ref_srv.submit_link_prediction(0, top_k=4)
    ref = ref_srv.flush()[ref_rid]
    _assert_value_equal(res.value, ref.value)


# ---------------------------------------------------------------------------
# satellite: per-kind stats, no seeded percentiles
# ---------------------------------------------------------------------------

def test_stats_omit_percentiles_until_served_and_split_pads():
    st = stream_session(G.erdos_renyi(60, 0.1, seed=2), "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8)
    stats = srv.stats()
    assert stats["served"] == 0
    assert "latency_p95_s" not in stats and "latency_mean_s" not in stats
    assert "staleness_mean" not in stats
    assert set(stats["pad_overhead"]) == {"pairs", "membership",
                                          "localcluster"}
    assert stats["pad_overhead"]["pairs"] == 0.0

    srv.submit_similarity(np.array([[1, 2]] * 3), "jaccard")
    srv.submit_membership(4, np.arange(5))
    srv.submit_local_cluster(3, 0.15, 1e-2)
    srv.flush()
    stats = srv.stats()
    assert stats["served"] == 3
    assert stats["by_kind"] == {"similarity": 1, "membership": 1,
                                "localcluster": 1}
    assert stats["latency_p95_s"] > 0.0
    # per-path padding: 3 pair rows -> 8-bucket, 5 membership rows ->
    # 8-bucket, 1 seed -> 8-bucket; nothing lumped together
    assert stats["pad_overhead"]["pairs"] == pytest.approx(8 / 3 - 1)
    assert stats["pad_overhead"]["membership"] == pytest.approx(8 / 5 - 1)
    assert stats["pad_overhead"]["localcluster"] == pytest.approx(8 / 1 - 1)
    assert stats["cache"]["inserts"] == 3


# ---------------------------------------------------------------------------
# admission policy: max_batch auto-flush + max_wait_s poll
# ---------------------------------------------------------------------------

def test_max_batch_auto_flushes_on_admission():
    st = stream_session(G.erdos_renyi(60, 0.1, seed=2), "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8, max_batch=2)
    r1 = srv.submit_triangle_count()
    assert srv.pending_count() == 1
    r2 = srv.submit_membership(3, np.arange(4))   # hits max_batch: flushes
    assert srv.pending_count() == 0
    out = srv.drain()
    assert set(out) == {r1, r2}
    assert srv.flush() == {}                      # nothing left undelivered


def test_poll_flushes_after_max_wait():
    st = stream_session(G.erdos_renyi(60, 0.1, seed=2), "bf", **_kw("bf"))
    srv = BatchedQueryServer(st, min_batch=8, max_wait_s=0.01)
    rid = srv.submit_triangle_count()
    assert srv.poll() == {} or srv.pending_count() == 0   # may not be due yet
    time.sleep(0.02)
    out = srv.poll()
    assert rid in out and srv.pending_count() == 0
    # without pressure nothing flushes early
    srv2 = BatchedQueryServer(st, min_batch=8, max_wait_s=30.0, max_batch=99)
    srv2.submit_triangle_count()
    assert srv2.poll() == {} and srv2.pending_count() == 1


# ---------------------------------------------------------------------------
# checkpoint: localcluster answers and footprints survive save/restore
# ---------------------------------------------------------------------------

def test_localcluster_footprint_survives_checkpoint_restore(tmp_path):
    from repro.stream import StreamSession
    g = G.kronecker(7, 6, seed=2)
    st = stream_session(g, "bf", storage_budget=0.5)
    srv = BatchedQueryServer(st, min_batch=8)
    rid = srv.submit_local_cluster(5, alpha=0.15, eps=1e-2)
    out = srv.flush()
    res = st.local_cluster(np.array([5], np.int32), alpha=0.15, eps=1e-2)
    fp = res.footprint(0)
    st.save(str(tmp_path))

    st2 = StreamSession.restore(str(tmp_path))
    # the restored session recomputes the same answer AND the same
    # dependency set — the serving cache's invalidation unit round-trips
    res2 = st2.local_cluster(np.array([5], np.int32), alpha=0.15, eps=1e-2)
    np.testing.assert_array_equal(res2.footprint(0), fp)
    srv2 = BatchedQueryServer(st2, min_batch=8)
    rid2 = srv2.submit_local_cluster(5, alpha=0.15, eps=1e-2)
    out2 = srv2.flush()
    _assert_value_equal(out2[rid2].value, out[rid].value)

    # and the restored footprint still steers eviction correctly
    key = ("localcluster", 5, 0.15, 1e-2)
    if key in srv2.cache:
        inside = int(fp[0])
        outside = [v for v in range(st2.dyn.n)
                   if v not in set(fp.tolist())][:2]
        if len(outside) == 2:
            st2.apply_delta([outside])               # misses the footprint
            assert key in srv2.cache
        st2.apply_delta([[inside, outside[0] if outside else inside + 1]])
        assert key not in srv2.cache                 # footprint hit evicts


# ---------------------------------------------------------------------------
# stale-put guard: a localcluster put that crossed a delta is rejected
# ---------------------------------------------------------------------------

def test_stale_put_guard_rejects_localcluster_entry_crossing_delta():
    from repro.stream import ResultCache
    g = G.kronecker(7, 6, seed=2)
    st = stream_session(g, "bf", storage_budget=0.5)
    res = st.local_cluster(np.array([5], np.int32), alpha=0.15, eps=1e-2)
    fp = res.footprint(0)
    key = ("localcluster", 5, 0.15, 1e-2)
    c = ResultCache()
    # a delta lands (epoch 1) on a support vertex while the answer computed
    # from the epoch-0 view was still in flight: the late put must lose
    assert c.invalidate([int(fp[-1])], epoch=1) == 0
    c.put(key, {"size": 1}, Footprint.of(fp), version=0, epoch=0)
    assert key not in c and c.rejected_stale == 1
    # same race, but the delta missed the support: the put is admitted
    # (fresh cache — the intersecting epoch-1 entry above must stay fatal
    # in its own log for as long as it is retained)
    c.put(key, {"size": 1}, Footprint.of(fp), version=0, epoch=0)
    assert c.rejected_stale == 2
    outside = next(v for v in range(g.n) if v not in set(fp.tolist()))
    cm = ResultCache()
    cm.invalidate([outside], epoch=2)
    cm.put(key, {"size": 1}, Footprint.of(fp), version=0, epoch=0)
    assert key in cm and cm.rejected_stale == 0
    # an answer computed AFTER the delta's publish epoch is admitted even
    # when its support intersects the delta
    c2 = ResultCache()
    c2.invalidate([int(fp[-1])], epoch=1)
    c2.put(key, {"size": 1}, Footprint.of(fp), version=1, epoch=1)
    assert key in c2 and c2.rejected_stale == 0
