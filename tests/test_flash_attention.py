"""Pallas flash-attention kernel vs the plain-softmax oracle (shape sweep)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


@pytest.mark.parametrize(
    "b,s,h,kv,d,window,bq,bkv",
    [
        (2, 64, 4, 2, 16, 0, 16, 16),      # GQA
        (1, 128, 8, 1, 32, 0, 32, 64),     # MQA
        (2, 64, 4, 4, 16, 24, 16, 8),      # MHA + sliding window
        (1, 96, 6, 2, 8, 0, 48, 32),       # non-square blocks
        (1, 32, 2, 2, 64, 8, 32, 16),      # window < block
    ])
def test_flash_matches_oracle(b, s, h, kv, d, window, bq, bkv, rng):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)).astype(np.float32))
    out = flash_attention(q, k, v, window=window, block_q=bq, block_kv=bkv,
                          interpret=True)
    exp = ref.causal_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_model_attention(rng):
    """Also agrees with the model's scan-based chunked attention."""
    from repro.models.layers import chunked_causal_attention
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)).astype(np.float32))
    a = flash_attention(q, k, v, block_q=32, block_kv=32, interpret=True)
    b_ = chunked_causal_attention(q, k, v, chunk_q=32, chunk_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 16))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 16))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 16))).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=32, block_kv=32, interpret=True)
    exp = ref.causal_attention(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)
