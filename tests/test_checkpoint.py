"""Checkpoint store: atomic publish, GC, async, restore-into-structure."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (save_checkpoint, restore_checkpoint, latest_step,
                              AsyncCheckpointer)


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "blocks": [{"a": jnp.ones(5)}, {"a": jnp.zeros(2)}]},
            "step": jnp.asarray(7)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save_checkpoint(d, 10, tree)
    assert latest_step(d) == 10
    restored = restore_checkpoint(d, 10, jax.tree.map(np.asarray, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_most_recent(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, s, _tree(), keep=3)
    names = sorted(os.listdir(d))
    assert names == ["step_00000003", "step_00000004", "step_00000005"]


def test_restore_respects_target_dtype(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": jnp.ones(4, jnp.float32)})
    target = {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)}
    out = restore_checkpoint(d, 1, target)
    assert out["w"].dtype == jnp.bfloat16


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3):
        ck.save(s, _tree())
    ck.wait()
    assert latest_step(d) == 3


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _tree())
    # simulate a torn write: tmp dir exists but was never renamed
    os.makedirs(os.path.join(d, "step_00000002.tmp.999"), exist_ok=True)
    assert latest_step(d) == 1  # tmp dirs are invisible to discovery


def test_elastic_restore_with_new_sharding(tmp_path):
    """Restore with explicit (single-device) shardings — the elastic path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(d, 5, tree)
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    out = restore_checkpoint(d, 5, tree, shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == shardings["w"]
