"""The paper's accuracy claims as executable regressions.

Two layers, both on seeded Erdős–Rényi + Kronecker graphs so every number
is deterministic:

* **Concentration intervals** (Theorem VII.1 via ``core.bounds``): the
  BF/KMV/kH triangle-count estimate must land inside the smallest deviation
  ``t`` whose tail probability is ≤ the configured confidence. The interval
  is inverted analytically here and cross-checked against the bounds module
  itself, so a regression in either the estimators *or* the bounds breaks
  the suite.
* **The >90%-accuracy headline claim** (paper §IX): with modestly sized
  sketches on power-law (Kronecker) graphs, all three TC estimators must
  stay within 10% relative error.

The large configurations are ``slow`` (nightly); one small configuration of
each layer stays in the fast gate as a smoke.
"""
import functools

import numpy as np
import pytest

from repro import engine as eng
from repro.core import bounds, graph as G, sketches as S

CONF = 0.1      # 90%-confidence intervals
SKETCH_SEED = 0


@functools.lru_cache(maxsize=None)
def graph_and_exact(name):
    gs = {
        "er200": lambda: G.erdos_renyi(200, 0.06, seed=11),
        "kron7": lambda: G.kronecker(7, 6, seed=3),
        "er800": lambda: G.erdos_renyi(800, 0.02, seed=7),
        "kron9": lambda: G.kronecker(9, 8, seed=5),
    }[name]()
    return gs, float(eng.session(gs, None).triangle_count())


def tc_interval(gs, sk, conf=CONF):
    """Smallest deviation t with Thm VII.1 tail probability ≤ conf."""
    deg = np.asarray(gs.deg)
    if sk.kind == "bf":
        # invert tc_bf_deviation_bound: 2 m² mse / (9 t²) ≤ conf, with the
        # MSE taken from the bounds module itself (single formula home)
        mse = bounds.bf_and_mse_bound(float(deg.max()), sk.total_bits,
                                      sk.num_hashes)
        return float(np.sqrt(2.0 * gs.m**2 * max(mse, 0.0) / (9.0 * conf)))
    # invert tc_minhash_deviation_bound: 2 exp(−18kt²/s2²) ≤ conf
    s2 = float(np.sum(deg.astype(np.float64) ** 2))
    return float(s2 * np.sqrt(np.log(2.0 / conf) / (18.0 * sk.k)))


def assert_within_interval(name, kind, storage_budget=0.5):
    gs, exact = graph_and_exact(name)
    sk = S.build(gs, kind, storage_budget=storage_budget, num_hashes=2,
                 seed=SKETCH_SEED)
    est = float(eng.session(gs, sk).triangle_count())
    t = tc_interval(gs, sk)
    # the inverted interval must agree with the bounds module itself
    if kind == "bf":
        p = bounds.tc_bf_deviation_bound(gs.m, int(np.asarray(gs.deg).max()),
                                         sk.total_bits, sk.num_hashes, t)
    else:
        p = bounds.tc_minhash_deviation_bound(np.asarray(gs.deg), sk.k, t)
    assert p <= CONF + 1e-9, (name, kind, p)
    assert abs(est - exact) <= t, \
        f"{name}/{kind}: |{est:.1f} - {exact:.1f}| > t={t:.1f}"


# ---------------------------------------------------------------------------
# layer 1: estimates land inside the Thm VII.1 concentration intervals
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["bf", "kmv", "kh"])
def test_tc_within_interval_smoke(kind):
    assert_within_interval("kron7", kind)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["er200", "er800", "kron9"])
@pytest.mark.parametrize("kind", ["bf", "kmv", "kh"])
def test_tc_within_interval(name, kind):
    assert_within_interval(name, kind)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["bf", "kmv", "kh"])
def test_tc_interval_shrinks_with_budget(kind):
    """More storage ⇒ a strictly tighter concentration interval."""
    gs, _ = graph_and_exact("kron7")
    small = S.build(gs, kind, storage_budget=0.25, num_hashes=2,
                    seed=SKETCH_SEED)
    large = S.build(gs, kind, storage_budget=1.0, num_hashes=2,
                    seed=SKETCH_SEED)
    assert tc_interval(gs, large) < tc_interval(gs, small)


# ---------------------------------------------------------------------------
# layer 2: the >90%-accuracy headline claim, executable
# ---------------------------------------------------------------------------

NINETY = [  # (graph, kind, explicit sketch size) — all must stay ≤ 10% off
    ("kron7", "bf", dict(words=128)),
    ("kron7", "kmv", dict(k=128)),
    ("kron7", "kh", dict(k=128)),
]
NINETY_SLOW = [
    ("kron9", "bf", dict(words=256)),
    ("kron9", "kmv", dict(k=256)),
    ("kron9", "kh", dict(k=256)),
    ("er200", "bf", dict(words=256)),
    ("er200", "kh", dict(k=128)),
]


def assert_ninety(name, kind, kw):
    gs, exact = graph_and_exact(name)
    sk = S.build(gs, kind, num_hashes=2, seed=SKETCH_SEED, **kw)
    est = float(eng.session(gs, sk).triangle_count())
    rel = abs(est - exact) / max(exact, 1.0)
    assert rel <= 0.10, f"{name}/{kind}{kw}: relative error {rel:.3f} > 10%"


@pytest.mark.parametrize("name,kind,kw", NINETY)
def test_tc_ninety_percent_accuracy_smoke(name, kind, kw):
    assert_ninety(name, kind, kw)


@pytest.mark.slow
@pytest.mark.parametrize("name,kind,kw", NINETY_SLOW)
def test_tc_ninety_percent_accuracy(name, kind, kw):
    assert_ninety(name, kind, kw)
