"""Graph model regressions: empty graphs, generator edge distributions."""
import numpy as np

from repro.core import graph as G


def test_from_edge_array_n0_returns_valid_empty_graph():
    # regression: the dedupe key lo*n+hi used to divide by n on the way out
    g = G.from_edge_array(0, np.zeros((0, 2), dtype=np.int64))
    assert g.n == 0 and g.m == 0
    assert np.asarray(g.indptr).shape == (1,)
    assert np.asarray(g.edges).shape == (0, 2)
    assert np.asarray(g.deg).shape == (0,)
    assert g.adj.shape == (0, 1) and g.d_max == 1


def test_from_edge_array_n0_drops_out_of_range_edges():
    g = G.from_edge_array(0, np.array([[0, 1], [1, 0]]))
    assert g.n == 0 and g.m == 0


def test_from_edge_array_no_valid_edges():
    g = G.from_edge_array(5, np.array([[2, 2], [3, 3]]))   # only self loops
    assert g.n == 5 and g.m == 0
    assert np.asarray(g.deg).sum() == 0


def test_erdos_renyi_empty_cases():
    assert G.erdos_renyi(0, 0.5).m == 0
    assert G.erdos_renyi(1, 0.5).m == 0
    assert G.erdos_renyi(100, 0.0).m == 0


def test_triu_unrank_exhaustive():
    for n in (2, 3, 7, 40):
        iu = np.triu_indices(n, k=1)
        u, v = G._triu_unrank(np.arange(n * (n - 1) // 2), n)
        assert np.array_equal(u, iu[0]) and np.array_equal(v, iu[1])


def test_erdos_renyi_large_n_geometric_skipping():
    # n chosen so max_pairs > 4M triggers the sparse branch; the old
    # with-replacement sampler silently dropped duplicates/self-loops and
    # undershot p — geometric skipping realizes Binomial(max_pairs, p)
    n, p = 3000, 0.0005
    max_pairs = n * (n - 1) // 2
    assert max_pairs > 4_000_000
    counts = [G.erdos_renyi(n, p, seed=s).m for s in range(3)]
    mean, sigma = p * max_pairs, np.sqrt(p * (1 - p) * max_pairs)
    for m in counts:
        assert abs(m - mean) < 6 * sigma, (m, mean, sigma)
    g = G.erdos_renyi(n, p, seed=0)
    e = np.asarray(g.edges)
    assert (e[:, 0] < e[:, 1]).all()                 # canonical, no self loops
    assert e[:, 1].max() < n
