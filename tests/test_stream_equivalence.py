"""Stream ⇄ static equivalence & device-residency fuzz harness.

The incremental path is the riskiest code in the repo, so this suite pins
its whole contract: after *every* interleaved mutation step — random
insert/delete batches, headroom-overflow row growth, policy-deferred
rebuilds, ``flush()`` — a ``StreamSession``'s ``triangle_count`` /
``local_clustering`` / ``similarity`` answers must be **bit-identical** to a
fresh ``engine.session`` over ``from_edge_array`` on the same edge set, for
all four sketch kinds (and the exact baseline), while the device-resident
mirror stays equal to the host source of truth and per-delta host → device
traffic stays proportional to the delta, never to n·d_max.

``HYPOTHESIS_PROFILE=nightly`` raises the fuzz example counts (CI's nightly
job sets it); the default profile keeps this suite inside the fast gate.
"""
import os

import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # minimal environments
    from _hypothesis_fallback import given, settings, strategies as st

from repro import engine as eng
from repro.core import graph as G, sketches as S
from repro.stream import ErrorBudgetPolicy, StreamSession, stream_session

KINDS = ("bf", "kh", "1h", "kmv")
KW = dict(words=4, k=6, num_hashes=2, seed=3)
NIGHTLY = os.environ.get("HYPOTHESIS_PROFILE") == "nightly"
N_EXAMPLES = 25 if NIGHTLY else 3


def static_session(s, kind):
    gs = G.from_edge_array(s.dyn.n, s.dyn.edge_array())
    sk = S.build(gs, kind, **KW) if kind else None
    return eng.session(gs, sk, plan=s.session.plan)


def assert_equiv(s, kind, pairs, ctx=""):
    """Stream answers ≡ from-scratch static session, bit for bit."""
    static = static_session(s, kind)
    assert float(s.triangle_count()) == float(static.triangle_count()), \
        (kind, ctx)
    np.testing.assert_array_equal(
        np.asarray(s.local_clustering()),
        np.asarray(static.local_clustering()), f"{kind} lcc {ctx}")
    np.testing.assert_array_equal(
        np.asarray(s.similarity(pairs, "jaccard")),
        np.asarray(static.similarity(jnp.asarray(pairs), "jaccard")),
        f"{kind} similarity {ctx}")


def assert_device_mirror(dyn):
    """The device-resident buffers equal the host source of truth."""
    dev = dyn._device
    assert dev is not None, "hot path did not materialize the device state"
    np.testing.assert_array_equal(np.asarray(dev.deg), dyn.deg, "deg")
    np.testing.assert_array_equal(np.asarray(dev.adj), dyn.adj, "adj")
    np.testing.assert_array_equal(np.asarray(dev.edges[: dyn.m]),
                                  dyn.edge_array(), "edges")
    tail = np.asarray(dev.edges[dyn.m:])
    assert (tail == dyn.n).all(), "edge buffer tail lost its sentinel"


def random_step(rng, s):
    """One mutation drawn from {insert, delete, mixed, hub-blast} batches."""
    n = s.dyn.n
    op = int(rng.integers(0, 4))
    ins = dels = None
    if op in (0, 2):
        ins = rng.integers(0, n, size=(int(rng.integers(1, 16)), 2))
    if op in (1, 2):
        cur = s.dyn.edge_array()
        if cur.shape[0]:
            k = min(int(rng.integers(1, 8)), cur.shape[0])
            dels = cur[rng.choice(cur.shape[0], size=k, replace=False)]
    if op == 3:
        # hub blast: push one vertex past its adjacency headroom so the
        # device mirror must grow its row width without a full re-upload
        hub = int(rng.integers(0, n))
        t = rng.choice(n, size=min(n - 1, s.dyn.capacity + 4), replace=False)
        ins = np.stack([np.full(t.size, hub), t], axis=1)
    return s.apply_delta(ins, dels)


# ---------------------------------------------------------------------------
# the fuzz: interleaved deltas stay bit-identical, every kind
# ---------------------------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None)
@given(st.integers(0, 10_000))
def test_fuzz_interleaved_deltas_bit_identical(seed):
    """Property: after every insert/delete/hub-blast step and after flush(),
    stream answers ≡ static session for all four sketch kinds.

    (Kinds loop inside the body: the deterministic hypothesis fallback shim
    wraps properties as zero-arg callables, which parametrize can't feed.)
    """
    for kind in KINDS:
        rng = np.random.default_rng(seed)
        g = G.erdos_renyi(60, 0.08, seed=seed % 97)
        s = stream_session(g, kind, policy=ErrorBudgetPolicy(0.0), **KW)
        _ = s.session.edge_cardinalities()             # warm the shared pass
        pairs = rng.integers(0, g.n, (16, 2)).astype(np.int32)
        for i in range(4):
            info = random_step(rng, s)
            assert info["bytes_uploaded"] >= 0
            assert_equiv(s, kind, pairs, f"step {i}")
            assert_device_mirror(s.dyn)
        s.flush()
        assert_equiv(s, kind, pairs, "after flush")


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(st.integers(0, 10_000))
def test_fuzz_exact_baseline_tracks_device_adjacency(seed):
    """The sketch-free session reads the device adjacency directly, so any
    mirror divergence shows up as a wrong exact triangle count."""
    rng = np.random.default_rng(seed)
    g = G.erdos_renyi(50, 0.1, seed=seed % 89)
    s = stream_session(g, None)
    _ = s.session.edge_cardinalities()
    pairs = rng.integers(0, g.n, (8, 2)).astype(np.int32)
    for i in range(4):
        random_step(rng, s)
        assert_equiv(s, None, pairs, f"step {i}")
        assert_device_mirror(s.dyn)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(st.integers(0, 10_000))
def test_fuzz_deferred_rebuilds_catch_up_on_flush(seed):
    """Under a lazy error-budget policy deletions defer row rebuilds; the
    graph/cache must stay device-mirrored throughout, and flush() must
    restore bit-identity for every kind."""
    for kind in KINDS:
        rng = np.random.default_rng(seed)
        g = G.erdos_renyi(60, 0.12, seed=seed % 83)
        s = stream_session(g, kind,
                           policy=ErrorBudgetPolicy(rel_tolerance=50.0),
                           **KW)
        _ = s.session.edge_cardinalities()
        pairs = rng.integers(0, g.n, (12, 2)).astype(np.int32)
        for _ in range(3):
            random_step(rng, s)
            assert_device_mirror(s.dyn)
        s.flush()
        # a flush leaves zero dirty rows and bit-identical answers
        assert s.maintainer.stats()["rows_dirty"] == 0
        assert_equiv(s, kind, pairs, "after lazy flush")


def test_headroom_overflow_grows_device_adjacency_in_place():
    """Repeated hub blasts force several capacity reallocations; the device
    mirror must follow via sentinel padding + touched-row scatters only."""
    g = G.erdos_renyi(80, 0.05, seed=2)
    s = stream_session(g, "bf", **KW)
    _ = s.session.edge_cardinalities()
    cap0 = s.dyn.capacity
    rng = np.random.default_rng(0)
    pairs = rng.integers(0, g.n, (8, 2)).astype(np.int32)
    hub = 7
    for wave in range(3):
        lo, hi = 1 + wave * 25, 1 + (wave + 1) * 25
        ins = [[hub, (hub + x) % g.n] for x in range(lo, hi)]
        info = s.apply_delta(ins)
        assert info["bytes_uploaded"] > 0
        assert_device_mirror(s.dyn)
        assert_equiv(s, "bf", pairs, f"wave {wave}")
    assert s.dyn.capacity > cap0


# ---------------------------------------------------------------------------
# device-resident contract: per-delta traffic scales with the delta
# ---------------------------------------------------------------------------

def test_noop_delta_uploads_zero_bytes():
    s = stream_session(G.erdos_renyi(60, 0.08, seed=1), "bf", **KW)
    _ = s.session.edge_cardinalities()
    info = s.apply_delta(np.zeros((0, 2)), None)
    assert info["bytes_uploaded"] == 0
    assert s.stats()["traffic"]["bytes_last_delta"] == 0


def test_bytes_per_delta_scale_with_delta_not_graph():
    """The acceptance criterion: the same small delta uploads roughly the
    same number of bytes no matter how large the resident graph is, and far
    fewer bytes than the graph's own residency footprint (n·d_max + m)."""
    per_graph = {}
    for n in (500, 2000):
        g = G.erdos_renyi(n, 8.0 / n, seed=4)          # same expected degree
        s = stream_session(g, "bf", **KW)
        _ = s.session.edge_cardinalities()
        rng = np.random.default_rng(7)
        total = 0
        for _ in range(3):
            ins = rng.integers(0, n, size=(8, 2))
            cur = s.dyn.edge_array()
            dels = cur[rng.choice(cur.shape[0], size=4, replace=False)]
            info = s.apply_delta(ins, dels)
            assert info["bytes_uploaded"] > 0
            # never within an order of magnitude of re-uploading the graph
            assert info["bytes_uploaded"] < s.dyn.traffic.bytes_init / 8
            total += info["bytes_uploaded"]
        per_graph[n] = total / 3
    # 4x the vertices, same delta => same-scale uploads (not 4x)
    assert per_graph[2000] < 3 * per_graph[500], per_graph


def test_stats_report_traffic_fields():
    s = stream_session(G.erdos_renyi(40, 0.1, seed=0), "kmv", **KW)
    s.apply_delta([[0, 1], [2, 3]])
    tr = s.stats()["traffic"]
    for key in ("bytes_init", "bytes_total", "bytes_last_delta",
                "bytes_per_delta_mean", "steps"):
        assert key in tr
    assert tr["bytes_init"] > 0 and tr["bytes_total"] > 0


def test_restored_session_keeps_device_resident_equivalence(tmp_path):
    """Restore re-establishes device residency from the checkpointed host
    state and keeps streaming bit-identically."""
    rng = np.random.default_rng(3)
    s = stream_session(G.erdos_renyi(50, 0.1, seed=6), "kh", **KW)
    for _ in range(2):
        random_step(rng, s)
    s.save(str(tmp_path))
    r = StreamSession.restore(str(tmp_path))
    _ = r.session.edge_cardinalities()
    pairs = rng.integers(0, r.dyn.n, (8, 2)).astype(np.int32)
    random_step(rng, r)
    assert_equiv(r, "kh", pairs, "after restore+delta")
    assert_device_mirror(r.dyn)
