"""Async serving: snapshot isolation, the background flush worker, tenant
admission (quota shed + SLO deadlines), close semantics, deep answer
freezing, and the delta/flush concurrency stress test — every answer served
while deltas land concurrently must be bit-identical to a synchronous
cache-off replay at that answer's ``answered_version``."""
import threading
import time

import numpy as np
import pytest

from repro.core import graph as G
from repro.stream import (BatchedQueryServer, ErrorBudgetPolicy,
                          OverloadError, stream_session)
from repro.stream.server import _freeze

KW = dict(words=4, k=6, num_hashes=2, seed=3,
          policy=ErrorBudgetPolicy(0.0))       # strict: bit-exact answers


def _session(seed=2, n=60, p=0.1):
    return stream_session(G.erdos_renyi(n, p, seed=seed), "bf", **KW)


def _values_equal(a, b) -> bool:
    if isinstance(a, dict):
        return set(a) == set(b) and all(_values_equal(a[k], b[k]) for k in a)
    if isinstance(a, np.ndarray):
        return bool(np.array_equal(a, np.asarray(b)))
    return a == b


def _wait_results(server, want, timeout=60.0):
    """Drain until ``want`` results arrived (the worker flushes on its own
    schedule) or fail the test."""
    out = {}
    t0 = time.perf_counter()
    while len(out) < want:
        out.update(server.drain())
        if time.perf_counter() - t0 > timeout:
            raise AssertionError(f"only {len(out)}/{want} answers arrived")
        time.sleep(0.001)
    return out


# ---------------------------------------------------------------------------
# serving-view snapshot isolation
# ---------------------------------------------------------------------------

def test_serving_view_is_isolated_from_later_deltas():
    st = _session()
    v0 = st.serving_view()
    tc0 = float(v0.session.triangle_count())
    nbrs0 = v0.host.neighbors(0).copy()
    st.apply_delta([[0, 1], [0, 2], [0, 3], [2, 5]])
    v1 = st.serving_view()
    assert v1 is not v0 and v1.version == v0.version + 1
    assert v1.epoch == v0.epoch + 1
    # the captured view still answers at version N: same engine state, and
    # the host snapshot's overlay shields its rows from in-place mutation
    assert float(v0.session.triangle_count()) == tc0
    np.testing.assert_array_equal(v0.host.neighbors(0), nbrs0)
    assert v1.host.m == st.dyn.m and v0.host.m != v1.host.m


def test_snapshot_neighbors_returns_stable_copies():
    # live-row reads copy under the row lock: the returned array must not
    # alias the mutable adjacency, and must survive a later delta intact
    st = _session()
    snap = st.serving_view().host
    v = int(np.argmax(st.dyn.deg))
    nbrs = snap.neighbors(v)
    before = nbrs.copy()
    assert not np.shares_memory(nbrs, st.dyn.adj)
    absent = [u for u in range(st.dyn.n)
              if u != v and u not in set(before.tolist())][:2]
    st.apply_delta([[v, u] for u in absent])
    assert int(st.dyn.deg[v]) == before.size + len(absent)
    np.testing.assert_array_equal(nbrs, before)
    np.testing.assert_array_equal(snap.neighbors(v), before)


def test_snapshot_neighbors_race_with_concurrent_deltas():
    """Hammer a version-0 snapshot's neighbors() from reader threads while
    deltas (inserts AND deletes, so rows shrink and grow) land from the
    main thread: every read must equal the version-0 truth — the TOCTOU
    window between the overlay probe and the live-row read is closed by
    the shared row lock."""
    n = 50
    st = _session(seed=9, n=n, p=0.12)
    snap = st.serving_view().host
    truth = {v: snap.neighbors(v).copy() for v in range(n)}
    stop = threading.Event()
    errs = []

    def read_loop(seed):
        rng = np.random.default_rng(seed)
        try:
            while not stop.is_set():
                v = int(rng.integers(0, n))
                got = snap.neighbors(v)
                if not np.array_equal(got, truth[v]):
                    errs.append((v, got.copy(), truth[v]))
                    return
        except Exception as exc:    # pragma: no cover - the failure signal
            errs.append(exc)

    readers = [threading.Thread(target=read_loop, args=(s,))
               for s in range(2)]
    for t in readers:
        t.start()
    rng = np.random.default_rng(1)
    try:
        for _ in range(30):
            e = rng.integers(0, n, size=(8, 2)).astype(np.int64)
            e = e[e[:, 0] != e[:, 1]]
            st.apply_delta(e[2:], e[:2])
    finally:
        stop.set()
        for t in readers:
            t.join()
    assert not errs, f"snapshot read diverged from version 0: {errs[:1]}"


def test_donation_guard_tracks_leases_and_stale_views():
    st = _session()
    # steady state: only the published view's snapshot is alive and no
    # lease is out, so the streaming session may donate device buffers
    assert st._device_donate_ok()
    st._end_donation()              # reset the window the check opened
    view = st.acquire_serving_view()
    try:
        assert not st._device_donate_ok()      # lease out: no donation
    finally:
        st.release_serving_view(view)
    old = st.serving_view()
    st.apply_delta([[0, 1], [0, 2]])
    # a stale view still alive vetoes donation; dropping it re-enables
    assert not st._device_donate_ok()
    del old, view
    assert st._device_donate_ok()
    st._end_donation()


def test_noop_delta_still_publishes_a_view():
    st = _session()
    e0 = st.serving_view().epoch
    st.apply_delta(None, None)
    assert st.serving_view().epoch == e0 + 1


# ---------------------------------------------------------------------------
# background flush worker
# ---------------------------------------------------------------------------

def test_async_worker_flushes_on_max_batch():
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8, async_flush=True, max_batch=2)
    try:
        r1 = srv.submit_triangle_count()
        r2 = srv.submit_membership(0, np.arange(8, dtype=np.int32))
        out = _wait_results(srv, 2)            # no explicit flush() anywhere
        assert set(out) == {r1, r2}
        assert out[r1].staleness == 0
    finally:
        srv.close()


def test_async_worker_flushes_on_max_wait():
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8, async_flush=True,
                             max_batch=64, max_wait_s=0.01)
    try:
        rid = srv.submit_triangle_count()      # far below max_batch
        out = _wait_results(srv, 1)
        assert rid in out
    finally:
        srv.close()


def test_async_backpressure_bounds_the_queue():
    """A submit loop hotter than the worker must block at the high-water
    mark instead of growing the queue without bound (and starving the
    worker of the lock): every answer still arrives, and the throttle is
    visible in the metrics."""
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8, async_flush=True,
                             max_batch=2, max_wait_s=0.005)  # backlog HWM = 8
    orig_flush = srv._flush_queue

    def _slow_flush():
        time.sleep(0.01)               # make the worker provably slower
        orig_flush()                   # than the tight submit loop below

    srv._flush_queue = _slow_flush
    seen_max = 0
    try:
        rids = []
        for i in range(40):
            rids.append(srv.submit_membership(
                i % st.dyn.n, np.arange(8, dtype=np.int32)))
            seen_max = max(seen_max, len(srv._queue))
        out = srv.flush()
        out.update(_wait_results(srv, len(rids) - len(out)))
        assert set(out) == set(rids)
        # the queue never grew past the high-water mark (+1 for the request
        # appended by the submit that then blocked on the throttle)
        assert seen_max <= srv.max_backlog + 1
        assert srv.metrics.counter("server_backpressure_total").value > 0
    finally:
        srv.close()


def test_async_backlog_alone_triggers_flush_no_submit_hang():
    """With no max_batch, no max_wait_s and deadline-free submits, the
    only admission trigger left is the backlog high-water mark itself —
    a submitter blocked on backpressure must be rescued by the worker
    flushing, never stuck forever (it cannot call flush() while blocked)."""
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8, async_flush=True,
                             max_backlog=4)
    rids = []
    done = threading.Event()

    def submit_all():
        for i in range(12):                 # 3x the high-water mark
            rids.append(srv.submit_membership(
                i % st.dyn.n, np.arange(4, dtype=np.int32)))
        done.set()

    t = threading.Thread(target=submit_all, daemon=True)
    try:
        t.start()
        t.join(60.0)
        assert done.is_set(), "submits blocked forever at max_backlog"
        out = srv.flush()
        out.update(_wait_results(srv, len(rids) - len(out)))
        assert set(out) == set(rids)
    finally:
        srv.close()
        t.join(5.0)


def test_async_flush_and_poll_keep_contracts():
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8, async_flush=True)
    try:
        rid = srv.submit_triangle_count()  # no admission trigger configured
        out = srv.flush()                  # explicit flush still synchronous
        assert rid in out and srv.poll() == {} and srv.drain() == {}
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# close(): flush-then-detach
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("async_flush", [False, True])
def test_close_answers_pending_then_rejects(async_flush):
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8, async_flush=async_flush)
    rid = srv.submit_triangle_count()
    srv.close()
    assert srv.closed and srv.cache is None
    out = srv.drain()                    # pending work answered, claimable
    assert rid in out
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit_triangle_count()
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit_similarity(np.array([[0, 1]], np.int32))
    srv.close()                          # idempotent


# ---------------------------------------------------------------------------
# per-tenant admission: quota shed + SLO deadlines
# ---------------------------------------------------------------------------

def test_tenant_quota_sheds_with_accounting():
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8, tenant_quota=2)
    try:
        srv.submit_triangle_count(tenant="gold")
        srv.submit_clique_count(4, tenant="gold")
        with pytest.raises(OverloadError):
            srv.submit_triangle_count(tenant="gold")
        # the quota is per tenant: another tenant still gets in
        srv.submit_triangle_count(tenant="silver")
        srv.flush()
        tenants = srv.stats()["tenants"]
        assert tenants["gold"]["shed"] == 1
        assert tenants["gold"]["served"] == 2
        assert tenants["silver"]["shed"] == 0
        assert tenants["silver"]["served"] == 1
        assert srv.stats()["shed"] == 1
        # a flush empties the pending count, so the tenant is admitted again
        srv.submit_triangle_count(tenant="gold")
    finally:
        srv.close()


def test_deadline_miss_marked_and_counted():
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8)
    try:
        r_miss = srv.submit_triangle_count(tenant="gold", deadline_s=0.0)
        r_ok = srv.submit_triangle_count(tenant="gold", deadline_s=120.0)
        out = srv.flush()
        assert out[r_miss].deadline_missed and not out[r_ok].deadline_missed
        assert out[r_miss].tenant == "gold"
        assert srv.stats()["tenants"]["gold"]["deadline_missed"] == 1
        assert "latency_p99_s" in srv.stats()["tenants"]["gold"]
    finally:
        srv.close()


def test_flush_orders_earliest_deadline_first():
    st = _session()
    srv = BatchedQueryServer(st, min_batch=8)
    try:
        late = srv.submit_local_cluster(1, eps=1e-2, deadline_s=60.0)
        none = srv.submit_local_cluster(2, eps=1e-2)
        soon = srv.submit_local_cluster(3, eps=1e-2, deadline_s=0.5)
        out = srv.flush()
        assert set(out) == {late, none, soon}
        # EDF is observable through the queue sort key, not the answer set;
        # assert directly on the comparator's ordering
        from repro.stream.server import _Pending, _edf_key
        ps = [_Pending(late, "x", (), "", None, {}, 0, 0.0, "t", 60.0),
              _Pending(none, "x", (), "", None, {}, 0, 0.0, "t", None),
              _Pending(soon, "x", (), "", None, {}, 0, 0.0, "t", 0.5)]
        assert [p.request_id for p in sorted(ps, key=_edf_key)] \
            == [soon, late, none]
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# deep freeze
# ---------------------------------------------------------------------------

def test_freeze_recurses_into_nested_containers():
    nested = {"top": np.zeros(3),
              "inner": {"arr": np.ones(2)},
              "list": [np.arange(4), {"deep": np.arange(2)}],
              "tup": (np.zeros(1),)}
    _freeze(nested)
    for arr in (nested["top"], nested["inner"]["arr"], nested["list"][0],
                nested["list"][1]["deep"], nested["tup"][0]):
        with pytest.raises(ValueError):
            arr[0] = 7


def test_cached_answers_cannot_be_mutated_through_a_hit():
    st = _session(seed=5)
    srv = BatchedQueryServer(st, min_batch=8)
    try:
        lc = srv.submit_local_cluster(4, eps=1e-2)
        lp = srv.submit_link_prediction(3, top_k=4)
        out = srv.flush()
        with pytest.raises(ValueError):
            out[lc].value["members"][0] = 99
        with pytest.raises(ValueError):
            out[lp].value["candidates"][...] = 0
        # the same objects come back on a cache hit, still intact
        lc2 = srv.submit_local_cluster(4, eps=1e-2)
        out2 = srv.flush()
        assert _values_equal(out2[lc2].value, out[lc].value)
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# the concurrency stress test: deltas racing in-flight flushes
# ---------------------------------------------------------------------------

def _submit_spec(server, spec, rng, n):
    kind = spec
    if kind == "similarity":
        pairs = rng.integers(0, n, size=(6, 2)).astype(np.int32)
        return server.submit_similarity(pairs, "jaccard"), ("similarity",
                                                           pairs)
    if kind == "membership":
        u = int(rng.integers(0, n))
        cand = rng.integers(0, n, size=8).astype(np.int32)
        return server.submit_membership(u, cand), ("membership", u, cand)
    if kind == "linkpred":
        u = int(rng.integers(0, n))
        return server.submit_link_prediction(u, top_k=4), ("linkpred", u)
    if kind == "localcluster":
        s = int(rng.integers(0, n))
        return server.submit_local_cluster(s, eps=1e-2), ("localcluster", s)
    return server.submit_triangle_count(), ("tc",)


def _resubmit(server, spec):
    kind = spec[0]
    if kind == "similarity":
        return server.submit_similarity(spec[1], "jaccard")
    if kind == "membership":
        return server.submit_membership(spec[1], spec[2])
    if kind == "linkpred":
        return server.submit_link_prediction(spec[1], top_k=4)
    if kind == "localcluster":
        return server.submit_local_cluster(spec[1], eps=1e-2)
    return server.submit_triangle_count()


def test_concurrent_deltas_and_flushes_are_bit_identical():
    """Apply deltas from one thread while the async worker flushes queries
    from another; then prove every answer equals a synchronous cache-off
    replay of the *same request* at that answer's ``answered_version``."""
    n = 60
    g = G.erdos_renyi(n, 0.1, seed=7)
    rng = np.random.default_rng(11)
    # withheld insert-only chunks (deletions would exercise the same code
    # path but make the per-version replay graphs harder to reason about)
    chunks = [rng.integers(0, n, size=(6, 2)).astype(np.int64)
              for _ in range(6)]
    chunks = [c[c[:, 0] != c[:, 1]] for c in chunks]

    # warm XLA's in-process compile cache on a throwaway twin first —
    # otherwise the first apply_delta/flush pay multi-second compiles and
    # the "race" degenerates into strictly sequential phases
    warm_st = stream_session(g, "bf", **KW)
    warm = BatchedQueryServer(warm_st, min_batch=8, cache=False)
    wrng = np.random.default_rng(13)
    for kind in ("similarity", "membership", "linkpred", "localcluster",
                 "tc"):
        _submit_spec(warm, kind, wrng, n)
    warm.flush()
    warm_st.apply_delta(chunks[0])
    warm.close()

    st = stream_session(g, "bf", **KW)
    srv = BatchedQueryServer(st, min_batch=8, async_flush=True,
                             max_batch=3, max_wait_s=0.005)
    stop = threading.Event()

    def mutate():
        for chunk in chunks:
            if stop.is_set():
                return
            st.apply_delta(chunk)
            time.sleep(0.004)

    mutator = threading.Thread(target=mutate)
    specs = {}
    results = {}
    kinds = ("similarity", "membership", "linkpred", "localcluster", "tc")
    try:
        mutator.start()
        qrng = np.random.default_rng(13)
        i = 0
        # keep traffic flowing for as long as deltas are landing (bounded:
        # the mutator finishes in ~tens of ms once warm)
        while mutator.is_alive() and i < 200:
            rid, spec = _submit_spec(srv, kinds[i % len(kinds)], qrng, n)
            specs[rid] = spec
            i += 1
            results.update(srv.drain())
            time.sleep(0.001)
        mutator.join()
        # one guaranteed post-delta round: these answer at the final version
        for kind in kinds:
            rid, spec = _submit_spec(srv, kind, qrng, n)
            specs[rid] = spec
        results.update(srv.flush())
        results.update(_wait_results(srv, len(specs) - len(results)))
    finally:
        stop.set()
        if mutator.is_alive():
            mutator.join()
        stats = srv.stats()
        cache_stats = stats["cache"]
        srv.close()

    assert len(results) == len(specs)
    assert all(r.staleness >= 0 for r in results.values())
    versions = sorted({r.answered_version for r in results.values()})
    assert versions[-1] == len(chunks)         # deltas really interleaved

    # ground truth: one fresh strict session per distinct answered version,
    # same deltas replayed synchronously, cache off
    for v in versions:
        truth_st = stream_session(g, "bf", **KW)
        for chunk in chunks[:v]:
            truth_st.apply_delta(chunk)
        truth = BatchedQueryServer(truth_st, min_batch=8, cache=False)
        rids = [rid for rid, r in results.items() if r.answered_version == v]
        mapping = {_resubmit(truth, specs[rid]): rid for rid in rids}
        answers = truth.flush()
        for t_rid, rid in mapping.items():
            assert _values_equal(results[rid].value, answers[t_rid].value), \
                f"{specs[rid][0]} diverged at version {v}"
        truth.close()

    # accounting survived the races: eviction/staleness counters consistent
    assert stats["served"] == len(specs)
    assert cache_stats["inserts"] >= cache_stats["entries"]
    assert cache_stats["rejected_stale"] >= 0
    lookups = cache_stats["hits"] + cache_stats["misses"]
    assert lookups >= cache_stats["entries"]


def test_save_mid_stream_is_version_consistent():
    # save() holds the mutation lock; a checkpoint taken between concurrent
    # deltas restores to a graph whose edge count matches its version
    import tempfile
    n = 40
    g = G.erdos_renyi(n, 0.1, seed=3)
    st = stream_session(g, "bf", **KW)
    rng = np.random.default_rng(5)
    chunks = [rng.integers(0, n, size=(4, 2)).astype(np.int64)
              for _ in range(4)]
    chunks = [c[c[:, 0] != c[:, 1]] for c in chunks]
    with tempfile.TemporaryDirectory() as d:
        errs = []

        def mutate():
            try:
                for chunk in chunks:
                    st.apply_delta(chunk)
            except Exception as exc:    # pragma: no cover
                errs.append(exc)

        t = threading.Thread(target=mutate)
        t.start()
        from repro.stream import StreamSession
        st.save(d, step=999)
        t.join()
        assert not errs
        restored = StreamSession.restore(d, step=999)
        assert restored.serving_view().version == restored.version
        # the restored edge set must be a consistent prefix of the stream
        assert restored.dyn.m <= st.dyn.m


def test_async_localcluster_races_deltas_bit_identical():
    """submit_local_cluster under async_flush=True with deltas landing from
    another thread: every served answer — on the sparse-frontier push path —
    must equal a synchronous cache-off replay at its ``answered_version``."""
    n = 60
    g = G.erdos_renyi(n, 0.1, seed=9)
    rng = np.random.default_rng(17)
    chunks = [c[c[:, 0] != c[:, 1]] for c in
              (rng.integers(0, n, size=(5, 2)).astype(np.int64)
               for _ in range(5))]
    # cap ≥ n: the sparse path engages but provably cannot spill, so every
    # answer stays on the capped-buffer code under the races
    kw = dict(KW, frontier_mode="sparse", frontier_cap=64)

    # warm XLA on a throwaway twin (same rationale as the stress test above)
    warm_st = stream_session(g, "bf", **kw)
    warm = BatchedQueryServer(warm_st, min_batch=8, cache=False)
    warm.submit_local_cluster(3, eps=1e-2)
    warm.flush()
    warm_st.apply_delta(chunks[0])
    warm.submit_local_cluster(4, eps=1e-2)
    warm.flush()
    warm.close()

    st = stream_session(g, "bf", **kw)
    srv = BatchedQueryServer(st, min_batch=8, async_flush=True,
                             max_batch=2, max_wait_s=0.005)
    stop = threading.Event()

    def mutate():
        for chunk in chunks:
            if stop.is_set():
                return
            st.apply_delta(chunk)
            time.sleep(0.004)

    mutator = threading.Thread(target=mutate)
    seeds = {}
    results = {}
    qrng = np.random.default_rng(23)
    try:
        mutator.start()
        i = 0
        while mutator.is_alive() and i < 100:
            seed = int(qrng.integers(0, n))
            seeds[srv.submit_local_cluster(seed, eps=1e-2)] = seed
            i += 1
            results.update(srv.drain())
            time.sleep(0.001)
        mutator.join()
        for seed in (3, 17, 42):      # guaranteed post-delta answers
            seeds[srv.submit_local_cluster(seed, eps=1e-2)] = seed
        results.update(srv.flush())
        results.update(_wait_results(srv, len(seeds) - len(results)))
    finally:
        stop.set()
        if mutator.is_alive():
            mutator.join()
        srv.close()

    assert len(results) == len(seeds)
    versions = sorted({r.answered_version for r in results.values()})
    assert versions[-1] == len(chunks)         # deltas really interleaved

    for v in versions:
        truth_st = stream_session(g, "bf", **kw)
        for chunk in chunks[:v]:
            truth_st.apply_delta(chunk)
        truth = BatchedQueryServer(truth_st, min_batch=8, cache=False)
        rids = [rid for rid, r in results.items()
                if r.answered_version == v]
        mapping = {truth.submit_local_cluster(seeds[rid], eps=1e-2): rid
                   for rid in rids}
        answers = truth.flush()
        for t_rid, rid in mapping.items():
            assert _values_equal(results[rid].value, answers[t_rid].value), \
                f"localcluster(seed={seeds[rid]}) diverged at version {v}"
        truth.close()
