"""End-to-end system tests: training driver, fault recovery, serving,
distributed mining (multi-device via subprocess)."""
import os
import subprocess
import sys

import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.distributed.fault import FaultInjector, StepMonitor
from repro.launch.train import TrainRunConfig, train


def test_train_loop_loss_decreases(tmp_path):
    run = TrainRunConfig(arch="gemma_2b", steps=25, global_batch=8,
                         seq_len=32, d_model=64, layers=2, lr=5e-3,
                         vocab_size=128)
    _, hist = train(run)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_train_recovers_from_injected_fault(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    run = TrainRunConfig(arch="qwen3_8b", steps=24, global_batch=4,
                         seq_len=32, d_model=64, layers=2, vocab_size=128,
                         ckpt_dir=ckpt, ckpt_every=6)
    fault = FaultInjector(fail_at_steps=[13])
    _, hist = train(run, fault=fault)
    steps_seen = [h["step"] for h in hist]
    assert 13 in fault.fired
    # restarted from step-12 checkpoint and completed
    assert steps_seen.count(12) >= 1
    assert steps_seen[-1] == 23


def test_train_resume_is_deterministic(tmp_path):
    """Same data at step k whether run straight or resumed (elastic restart)."""
    ckpt = str(tmp_path / "ckpt")
    base = dict(arch="gemma_2b", steps=12, global_batch=4, seq_len=32,
                d_model=64, layers=2, vocab_size=128, ckpt_every=6)
    _, h1 = train(TrainRunConfig(**base, ckpt_dir=ckpt))
    # rerun with a fault right after the step-6 checkpoint
    ckpt2 = str(tmp_path / "ckpt2")
    fault = FaultInjector(fail_at_steps=[7])
    _, h2 = train(TrainRunConfig(**base, ckpt_dir=ckpt2), fault=fault)
    l1 = {h["step"]: h["loss"] for h in h1}
    l2 = {h["step"]: h["loss"] for h in h2}
    for s in (8, 9, 10, 11):
        assert abs(l1[s] - l2[s]) < 1e-4, (s, l1[s], l2[s])


def test_compressed_grads_trains(tmp_path):
    run = TrainRunConfig(arch="gemma_2b", steps=15, global_batch=4,
                         seq_len=32, d_model=64, layers=2, vocab_size=128,
                         compress_grads=True)
    _, hist = train(run)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_adafactor_driver(tmp_path):
    """Adafactor path through the driver descends (slower than AdamW by
    design — decaying beta2 + update clipping need more steps)."""
    run = TrainRunConfig(arch="gemma_2b", steps=100, global_batch=8,
                         seq_len=32, d_model=64, layers=2, vocab_size=128,
                         optimizer="adafactor", lr=3e-2, warmup=10)
    _, hist = train(run)
    assert np.mean([h["loss"] for h in hist[-10:]]) < hist[0]["loss"] - 0.05


def test_step_monitor_flags_stragglers():
    mon = StepMonitor(window=16, straggler_factor=2.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 0.5)
    assert not mon.record(11, 0.12)


def test_serving_generates():
    from repro.launch.serve import BatchedServer, ServeConfig
    server = BatchedServer(ServeConfig(arch="gemma_2b", batch=2, max_len=64,
                                       d_model=64, layers=2))
    out = server.generate([[1, 2, 3], [4, 5]], num_tokens=8, greedy=True)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < server.cfg.vocab_size).all()


def test_distributed_mining_multidevice():
    """shard_map mining on 8 fake devices == single-device estimate."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, %r)
import jax
from repro.core import graph as G
from repro.launch.mine import mine
g = G.erdos_renyi(300, 0.05, seed=5)
mesh = jax.make_mesh((4, 2), ("data", "model"))
out = mine(g, mesh, storage_budget=0.5)
print("TC8=", out["tc_estimate"])
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", script % src],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    tc8 = float(proc.stdout.strip().split("TC8=")[1])

    # single-device reference with the same sketch params
    from repro.core import graph as G, sketches as S
    from repro.core import triangle_count
    g = G.erdos_renyi(300, 0.05, seed=5)
    sk = S.build(g, "bf", storage_budget=0.5, num_hashes=2, seed=0)
    tc1 = float(triangle_count(g, sk))
    assert abs(tc8 - tc1) / max(tc1, 1) < 1e-3, (tc8, tc1)
