"""Logical-axis sharding rules + HLO analyzer unit tests."""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis import hlo as H
from repro.distributed import sharding as SH


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_filters_nondivisible_dims():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = SH.spec_for(("batch", "seq", "vocab"), (1, 1, 32000), mesh=mesh,
                       rules=SH.BASE_RULES)
    assert spec == P(None, None, "model")  # batch=1 cannot shard; vocab can
    spec = SH.spec_for(("batch", "seq", "vocab"), (256, 4096, 50280), mesh=mesh,
                       rules=SH.BASE_RULES)
    assert spec[0] == "data" and spec[2] is None  # 50280 % 16 != 0


def test_spec_never_reuses_mesh_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # heads takes 'model'; head_dim_tp must not reuse it
    spec = SH.spec_for(("embed_fsdp", "heads", "head_dim_tp"),
                       (4096, 32, 128), mesh=mesh, rules=SH.BASE_RULES)
    assert spec == P("data", "model", None)
    # MQA fallback: heads=8 can't take 16-way 'model'; head_dim 256 can
    spec = SH.spec_for(("embed_fsdp", "heads", "head_dim_tp"),
                       (2048, 8, 256), mesh=mesh, rules=SH.BASE_RULES)
    assert spec == P("data", None, "model")


def test_shard_as_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert SH.shard_as(x, "batch", None) is x


def test_hlo_analyzer_dot_flops():
    txt = """
HloModule test

ENTRY %main (p0: f32[64,128], p1: f32[128,32]) -> f32[64,32] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %p1 = f32[128,32]{1,0} parameter(1)
  ROOT %dot.1 = f32[64,32]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    stats = H.analyze(txt)
    assert stats.flops == 2 * 64 * 128 * 32


def test_hlo_analyzer_while_scaling():
    txt = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %dot.2 = f32[8,8]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8]) tuple(%g0, %dot.2)
}

%cond (p2: (s32[], f32[8,8])) -> pred[] {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %g = s32[] get-tuple-element(%p2), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%g, %c), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    stats = H.analyze(txt)
    assert stats.flops == 12 * 2 * 8 * 8 * 8
    assert stats.while_trip_counts == [12]


def test_hlo_analyzer_collectives():
    txt = """
HloModule test

%add (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %a = f32[] add(%x, %y)
}

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    stats = H.analyze(txt)
    assert stats.collective_bytes["all-reduce"] == 4096
    # ring model: 2(g-1)/g * bytes with g=4
    assert abs(stats.collective_link_bytes - 2 * 3 / 4 * 4096) < 1e-6


def test_roofline_terms():
    from repro.analysis import roofline as R
    from repro import configs as C
    from repro.models import SHAPES
    stats = H.HloStats(flops=1.97e14, bytes_proxy=8.19e11,
                       collective_link_bytes=5e10)
    roof = R.build("qwen3_8b", SHAPES["train_4k"], C.get("qwen3_8b"),
                   "16x16", 256, stats)
    assert abs(roof.compute_s - 1.0) < 1e-6
    assert abs(roof.memory_s - 1.0) < 1e-6
    assert abs(roof.collective_s - 1.0) < 1e-6
    assert roof.bottleneck in ("compute", "memory", "collective")
