"""Streaming subsystem: dynamic graph deltas, incremental sketch maintenance
(≡ from-scratch rebuild, bit-identical), delta-aware session refresh, the
batched query server, and snapshot/restore."""
import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # minimal environments
    from _hypothesis_fallback import given, settings, strategies as st

from repro import engine as eng
from repro.core import graph as G, sketches as S
from repro.stream import (BatchedQueryServer, DynamicGraph, ErrorBudgetPolicy,
                          StreamSession, stream_session)

KINDS = ("bf", "kh", "1h", "kmv")
SKETCH_KW = dict(words=4, k=6, num_hashes=2, seed=3)
# explicit @settings pins override any loaded hypothesis profile, so the
# nightly raise must come from the env var directly (same contract as
# tests/test_stream_equivalence.py)
N_EXAMPLES = 25 if os.environ.get("HYPOTHESIS_PROFILE") == "nightly" else 5


def base_graph(n=90, p=0.07, seed=5):
    return G.erdos_renyi(n, p, seed=seed)


def random_delta(rng, n, dyn, n_ins=20, n_del=6):
    ins = rng.integers(0, n, size=(n_ins, 2))
    cur = dyn.edge_array()
    dels = (cur[rng.choice(cur.shape[0], size=min(n_del, cur.shape[0]),
                           replace=False)] if cur.shape[0] else None)
    return ins, dels


def scratch_sketch(dyn, kind):
    return S.build(G.from_edge_array(dyn.n, dyn.edge_array()), kind,
                   **SKETCH_KW)


# ---------------------------------------------------------------------------
# DynamicGraph
# ---------------------------------------------------------------------------

def test_dynamic_snapshot_matches_from_edge_array():
    g = base_graph()
    rng = np.random.default_rng(0)
    dyn = DynamicGraph.from_graph(g)
    for _ in range(4):
        dyn.apply_delta(*random_delta(rng, g.n, dyn))
    snap = dyn.snapshot()
    ref = G.from_edge_array(g.n, dyn.edge_array())
    for name in ("indptr", "indices", "adj", "deg", "edges"):
        np.testing.assert_array_equal(np.asarray(getattr(snap, name)),
                                      np.asarray(getattr(ref, name)), name)
    assert (snap.n, snap.m, snap.d_max) == (ref.n, ref.m, ref.d_max)


def test_dynamic_delta_canonicalization():
    dyn = DynamicGraph.from_edges(10, [[0, 1], [1, 2]])
    # duplicate / reversed / self-loop / already-present inserts collapse
    d = dyn.apply_delta([[2, 1], [3, 3], [4, 5], [5, 4], [4, 5]], [[9, 8]])
    assert d.inserted.shape[0] == 1 and d.deleted.shape[0] == 0
    assert dyn.m == 3
    d = dyn.apply_delta(None, [[1, 0], [0, 1]])
    np.testing.assert_array_equal(d.deleted, [[0, 1]])
    assert dyn.m == 2 and np.array_equal(d.dirty, [0, 1])


def test_dynamic_headroom_growth():
    dyn = DynamicGraph.from_edges(64, [[0, 1]], headroom=1.5)
    cap0 = dyn.capacity
    dyn.apply_delta([[0, v] for v in range(2, 40)])
    assert dyn.capacity > cap0 and dyn.deg[0] == 39
    np.testing.assert_array_equal(np.sort(dyn.neighbors(0)),
                                  np.arange(1, 40))
    ref = G.from_edge_array(64, dyn.edge_array())
    np.testing.assert_array_equal(np.asarray(dyn.snapshot().adj),
                                  np.asarray(ref.adj))


def test_dynamic_empty_graph_n0():
    dyn = DynamicGraph.from_edges(0, None)
    d = dyn.apply_delta([[0, 1]], None)
    assert d.is_noop and dyn.m == 0
    assert dyn.snapshot().n == 0


# ---------------------------------------------------------------------------
# incremental maintenance ≡ from-scratch rebuild (bit-identical, all kinds)
# ---------------------------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None)
@given(st.integers(0, 10_000))
def test_incremental_insert_equals_rebuild(seed):
    """Property: insert-only maintenance ≡ from-scratch build, every kind.

    (Kinds loop inside the body: the deterministic hypothesis fallback shim
    wraps properties as zero-arg callables, which parametrize can't feed.)
    """
    for kind in KINDS:
        rng = np.random.default_rng(seed)
        g = G.erdos_renyi(60, 0.08, seed=seed % 97)
        s = stream_session(g, kind, policy=ErrorBudgetPolicy(0.0),
                           **SKETCH_KW)
        for _ in range(3):
            s.apply_delta(
                rng.integers(0, g.n, size=(int(rng.integers(1, 25)), 2)))
        assert s.maintainer.rows_rebuilt == 0          # inserts never rebuild
        np.testing.assert_array_equal(
            np.asarray(s.sketch.data),
            np.asarray(scratch_sketch(s.dyn, kind).data), kind)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(st.integers(0, 10_000))
def test_delete_dirty_rebuild_cycle_equals_rebuild(seed):
    """Property: delete→dirty→selective-rebuild cycles stay bit-identical."""
    for kind in KINDS:
        rng = np.random.default_rng(seed)
        g = G.erdos_renyi(60, 0.12, seed=seed % 89)
        s = stream_session(g, kind, policy=ErrorBudgetPolicy(0.0),
                           **SKETCH_KW)
        for _ in range(3):
            s.apply_delta(*random_delta(rng, g.n, s.dyn, n_ins=12, n_del=8))
        assert s.maintainer.stats()["rows_dirty"] == 0    # strict policy
        assert s.maintainer.rows_rebuilt > 0
        np.testing.assert_array_equal(
            np.asarray(s.sketch.data),
            np.asarray(scratch_sketch(s.dyn, kind).data), kind)


@pytest.mark.parametrize("kind", KINDS)
def test_empty_delta_is_a_noop(kind):
    s = stream_session(base_graph(), kind, **SKETCH_KW)
    before = s.sketch.data
    stats = s.maintainer.stats()
    info = s.apply_delta(np.zeros((0, 2)), None)
    assert info["inserted"] == info["deleted"] == 0
    assert s.sketch.data is before                     # untouched, not rebuilt
    after = s.maintainer.stats()
    assert after["rows_incremental"] == stats["rows_incremental"]
    assert after["rows_rebuilt"] == stats["rows_rebuilt"]


@pytest.mark.parametrize("kind", KINDS)
def test_error_budget_defers_then_flush_catches_up(kind):
    g = G.erdos_renyi(80, 0.1, seed=2)
    s = stream_session(g, kind, policy=ErrorBudgetPolicy(rel_tolerance=50.0),
                       **SKETCH_KW)
    s.apply_delta(None, s.dyn.edge_array()[:6])
    ms = s.maintainer.stats()
    # most dirty rows stay deferred (their staleness hides below the sketch's
    # own error scale); only rows whose degree dropped near 0 — zero error
    # tolerance — may rebuild immediately
    assert ms["rows_dirty"] > 0
    assert ms["rows_rebuilt"] < ms["rows_dirty"] + ms["rows_rebuilt"]
    assert ms["rows_rebuilt"] <= 2
    assert ms["stale_total"] > 0
    s.flush()
    assert s.maintainer.stats()["rows_dirty"] == 0
    np.testing.assert_array_equal(np.asarray(s.sketch.data),
                                  np.asarray(scratch_sketch(s.dyn, kind).data))


def test_strict_policy_allows_zero_lazy_allows_more():
    s = stream_session(base_graph(), "bf", **SKETCH_KW)
    deg = np.asarray([4, 16, 64])
    assert (ErrorBudgetPolicy(0.0).allowed_stale(s.sketch, deg) == 0).all()
    lazy = ErrorBudgetPolicy(rel_tolerance=1.0).allowed_stale(s.sketch, deg)
    assert (lazy > 0).all()


# ---------------------------------------------------------------------------
# delta-aware session refresh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", KINDS + (None,))
def test_session_refresh_matches_from_scratch(kind):
    g = base_graph(n=120, p=0.06)
    kw = SKETCH_KW if kind else {}
    s = stream_session(g, kind, **kw)
    _ = s.session.edge_cardinalities()                 # populate the cache
    rng = np.random.default_rng(7)
    total_recomputed = 0
    for _ in range(4):
        info = s.apply_delta(*random_delta(rng, g.n, s.dyn, n_ins=10, n_del=3))
        total_recomputed += info["cards_recomputed"]
        assert info["cards_recomputed"] < s.dyn.m      # never the full pass
        gs = G.from_edge_array(g.n, s.dyn.edge_array())
        sk = S.build(gs, kind, **SKETCH_KW) if kind else None
        ref = np.asarray(eng.edge_cardinalities(gs, sk, s.session.plan))
        np.testing.assert_array_equal(
            np.asarray(s.session.edge_cardinalities()), ref)
    assert total_recomputed > 0


def test_refresh_drop_semantics():
    g = base_graph()
    sess = eng.session(g, "bf", storage_budget=0.3)
    _ = sess.edge_cardinalities()
    assert sess.refresh(g) is None                     # carry=None drops cache
    assert sess._edge_cards is None
    assert float(sess.triangle_count()) > 0            # lazily recomputed


def test_stream_stats_do_not_count_dropped_cache_as_carried():
    g = base_graph()
    s = stream_session(g, "bf", **SKETCH_KW)           # no cache warm-up
    info = s.apply_delta([[0, 1], [2, 3]])
    assert info["cards_recomputed"] == 0 and info["cards_carried"] == 0
    assert s.cards_carried == 0


# ---------------------------------------------------------------------------
# end-to-end replay: ≥10 deltas, answers ≡ static session, rebuilds ≪ n
# ---------------------------------------------------------------------------

def test_replay_matches_static_session_every_batch():
    n_batches = 10
    g = G.kronecker(8, 6, seed=4)
    rng = np.random.default_rng(0)
    edges = np.asarray(g.edges)
    order = rng.permutation(edges.shape[0])
    split = int(0.7 * edges.shape[0])
    dyn = DynamicGraph.from_edges(g.n, edges[order[:split]])
    s = StreamSession(dyn, "bf", **SKETCH_KW)
    _ = s.session.edge_cardinalities()
    chunks = np.array_split(edges[order[split:]], n_batches)
    qpairs = rng.integers(0, g.n, size=(32, 2)).astype(np.int32)
    for b in range(n_batches):
        cur = dyn.edge_array()
        dels = cur[rng.choice(cur.shape[0], size=4, replace=False)]
        s.apply_delta(chunks[b], dels)
        gs = G.from_edge_array(g.n, dyn.edge_array())
        static = eng.session(gs, S.build(gs, "bf", **SKETCH_KW),
                             plan=s.session.plan)
        assert float(s.triangle_count()) == float(static.triangle_count())
        np.testing.assert_array_equal(
            np.asarray(s.similarity(qpairs, "jaccard")),
            np.asarray(static.similarity(jnp.asarray(qpairs), "jaccard")))
    # incremental maintenance must have avoided full rebuilds: over the whole
    # replay only deletion-dirty rows were rebuilt, a sliver of n per delta
    assert s.maintainer.rows_rebuilt <= n_batches * 8 < g.n
    assert s.maintainer.rows_incremental > 0


# ---------------------------------------------------------------------------
# batched query server
# ---------------------------------------------------------------------------

def test_server_batched_answers_match_direct():
    g = base_graph(n=100)
    s = stream_session(g, "bf", **SKETCH_KW)
    srv = BatchedQueryServer(s)
    rng = np.random.default_rng(3)
    pairs_a = rng.integers(0, g.n, size=(9, 2)).astype(np.int32)
    pairs_b = rng.integers(0, g.n, size=(23, 2)).astype(np.int32)
    ra = srv.submit_similarity(pairs_a, "jaccard")
    rb = srv.submit_similarity(pairs_b, "common")
    rm = srv.submit_membership(7, np.arange(25))
    rt = srv.submit_triangle_count()
    rl = srv.submit_link_prediction(11, top_k=3)
    assert srv.pending_count() == 5
    res = srv.flush()
    assert srv.pending_count() == 0
    np.testing.assert_array_equal(res[ra].value,
                                  np.asarray(s.similarity(pairs_a, "jaccard")))
    np.testing.assert_array_equal(res[rb].value,
                                  np.asarray(s.similarity(pairs_b, "common")))
    np.testing.assert_array_equal(res[rm].value,
                                  np.asarray(s.membership(7, np.arange(25))))
    assert res[rt].value == float(s.triangle_count())
    assert res[rl].value["candidates"].shape[0] <= 3
    assert all(r.latency_s >= 0 and r.staleness == 0 for r in res.values())


def test_server_staleness_counts_interleaved_deltas():
    g = base_graph()
    s = stream_session(g, "bf", **SKETCH_KW)
    srv = BatchedQueryServer(s)
    rid_old = srv.submit_triangle_count()
    s.apply_delta([[0, 1], [2, 3]])
    s.apply_delta([[4, 5]])
    rid_new = srv.submit_triangle_count()
    res = srv.flush()
    assert res[rid_old].staleness == 2 and res[rid_new].staleness == 0
    stats = srv.stats()
    assert stats["served"] == 2 and stats["flushes"] == 1


def test_server_membership_finds_live_neighbors():
    s = stream_session(base_graph(), "bf", **SKETCH_KW)
    s.apply_delta([[0, 50], [0, 51]])
    srv = BatchedQueryServer(s)
    rid = srv.submit_membership(0, [50, 51])
    got = srv.flush()[rid].value
    assert got.all()                         # BF: no false negatives, ever


# ---------------------------------------------------------------------------
# snapshot / restore through checkpoint.store
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["bf", "kmv"])
def test_checkpoint_roundtrip(tmp_path, kind):
    rng = np.random.default_rng(11)
    s = stream_session(base_graph(), kind,
                       policy=ErrorBudgetPolicy(rel_tolerance=50.0),
                       **SKETCH_KW)
    for _ in range(3):
        s.apply_delta(*random_delta(rng, s.dyn.n, s.dyn))
    path = s.save(str(tmp_path))
    assert "step_" in path
    r = StreamSession.restore(str(tmp_path))
    assert r.version == s.version and r.dyn.m == s.dyn.m
    np.testing.assert_array_equal(r.dyn.edge_keys, s.dyn.edge_keys)
    np.testing.assert_array_equal(r.dyn.adj, s.dyn.adj)
    np.testing.assert_array_equal(np.asarray(r.sketch.data),
                                  np.asarray(s.sketch.data))
    np.testing.assert_array_equal(r.maintainer.dirty, s.maintainer.dirty)
    np.testing.assert_array_equal(r.maintainer.stale, s.maintainer.stale)
    assert float(r.triangle_count()) == float(s.triangle_count())
    # the restored session keeps streaming correctly
    r.apply_delta([[1, 2], [3, 4]])
    r.flush()
    np.testing.assert_array_equal(np.asarray(r.sketch.data),
                                  np.asarray(scratch_sketch(r.dyn, kind).data))


# ---------------------------------------------------------------------------
# satellite: session stats are JSON-serializable
# ---------------------------------------------------------------------------

def test_session_stats_json_serializable():
    sess = eng.session(base_graph(), "bf", storage_budget=0.3)
    blob = json.dumps(sess.stats())
    plan = json.loads(blob)["plan"]
    assert plan["edge_chunk"] > 0 and "use_kernel" in plan
