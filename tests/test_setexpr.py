"""Set-expression compiler (SISA layer): golden bit-identity against the
legacy hand-rolled kernels, compile-cache behavior, deprecation shims, and
the cliques5 workload end-to-end (engine, launch seam, serving tier).

This file is also the ``-W error::DeprecationWarning`` CI gate: the engine
paths exercised here must not touch the deprecated ``bf_intersect`` names.
"""
import itertools
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro import engine as eng
from repro.core import (bounds, five_clique_count, four_clique_count,
                        graph as G, sketches as S, triangle_count)
from repro.core.algorithms import localcluster as LC
from repro.engine import setexpr
from repro.kernels import bf_intersect as legacy
from repro.kernels import ops, ref
from repro.stream import BatchedQueryServer, ErrorBudgetPolicy, stream_session


def _np_popcount(rows: np.ndarray) -> np.ndarray:
    """Reference popcount over the trailing word axis."""
    return np.unpackbits(
        np.ascontiguousarray(rows).view(np.uint8),
        axis=-1).sum(axis=-1).astype(np.int32)


def _pad_rows(x, mult, fill=0):
    pad = (-x.shape[0]) % mult
    return np.concatenate(
        [x, np.full((pad, *x.shape[1:]), fill, x.dtype)], axis=0)


def _pad_cols(x, mult):
    pad = (-x.shape[1]) % mult
    return np.concatenate(
        [x, np.zeros((x.shape[0], pad), x.dtype)], axis=1)


@pytest.fixture(scope="module")
def bloom(rng):
    return jnp.asarray(rng.integers(0, 2**32, size=(60, 10), dtype=np.uint32))


# ---------------------------------------------------------------------------
# golden bit-identity: compiled expressions vs the legacy raw kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,block_e,block_w", [(40, 8, 512), (129, 8, 512),
                                               (3, 1, 512), (21, 8, 4),
                                               (64, 64, 512)])
def test_compiled_and2_gather_matches_legacy(bloom, rng, t, block_e, block_w):
    """Gather-form 2-way AND == the pre-PR block-gather kernel, bit for bit,
    on ragged tuple counts and ragged word axes."""
    n, w = bloom.shape
    edges = rng.integers(0, n, size=(t, 2), dtype=np.int32)
    u, v = setexpr.rows(2)
    ce = setexpr.compile_expr(u & v, block_e=block_e, block_w=block_w)
    got = np.asarray(ce.ones(bloom, jnp.asarray(edges)))
    # drive the private legacy kernel with the pre-PR padding contract
    be = min(block_e, t)
    bw = min(block_w, w)
    want = np.asarray(legacy._edge_impl(
        jnp.asarray(_pad_cols(np.asarray(bloom), bw)),
        jnp.asarray(_pad_rows(edges, be)),
        block_e=be, block_w=bw, interpret=True))[:t]
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        got, np.asarray(ref.bf_edge_intersect(bloom, jnp.asarray(edges))))
    # jnp lowering of the same expression: identical integers
    ce_j = setexpr.compile_expr(u & v, use_kernel=False)
    np.testing.assert_array_equal(
        np.asarray(ce_j.ones(bloom, jnp.asarray(edges))), got)


def test_compiled_and3_gather_matches_legacy(bloom, rng):
    """Gather-form 3-way AND == the pre-PR 3-slab kernel, bit for bit."""
    n, w = bloom.shape
    triples = rng.integers(0, n, size=(37, 3), dtype=np.int32)
    ce = setexpr.compile_expr(setexpr.and_all(*setexpr.rows(3)))
    got = np.asarray(ce.ones(bloom, jnp.asarray(triples)))
    be = min(8, 37)
    want = np.asarray(legacy._edge3_impl(
        bloom, jnp.asarray(_pad_rows(triples, be)),
        block_e=be, block_w=w, interpret=True))[:37]
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        got, np.asarray(ref.bf_edge_intersect3(bloom, jnp.asarray(triples))))


@pytest.mark.parametrize("e,w", [(1, 2), (7, 2), (64, 16), (257, 30)])
def test_compiled_and2_dense_matches_legacy(rng, e, w):
    """Dense-form 2-way AND (the sweep-gating shape) == the pre-PR pairs
    kernel on ragged row counts and odd word widths."""
    a = rng.integers(0, 2**32, size=(e, w), dtype=np.uint32)
    b = rng.integers(0, 2**32, size=(e, w), dtype=np.uint32)
    u, v = setexpr.rows(2)
    ce = setexpr.compile_expr(u & v, block_e=256, block_w=512)
    got = np.asarray(ce.ones_rows(jnp.asarray(a), jnp.asarray(b)))
    be = min(256, e)
    a2 = _pad_cols(_pad_rows(a, be), 2)
    want = np.asarray(legacy._pairs_impl(
        jnp.asarray(a2), jnp.asarray(_pad_cols(_pad_rows(b, be), 2)),
        block_e=be, block_w=min(512, a2.shape[1]), interpret=True))[:e]
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(
        got, np.asarray(ref.bf_intersect_pairs(jnp.asarray(a),
                                               jnp.asarray(b))))
    # jnp lowering agrees too
    ce_j = setexpr.compile_expr(u & v, use_kernel=False)
    np.testing.assert_array_equal(
        np.asarray(ce_j.ones_rows(jnp.asarray(a), jnp.asarray(b))), got)


def test_compiled_or_andnot_nested_match_reference(bloom, rng):
    """OR / ANDNOT / nested trees: kernel and jnp lowerings both equal the
    numpy popcount of the same bitwise formula."""
    n = bloom.shape[0]
    tuples = rng.integers(0, n, size=(33, 3), dtype=np.int32)
    data = np.asarray(bloom)
    ra, rb, rc = (data[tuples[:, i]] for i in range(3))
    u, v, t3 = setexpr.rows(3)
    cases = [
        (u | v, ra | rb),
        (u - v, ra & ~rb),
        ((u & v) | t3, (ra & rb) | rc),
        ((u | v) - t3, (ra | rb) & ~rc),
        (setexpr.or_all(u, v, t3), ra | rb | rc),
    ]
    for expr, rows_np in cases:
        want = _np_popcount(rows_np)
        for use_kernel in (True, False):
            ce = setexpr.compile_expr(expr, use_kernel=use_kernel)
            got = np.asarray(ce.ones(bloom, jnp.asarray(tuples)))
            np.testing.assert_array_equal(got, want, err_msg=repr(expr))


def test_four_way_and_matches_reference(bloom, rng):
    """The cliques5 workhorse (4-way AND) needs no new kernel."""
    n = bloom.shape[0]
    quads = rng.integers(0, n, size=(19, 4), dtype=np.int32)
    data = np.asarray(bloom)
    want = _np_popcount(data[quads[:, 0]] & data[quads[:, 1]]
                        & data[quads[:, 2]] & data[quads[:, 3]])
    ce = setexpr.compile_expr(setexpr.and_all(*setexpr.rows(4)))
    np.testing.assert_array_equal(
        np.asarray(ce.ones(bloom, jnp.asarray(quads))), want)
    plan = eng.EnginePlan(use_kernel=True)
    sk = S.SketchSet(data=bloom, kind="bf", num_hashes=2, k=0, seed=0, n=n)
    np.testing.assert_array_equal(
        np.asarray(eng.tuple_cardinality_ones(sk, jnp.asarray(quads), plan)),
        want)


def test_compiled_expr_edge_cases(bloom):
    """Empty inputs, narrow tuples, wrong dense arity, leafless trees."""
    u, v = setexpr.rows(2)
    ce = setexpr.compile_expr(u & v)
    out = ce.ones(bloom, jnp.zeros((0, 2), jnp.int32))
    assert out.shape == (0,) and out.dtype == jnp.int32
    assert ce.ones_rows(jnp.zeros((0, 4), jnp.uint32),
                        jnp.zeros((0, 4), jnp.uint32)).shape == (0,)
    with pytest.raises(ValueError):
        ce.ones(bloom, jnp.zeros((3, 1), jnp.int32))     # needs column 1
    with pytest.raises(ValueError):
        ce.ones_rows(jnp.zeros((3, 4), jnp.uint32))      # needs 2 operands


def test_sweep_cut_kernel_vs_jnp_bit_identical():
    """The rerouted sweep gating (dense compiled AND) gives bit-identical
    conductance profiles on both lowerings."""
    g = G.kronecker(7, 6, seed=2)
    sk = S.build(g, "bf", 0.5, num_hashes=2, seed=1)
    seeds = np.array([3, 17, 40], np.int32)
    res_j = LC.local_cluster(g, seeds, 0.15, 1e-3, sk,
                             plan=eng.EnginePlan(use_kernel=False))
    res_k = LC.local_cluster(g, seeds, 0.15, 1e-3, sk,
                             plan=eng.EnginePlan(use_kernel=True))
    np.testing.assert_array_equal(np.asarray(res_j.conductance),
                                  np.asarray(res_k.conductance))
    np.testing.assert_array_equal(np.asarray(res_j.order),
                                  np.asarray(res_k.order))


# ---------------------------------------------------------------------------
# compile cache
# ---------------------------------------------------------------------------

def test_compile_cache_keyed_by_structure():
    """Same expression structure + config -> the same compiled object;
    different structure or block shape -> a fresh one."""
    setexpr.cache_clear()
    u, v = setexpr.rows(2)
    c1 = setexpr.compile_expr(u & v)
    c2 = setexpr.compile_expr(setexpr.rows(2)[0] & setexpr.rows(2)[1])
    assert c1 is c2
    assert setexpr.cache_info() == {"size": 1, "hits": 1}
    c3 = setexpr.compile_expr(u & v, block_e=16)
    c4 = setexpr.compile_expr(u | v)
    assert c3 is not c1 and c4 is not c1
    assert setexpr.cache_info()["size"] == 3


def test_expression_structure_and_flattening():
    """Operator sugar flattens chains; keys are canonical nested tuples."""
    u, v, w, x = setexpr.rows(4)
    assert (u & v & w & x).key() == ("and", ("row", 0), ("row", 1),
                                    ("row", 2), ("row", 3))
    assert setexpr.and_all(u & v, w & x).key() == (u & v & w & x).key()
    assert (u | (v | w)).key() == ("or", ("row", 0), ("row", 1), ("row", 2))
    assert ((u & v) - w).key() == ("andnot", ("and", ("row", 0), ("row", 1)),
                                  ("row", 2))
    assert setexpr.expr_slots((x & v) - u) == (0, 1, 3)


# ---------------------------------------------------------------------------
# deprecation shims + clean engine paths
# ---------------------------------------------------------------------------

def test_legacy_kernel_names_warn(bloom, rng):
    """The old public names in bf_intersect still work — and warn."""
    n, w = bloom.shape
    edges = jnp.asarray(rng.integers(0, n, size=(8, 2), dtype=np.int32))
    a = jnp.asarray(rng.integers(0, 2**32, size=(8, 4), dtype=np.uint32))
    with pytest.warns(DeprecationWarning, match="bf_edge_intersect "):
        out = legacy.bf_edge_intersect(bloom, edges, block_e=8, block_w=w,
                                       interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.bf_edge_intersect(bloom, edges)))
    with pytest.warns(DeprecationWarning):
        legacy.bf_intersect_pairs(a, a, block_e=8, block_w=4, interpret=True)
    with pytest.warns(DeprecationWarning):
        legacy.bf_intersect3_pairs(a, a, a, block_e=8, block_w=4,
                                   interpret=True)
    triples = jnp.asarray(rng.integers(0, n, size=(8, 3), dtype=np.int32))
    with pytest.warns(DeprecationWarning):
        legacy.bf_edge_intersect3(bloom, triples, block_e=8, block_w=w,
                                  interpret=True)


def test_engine_paths_free_of_deprecated_entrypoints():
    """Kernel-path TC, 4/5-cliques and sweep cuts must not route through
    the deprecated names (this is what the -W error CI step enforces)."""
    g = G.erdos_renyi(60, 0.15, seed=4)
    sk = S.build(g, "bf", 0.5, num_hashes=2, seed=1)
    plan = eng.EnginePlan(use_kernel=True, degree_order=False)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        float(triangle_count(g, sk, plan=plan))
        float(four_clique_count(g, sk, plan=plan.with_(edge_chunk=64)))
        float(five_clique_count(g, sk, plan=plan.with_(edge_chunk=32)))
        LC.local_cluster(g, np.array([3], np.int32), 0.15, 1e-2, sk,
                         plan=plan)


# ---------------------------------------------------------------------------
# cliques5: exact enumeration, estimator accuracy, path bit-identity
# ---------------------------------------------------------------------------

def _brute_five_cliques(g) -> int:
    """Literal itertools enumeration of 5-cliques."""
    nbrs = {}
    for a, b in np.asarray(g.edges):
        nbrs.setdefault(int(a), set()).add(int(b))
        nbrs.setdefault(int(b), set()).add(int(a))
    count = 0
    for clique in itertools.combinations(sorted(nbrs), 5):
        if all(q in nbrs[p] for p, q in itertools.combinations(clique, 2)):
            count += 1
    return count


@pytest.mark.parametrize("make", [
    lambda: G.erdos_renyi(18, 0.5, seed=3),
    lambda: G.erdos_renyi(25, 0.4, seed=11),
    lambda: G.kronecker(5, 6, seed=2),
])
def test_cliques5_exact_matches_bruteforce(make):
    g = make()
    want = float(_brute_five_cliques(g))
    assert float(five_clique_count(g)) == want
    # chunk-size invariance of the fold
    assert float(five_clique_count(
        g, plan=eng.EnginePlan(edge_chunk=7))) == want


def test_cliques5_bf_estimate_and_path_identity():
    g = G.erdos_renyi(18, 0.5, seed=3)
    want = _brute_five_cliques(g)
    sk = S.build(g, "bf", 4.0, num_hashes=2, seed=1)
    got_k = float(five_clique_count(
        g, sk, plan=eng.EnginePlan(edge_chunk=64, use_kernel=True)))
    got_j = float(five_clique_count(
        g, sk, plan=eng.EnginePlan(edge_chunk=64, use_kernel=False)))
    assert got_k == got_j                     # same compiled expression
    assert abs(got_k - want) / max(want, 1) < 0.35
    # the k-way bound degrades gracefully with k (same Prop IV.1 form)
    assert (bounds.bf_kway_and_mse_bound(5.0, 1024, 2, k=4)
            == bounds.bf_and_mse_bound(5.0, 1024, 2))
    with pytest.raises(ValueError):
        bounds.bf_kway_and_mse_bound(5.0, 1024, 2, k=1)


def test_cliques5_rejects_unsupported_sketch():
    g = G.erdos_renyi(20, 0.3, seed=1)
    sk = S.build(g, "kh", 0.5, seed=1)
    with pytest.raises(ValueError, match="sketch kind"):
        five_clique_count(g, sk)


def test_session_five_clique_count():
    g = G.erdos_renyi(18, 0.5, seed=3)
    sess = eng.session(g, None)
    assert float(sess.five_clique_count()) == float(_brute_five_cliques(g))


# ---------------------------------------------------------------------------
# serving tier: the new query kind caches and invalidates like tc
# ---------------------------------------------------------------------------

def test_server_clique_count_cached_and_invalidated():
    g = G.erdos_renyi(36, 0.25, seed=7)
    st = stream_session(g, "bf", words=4, num_hashes=2, seed=3,
                        policy=ErrorBudgetPolicy(0.0))
    srv = BatchedQueryServer(st, min_batch=8)
    r4 = srv.submit_clique_count(4)
    r5 = srv.submit_clique_count(5)
    out = srv.flush()
    assert out[r4].value == float(st.four_clique_count())
    assert out[r5].value == float(st.five_clique_count())
    # resubmission with no intervening delta is a cache hit, same object
    hits0 = srv.cache.hits
    h5 = srv.submit_clique_count(5)
    assert srv.flush()[h5].value == out[r5].value
    assert srv.cache.hits > hits0
    # whole-graph footprint: any delta invalidates the cached count
    st.apply_delta(np.array([[1, 3]]), np.zeros((0, 2), np.int64))
    r5b = srv.submit_clique_count(5)
    assert srv.flush()[r5b].value == float(st.five_clique_count())
    with pytest.raises(ValueError):
        srv.submit_clique_count(3)


# ---------------------------------------------------------------------------
# public API surface
# ---------------------------------------------------------------------------

def test_engine_api_facade_exports():
    """launch/stream import from repro.engine.api — pin the surface."""
    from repro.engine import api
    for name in ("EnginePlan", "Footprint", "MiningSession", "SetExpr",
                 "compile_expr", "edge_cardinalities", "map_edges",
                 "pair_cardinality_fn", "pow2_bucket", "resolve_plan",
                 "rows", "session", "tuple_cardinality_ones",
                 "wedge_quad_ones"):
        assert hasattr(api, name), name
    for name in ("_sharded_fold", "engine"):
        assert name not in api.__all__


def test_kernel_knobs_are_keyword_only(bloom):
    """Tuning knobs (block_e/block_w/interpret) reject positional use."""
    edges = jnp.zeros((4, 2), jnp.int32)
    with pytest.raises(TypeError):
        ops.bf_edge_intersect(bloom, edges, 8)
    with pytest.raises(TypeError):
        setexpr.compile_expr(setexpr.rows(2)[0] & setexpr.rows(2)[1], 8)
