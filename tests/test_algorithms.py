"""Graph algorithms: exact oracles + estimator accuracy on fixed seeds."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G, sketches as S, exact as X
from repro.core import (triangle_count, four_clique_count, jarvis_patrick,
                        pair_similarity, link_prediction_effectiveness)
from repro.core.algorithms.tc import local_clustering_coefficient


@pytest.fixture(scope="module")
def g():
    return G.erdos_renyi(250, 0.06, seed=11)


@pytest.fixture(scope="module")
def gk():
    return G.kronecker(9, 12, seed=4)


def test_exact_tc_matches_dense_oracle(g):
    assert int(X.exact_triangle_count(g)) == G.triangle_count_dense(g)


def test_exact_tc_chunked_fold(g):
    full = int(X.exact_triangle_count(g))
    chunked = int(X.exact_triangle_count(g, edge_chunk=64))
    assert full == chunked


def test_exact_4clique_matches_bruteforce(g):
    assert int(four_clique_count(g)) == G.four_clique_count_bruteforce(g)


def test_tc_estimators_accuracy(gk):
    tc = int(X.exact_triangle_count(gk))
    for kind, tol in [("bf", 0.8), ("kh", 0.35), ("1h", 0.45)]:
        sk = S.build(gk, kind, storage_budget=0.33, num_hashes=1, seed=2)
        est = float(triangle_count(gk, sk))
        assert abs(est - tc) / tc < tol, (kind, est, tc)


def test_tc_kernel_path_equals_jnp(g):
    sk = S.build(g, "bf", 0.33, num_hashes=2, seed=1)
    a = float(triangle_count(g, sk))
    b = float(triangle_count(g, sk, use_kernel=True))
    assert abs(a - b) < 1e-3


def test_clustering_threshold_monotone(g):
    _, n_lo = jarvis_patrick(g, None, "common", 1.0)
    _, n_hi = jarvis_patrick(g, None, "common", 6.0)
    # higher threshold keeps fewer edges -> at least as many clusters
    assert int(n_hi) >= int(n_lo)


def test_clustering_sketch_count_within_paper_band():
    """Cluster-count ratio vs exact stays inside the paper's own plotted
    band (Fig. 7 caps relative cluster counts at 10; threshold clustering is
    the documented high-variance case of the AND estimator, §VIII-C)."""
    gp = G.random_bipartite_community(300, 4, 0.25, 0.002, seed=5)
    _, n_exact = jarvis_patrick(gp, None, "jaccard", 0.05)
    for kind, b in [("bf", 2), ("kh", 0)]:
        sk = S.build(gp, kind, 0.5, num_hashes=max(b, 1), seed=3)
        _, n_sk = jarvis_patrick(gp, sk, "jaccard", 0.05)
        hi, lo = max(int(n_sk), int(n_exact)), max(min(int(n_sk), int(n_exact)), 1)
        assert hi / lo < 10.0, (kind, int(n_exact), int(n_sk))


def test_clustering_planted_partition():
    g = G.random_bipartite_community(300, 4, 0.25, 0.002, seed=5)
    labels, num = jarvis_patrick(g, None, "common", 2.0)
    # strong communities: far fewer clusters than vertices
    assert int(num) < g.n // 3


def test_similarity_measures_exact(g):
    pairs = g.edges[:64]
    du = np.asarray(g.deg)[np.asarray(pairs)[:, 0]].astype(float)
    dv = np.asarray(g.deg)[np.asarray(pairs)[:, 1]].astype(float)
    inter = np.asarray(X.exact_pair_cardinalities(g, pairs)).astype(float)
    jac = np.asarray(pair_similarity(g, pairs, "jaccard"))
    np.testing.assert_allclose(jac, inter / np.maximum(du + dv - inter, 1.0), rtol=1e-5)
    tot = np.asarray(pair_similarity(g, pairs, "total"))
    np.testing.assert_allclose(tot, du + dv - inter, rtol=1e-5)


def test_adamic_adar_bf_vs_exact(g):
    pairs = g.edges[:64]
    aa_exact = np.asarray(pair_similarity(g, pairs, "adamic_adar"))
    sk = S.build(g, "bf", 0.5, num_hashes=2, seed=3)
    aa_bf = np.asarray(pair_similarity(g, pairs, "adamic_adar", sk))
    # BF membership has no false negatives: BF estimate >= exact - tiny
    assert np.all(aa_bf >= aa_exact - 1e-4)
    # and inflation stays bounded on this budget
    assert np.mean(aa_bf - aa_exact) < 2.0


def test_local_clustering_coefficient(g):
    cc = np.asarray(local_clustering_coefficient(g))
    assert cc.shape == (g.n,)
    assert np.all(cc >= 0) and np.all(cc <= 1.0 + 1e-6)


def test_link_prediction_beats_random(gk):
    ef = link_prediction_effectiveness(gk, "common", removed_fraction=0.05, seed=3)
    # wedge-candidate common-neighbors must beat uniform-random guessing
    assert ef > 0.01


def test_link_prediction_with_sketch(gk):
    ef = link_prediction_effectiveness(gk, "common", removed_fraction=0.05,
                                       sketch_kind="bf", storage_budget=0.5, seed=3)
    assert ef > 0.005
