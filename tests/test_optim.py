"""Optimizers, schedules, gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import AdamW, Adafactor, cosine_warmup, error_feedback_compress
from repro.optim.compress import quantize_int8, dequantize_int8


def _quadratic_descends(opt, steps=120, tol=1e-2):
    target = {"w": jnp.asarray([3.0, -2.0, 0.5]), "b": jnp.asarray(1.5)}
    params = {"w": jnp.zeros(3), "b": jnp.zeros(())}
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target["w"]) ** 2) + (p["b"] - target["b"]) ** 2

    for _ in range(steps):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    return float(loss(params))


def test_adamw_descends():
    assert _quadratic_descends(AdamW(learning_rate=0.1, weight_decay=0.0)) < 1e-2


def test_adamw_bf16_master():
    opt = AdamW(learning_rate=0.05, weight_decay=0.0, keep_master=True)
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(4, jnp.bfloat16)}
    new_p, state = opt.update(g, state, params)
    assert new_p["w"].dtype == jnp.bfloat16


def test_adafactor_descends():
    assert _quadratic_descends(Adafactor(learning_rate=0.3, weight_decay=0.0)) < 0.3


def test_adafactor_factored_state_small():
    opt = Adafactor()
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 4))}
    st = opt.init(params)
    assert set(st["v"]["big"].keys()) == {"vr", "vc"}
    assert st["v"]["big"]["vr"].shape == (256,)
    assert set(st["v"]["small"].keys()) == {"v"}
    # factored state is tiny vs AdamW's 2x full
    n_full = 2 * 256 * 512
    n_fact = 256 + 512
    assert n_fact < n_full / 100


def test_schedule():
    sch = cosine_warmup(1e-3, 10, 100)
    assert float(sch(jnp.asarray(0))) == 0.0
    assert abs(float(sch(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(sch(jnp.asarray(100))) < 2e-4


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=1000).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF compression: the *accumulated* update converges to the true sum."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    applied_sum = np.zeros(64, np.float32)
    err = None
    for _ in range(200):
        g = {"g": jnp.asarray(rng.normal(size=64).astype(np.float32))}
        true_sum += np.asarray(g["g"])
        dec, err = error_feedback_compress(g, err)
        applied_sum += np.asarray(dec["g"])
    resid = np.abs(applied_sum - true_sum)
    # residual equals the current error buffer -> bounded by one quant step
    assert resid.max() < 0.5


def test_compressed_training_tracks_uncompressed():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    target = jnp.asarray([3.0, -2.0, 0.5])

    def run(compress):
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        err = None
        for _ in range(80):
            grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            if compress:
                grads, err = error_feedback_compress(grads, err)
            params, state = opt.update(grads, state, params)
        return float(jnp.sum((params["w"] - target) ** 2))

    assert run(True) < 1e-2 and run(False) < 1e-2
