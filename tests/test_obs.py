"""Observability layer: tracer, metrics registry, accuracy telemetry,
stat-facade equivalence, and the bench record schema."""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core import sketches as SK
from repro.obs import accuracy, trace
from repro.obs.metrics import REGISTRY, MetricsRegistry
from repro.stream.dynamic_graph import TrafficMeter

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


@pytest.fixture
def tracing():
    """Enable the global tracer for one test, restoring the disabled state."""
    trace.enable()
    trace.clear()
    yield
    trace.disable()
    trace.clear()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_span_nesting_records_parent_and_depth(tracing):
    with trace.span("outer", a=1):
        with trace.span("inner") as sp:
            sp.set(b=2)
    evs = trace.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]  # exit order
    inner, outer = evs
    assert inner["parent"] == "outer" and inner["depth"] == 1
    assert outer["parent"] is None and outer["depth"] == 0
    assert outer["args"] == {"a": 1}
    assert inner["args"] == {"b": 2}
    assert inner["dur"] <= outer["dur"]
    assert inner["ts"] >= outer["ts"]


def test_disabled_tracer_returns_shared_null_span():
    assert not trace.enabled()
    s1 = trace.span("x", huge=1)
    s2 = trace.span("y")
    assert s1 is s2                      # one shared no-op object
    with s1 as sp:
        assert sp.fence(42) == 42        # passthrough, no blocking
        sp.set(k=1)
    assert trace.events() == []


def test_ring_buffer_drops_oldest():
    t = trace.Tracer(capacity=4)
    t.enable()
    for i in range(10):
        with t.span(f"s{i}"):
            pass
    evs = t.events()
    assert len(evs) == 4
    assert [e["name"] for e in evs] == ["s6", "s7", "s8", "s9"]
    assert t.recorded == 10


def test_span_fence_blocks_device_value(tracing):
    with trace.span("jit") as sp:
        out = sp.fence(jnp.arange(8) * 2)
    assert out.sum() == 56
    assert trace.events()[0]["name"] == "jit"


def test_span_records_error_flag(tracing):
    with pytest.raises(ValueError):
        with trace.span("boom"):
            raise ValueError("x")
    ev = trace.events()[0]
    assert ev["name"] == "boom" and ev["error"] is True


def test_traced_decorator(tracing):
    @trace.traced("deco.fn", tag=3)
    def f(x):
        return x + 1

    assert f(1) == 2
    ev = trace.events()[0]
    assert ev["name"] == "deco.fn" and ev["args"] == {"tag": 3}


def test_export_chrome_trace_schema(tmp_path, tracing):
    with trace.span("parent", n=5):
        with trace.span("child"):
            pass
    path = tmp_path / "t.json"
    doc = trace.export(str(path))
    ondisk = json.loads(path.read_text())
    assert ondisk == doc
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["recorded"] == 2
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X" and ev["cat"] == "repro"
        assert set(ev) >= {"name", "ts", "dur", "pid", "tid", "args"}
        assert "depth" in ev["args"] and "parent" in ev["args"]
    child = next(e for e in doc["traceEvents"] if e["name"] == "child")
    assert child["args"]["parent"] == "parent"


def test_aggregate_counts_and_totals(tracing):
    for _ in range(3):
        with trace.span("a"):
            pass
    with trace.span("b"):
        pass
    agg = trace.aggregate()
    assert agg["a"]["count"] == 3 and agg["b"]["count"] == 1
    assert agg["a"]["total_s"] >= 0
    assert agg["a"]["mean_s"] == pytest.approx(agg["a"]["total_s"] / 3)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_instrument_identity_and_values():
    reg = MetricsRegistry()
    c = reg.counter("hits", kind="bf")
    assert reg.counter("hits", kind="bf") is c       # same (name, labels)
    assert reg.counter("hits", kind="kh") is not c
    c.inc()
    c.inc(4)
    assert reg.value("hits", kind="bf") == 5
    g = reg.gauge("fill")
    g.set(0.25)
    g.add(0.5)
    assert reg.value("fill") == 0.75
    assert reg.value("never_created") is None


def test_registry_snapshot_flat_names_and_histograms():
    reg = MetricsRegistry()
    reg.counter("served", kind="tc").inc(2)
    reg.gauge("fill").set(0.5)
    h = reg.histogram("lat", window=8)
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["served{kind=tc}"] == 2
    assert snap["fill"] == 0.5
    assert snap["lat_count"] == 4
    assert snap["lat_mean"] == pytest.approx(2.5)
    assert snap["lat_p95"] == pytest.approx(np.percentile([1, 2, 3, 4], 95))
    assert snap["lat_max"] == 4.0
    assert json.loads(json.dumps(snap)) == snap      # JSON-serializable


def test_histogram_window_and_labelled_enumeration():
    reg = MetricsRegistry()
    h = reg.histogram("lat", window=3)
    for v in range(10):
        h.observe(float(v))
    assert h.count == 10
    np.testing.assert_array_equal(h.values(), [7.0, 8.0, 9.0])
    reg.counter("served").inc()
    reg.counter("served", kind="tc").inc(3)
    by = reg.labelled("served")
    assert {dict(k).get("kind") for k in by} == {None, "tc"}
    reg.reset()
    assert reg.snapshot() == {}


def test_concurrent_counter_increments_lose_nothing():
    # `self._value += 1` is several bytecodes; without the per-instrument
    # lock, contending threads interleave mid-RMW and increments vanish
    # (this test fails on the unlocked implementation)
    import threading
    reg = MetricsRegistry()
    per_thread, n_threads = 20000, 8

    def hammer():
        # fetch through the registry each time: exercises _get's lock too
        c = reg.counter("served", kind="race")
        g = reg.gauge("level")
        for _ in range(per_thread):
            c.inc()
            g.add(1.0)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.value("served", kind="race") == per_thread * n_threads
    assert reg.value("level") == pytest.approx(per_thread * n_threads)


def test_concurrent_histogram_observe_and_values():
    # deque iteration while another thread appends past maxlen raises
    # RuntimeError unless observe/values share the instrument lock; count
    # is an unlocked += without the fix and drops updates
    import threading
    reg = MetricsRegistry()
    h = reg.histogram("lat", window=64)
    per_thread, n_threads = 5000, 4
    errors = []

    def observe():
        try:
            for i in range(per_thread):
                h.observe(float(i))
        except RuntimeError as exc:     # pragma: no cover - the regression
            errors.append(exc)

    def read():
        try:
            for _ in range(2000):
                vals = h.values()
                assert vals.size <= 64
        except RuntimeError as exc:     # pragma: no cover - the regression
            errors.append(exc)

    threads = ([threading.Thread(target=observe) for _ in range(n_threads)]
               + [threading.Thread(target=read)])
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert h.count == per_thread * n_threads


# ---------------------------------------------------------------------------
# accuracy telemetry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["bf", "kh", "1h", "kmv"])
def test_fill_ratio_in_unit_interval(kind):
    g = G.kronecker(7, 8, seed=0)
    sk = SK.build(g, kind, storage_budget=0.5, num_hashes=2, seed=0)
    r = accuracy.fill_ratio(sk)
    assert 0.0 < r <= 1.0
    reg = MetricsRegistry()
    assert accuracy.record_fill(sk, reg) == r
    assert reg.value("sketch_fill_ratio", kind=kind) == r


@pytest.mark.parametrize("kind", ["bf", "kh"])
def test_record_pair_error_gauges(kind):
    g = G.kronecker(7, 8, seed=0)
    sk = SK.build(g, kind, storage_budget=0.5, num_hashes=2, seed=0)
    deg = np.asarray(g.deg)
    e = np.asarray(g.edges)[:32]
    du, dv = deg[e[:, 0]], deg[e[:, 1]]
    cards = np.minimum(du, dv).astype(np.float64)
    reg = MetricsRegistry()
    out = accuracy.record_pair_error(sk, cards, du, dv, reg)
    assert out["rmse"] > 0.0 and out["rel"] > 0.0
    assert reg.value("accuracy_err_rmse", kind=kind) == out["rmse"]
    assert reg.value("accuracy_err_rel", kind=kind) == out["rel"]
    # empty batch records nothing and returns zeros
    assert accuracy.record_pair_error(sk, [], [], [], MetricsRegistry()) == \
        {"rmse": 0.0, "rel": 0.0}


def test_record_maintenance_mirrors_stats():
    reg = MetricsRegistry()
    stats = {"kind": "bf", "rows_dirty": 3, "stale_total": 1.5,
             "rows_rebuilt": 7, "rows_incremental": 20, "deltas_applied": 4}
    accuracy.record_maintenance(stats, reg)
    assert reg.value("sketch_rows_dirty", kind="bf") == 3.0
    assert reg.value("sketch_stale_total", kind="bf") == 1.5
    assert reg.value("sketch_rows_rebuilt", kind="bf") == 7
    assert reg.value("sketch_rows_incremental", kind="bf") == 20
    assert reg.value("sketch_deltas_applied", kind="bf") == 4
    # set-not-inc: re-recording the same stats must not double
    accuracy.record_maintenance(stats, reg)
    assert reg.value("sketch_rows_rebuilt", kind="bf") == 7


# ---------------------------------------------------------------------------
# stat facades as registry views
# ---------------------------------------------------------------------------

def test_traffic_meter_is_a_registry_view():
    tm = TrafficMeter()
    tm.put(np.zeros(100, np.int32), init=True)       # 400 bytes init
    tm.begin_delta()
    tm.put(np.zeros(10, np.int32))                   # 40 bytes delta
    tm.put(np.zeros(5, np.int32))                    # +20
    tm.commit_step()
    assert tm.bytes_init == 400
    assert tm.bytes_delta == 60
    assert tm.bytes_total == 60
    assert tm.steps == 1
    assert tm.stats() == {"bytes_init": 400, "bytes_total": 60,
                          "bytes_last_delta": 60, "bytes_per_delta_mean": 60.0,
                          "steps": 1}
    # the same numbers, straight from the backing registry
    assert tm.registry.value("traffic_bytes", path="init") == 400
    assert tm.registry.value("traffic_bytes", path="delta") == 60
    assert tm.registry.value("traffic_bytes_last_delta") == 60
    assert tm.registry.value("traffic_steps") == 1
    tm.begin_delta()
    assert tm.bytes_delta == 0 and tm.bytes_total == 60
    # meters do not share registries (concurrent sessions stay isolated)
    assert TrafficMeter().bytes_init == 0


def test_setexpr_compile_cache_counters():
    from repro.engine import setexpr

    setexpr.cache_clear()
    hits0 = REGISTRY.counter("setexpr_compile_total", result="hit").value
    miss0 = REGISTRY.counter("setexpr_compile_total", result="miss").value
    u, v, w = setexpr.Row(0), setexpr.Row(1), setexpr.Row(2)
    setexpr.compile_expr((u & v) - w)
    setexpr.compile_expr((u & v) - w)
    setexpr.compile_expr((u & v) - w)
    assert REGISTRY.counter("setexpr_compile_total",
                            result="miss").value == miss0 + 1
    assert REGISTRY.counter("setexpr_compile_total",
                            result="hit").value == hits0 + 2


# ---------------------------------------------------------------------------
# live roofline wiring
# ---------------------------------------------------------------------------

def test_record_roofline_from_compiled_fn():
    from repro.analysis import live

    a = jnp.ones((64, 64), jnp.float32)
    fn = jax.jit(lambda: a @ a).lower().compile()
    reg = MetricsRegistry()
    out = live.record_roofline("matmul", fn, wall_s=1e-3, registry=reg)
    assert out["flops"] > 0
    assert out["bound_s"] > 0
    assert out["fraction"] == pytest.approx(out["bound_s"] / 1e-3)
    assert reg.value("roofline_fraction", op="matmul") == out["fraction"]
    assert reg.value("roofline_bound_s", op="matmul") == out["bound_s"]


# ---------------------------------------------------------------------------
# bench record schema (benchmarks.common)
# ---------------------------------------------------------------------------

def test_bench_emit_schema_and_derived_parsing(capsys):
    from benchmarks import common

    common.reset_records()
    common.emit("bench_x", 1500.0,
                "speedup=2.50x;rows=128;label=abc;flag")
    common.emit("bench_y", 10.0)
    assert [r["name"] for r in common.RECORDS] == ["bench_x", "bench_y"]
    rec = common.RECORDS[0]
    assert set(rec) == {"name", "wall_s", "metrics"}
    assert rec["wall_s"] == pytest.approx(1.5e-3)
    assert rec["metrics"] == {"speedup": 2.5, "rows": 128.0,
                              "label": "abc", "flag": True}
    assert common.RECORDS[1]["metrics"] == {}
    assert json.loads(json.dumps(common.RECORDS)) == common.RECORDS
    common.reset_records()
    assert common.RECORDS == [] and common.ROWS == []
    out = capsys.readouterr().out
    assert "bench_x,1500.0,speedup=2.50x;rows=128;label=abc;flag" in out


def test_dress_rehearsal_marks_warmup_span(tracing):
    from benchmarks import common

    out = common.dress_rehearsal(lambda: jnp.arange(4).sum())
    assert int(out) == 6
    names = [e["name"] for e in trace.events()]
    assert "bench.warmup" in names
