"""Sparse-frontier PPR push: equivalence, spill, streaming, and memory.

The battery behind docs/ARCHITECTURE.md invariant 10: the capped ``[S, cap]``
sparse push must agree with the dense ``[S, n]`` oracle within the ACL bound
(in practice bit-for-bit on these graphs), sweep conductance profiles must be
bit-identical on the shared support, overflow must *spill* to the dense path
(slower, never wrong), streamed sparse answers must match a fresh static
session, and the buffers must scale with ``S/(alpha·eps)`` — never ``S·n``.
"""
import functools
import math
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro import engine as ENG
from repro.core import graph as G, sketches as SK
from repro.core.algorithms import localcluster as LC
from repro.obs import metrics as obs_metrics
from repro.stream import BatchedQueryServer, DynamicGraph, StreamSession

ALPHA = 0.15
# explicit @settings pins override any loaded hypothesis profile, so the
# nightly raise must come from the env var directly (same contract as
# tests/test_stream.py)
N_EXAMPLES = 25 if os.environ.get("HYPOTHESIS_PROFILE") == "nightly" else 5


@pytest.fixture(scope="module")
def kron():
    return _kron()


@functools.lru_cache(maxsize=None)
def _kron():
    # plain cached builder, not a fixture: @given-wrapped properties can't
    # take fixtures under the fallback shim (zero-arg wrapper)
    return G.kronecker(8, 8, seed=1)          # n = 256


def _dense(graph, seeds, eps, **kw):
    return LC.local_cluster(graph, seeds, ALPHA, eps,
                            frontier_mode="dense", **kw)


def _sparse(graph, seeds, eps, **kw):
    return LC.local_cluster(graph, seeds, ALPHA, eps,
                            frontier_mode="sparse", **kw)


def _assert_profiles_match(res_d, res_s):
    """Dense/sparse sweep agreement: identical order on the shared prefix
    width, bit-identical conductance wherever the orders agree."""
    k = min(res_d.order.shape[1], res_s.order.shape[1])
    ord_d = np.asarray(res_d.order)[:, :k]
    ord_s = np.asarray(res_s.order)[:, :k]
    np.testing.assert_array_equal(ord_d, ord_s)
    np.testing.assert_array_equal(np.asarray(res_d.conductance)[:, :k],
                                  np.asarray(res_s.conductance)[:, :k])
    np.testing.assert_array_equal(np.asarray(res_d.support),
                                  np.asarray(res_s.support))


# ---------------------------------------------------------------------------
# sparse == dense (hypothesis-driven)
# ---------------------------------------------------------------------------

@settings(max_examples=N_EXAMPLES, deadline=None)
@given(gseed=st.integers(0, 3), sseed=st.integers(0, 6),
       eps_i=st.integers(0, 2))
def test_sparse_push_matches_dense_fuzz(gseed, sseed, eps_i):
    g = G.erdos_renyi(96, 0.06, seed=gseed)   # one shape class per example
    eps = (2e-2, 8e-3, 3e-3)[eps_i]
    rng = np.random.default_rng(sseed)
    seeds = rng.integers(0, g.n, size=4).astype(np.int32)
    p, r, it_d = LC.ppr_push(g, seeds, ALPHA, eps)
    fr = LC.ppr_push_sparse(g, seeds, ALPHA, eps)
    assert not bool(fr.overflowed)
    assert int(fr.iterations) == int(it_d)
    pd, rd = fr.densify()
    # within the ACL slack both are valid answers; in practice the sparse
    # merge reproduces the dense scatter-adds to float32 round-off
    np.testing.assert_allclose(np.asarray(pd), np.asarray(p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(rd), np.asarray(r), atol=1e-6)
    # identical support sets, straight from the index buffer
    dense_sup = (np.asarray(p) > 0) | (np.asarray(r) > 0)
    sparse_sup = (np.asarray(pd) > 0) | (np.asarray(rd) > 0)
    np.testing.assert_array_equal(dense_sup, sparse_sup)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(sseed=st.integers(0, 8))
def test_sweep_profiles_bit_identical_on_shared_support(sseed):
    kron = _kron()
    rng = np.random.default_rng(sseed)
    seeds = rng.integers(0, kron.n, size=3).astype(np.int32)
    eps = 5e-3
    res_d = _dense(kron, seeds, eps)
    res_s = _sparse(kron, seeds, eps)
    assert res_s.frontier is not None and not res_s.spilled
    assert res_d.frontier is None
    _assert_profiles_match(res_d, res_s)
    np.testing.assert_array_equal(np.asarray(res_d.best_conductance),
                                  np.asarray(res_s.best_conductance))


def test_sparse_sweep_with_sketch_is_bit_identical_to_dense_sketch(kron):
    # the sketch-gated increments read only (order, deg, adj, sketch) — the
    # prefix-OR estimator is untouched by the frontier layout
    seeds = np.array([3, 17, 101], np.int32)
    sk = SK.build(kron, "bf", storage_budget=2.0)
    res_d = _dense(kron, seeds, 5e-3, sketch=sk)
    res_s = _sparse(kron, seeds, 5e-3, sketch=sk)
    _assert_profiles_match(res_d, res_s)


def test_sparse_acl_invariant_vs_power_iteration(kron):
    eps = 2e-3
    seeds = np.array([3, 17], np.int32)
    fr = LC.ppr_push_sparse(kron, seeds, ALPHA, eps, max_iters=500)
    assert not bool(fr.overflowed)
    p, r = fr.densify()
    ref = LC.ppr_power_iteration(kron, seeds, ALPHA, iters=400)
    err = np.asarray(ref) - np.asarray(p)
    bound = eps * np.asarray(kron.deg, np.float64)[None, :] + 1e-4
    assert (err <= bound).all() and (err >= -1e-4).all()
    thresh = eps * np.maximum(np.asarray(kron.deg, np.float64), 1.0)
    assert (np.asarray(r) < thresh[None, :] + 1e-7).all()


def test_sparse_footprint_matches_dense(kron):
    seeds = np.array([3, 200], np.int32)
    res_d = _dense(kron, seeds, 5e-3)
    res_s = _sparse(kron, seeds, 5e-3)
    for s in range(len(seeds)):
        fp_d, fp_s = res_d.footprint(s), res_s.footprint(s)
        np.testing.assert_array_equal(fp_d, fp_s)
        assert (np.diff(fp_s) > 0).all()          # sorted, duplicate-free


# ---------------------------------------------------------------------------
# overflow spill: perf event, never a correctness event
# ---------------------------------------------------------------------------

def test_overflow_spills_to_dense(kron):
    seeds = np.array([3, 17, 101], np.int32)
    fr = LC.ppr_push_sparse(kron, seeds, ALPHA, 1e-3, frontier_cap=4)
    assert bool(fr.overflowed)

    spills_before = obs_metrics.REGISTRY.counter("ppr.spill").value
    res_s = _sparse(kron, seeds, 1e-3, frontier_cap=4)
    assert res_s.spilled and res_s.frontier is None
    assert res_s.ppr is not None                  # dense fallback ran
    assert obs_metrics.REGISTRY.counter("ppr.spill").value \
        == spills_before + 1
    # the spilled answer IS the dense answer, bit for bit
    res_d = _dense(kron, seeds, 1e-3)
    np.testing.assert_array_equal(np.asarray(res_d.order),
                                  np.asarray(res_s.order))
    np.testing.assert_array_equal(np.asarray(res_d.conductance),
                                  np.asarray(res_s.conductance))
    for s in range(len(seeds)):
        np.testing.assert_array_equal(res_d.footprint(s), res_s.footprint(s))


def test_auto_mode_selects_by_cap_vs_n(kron):
    # tight eps on a small graph: the ACL cap rivals n, auto must go dense
    assert LC.resolve_frontier_mode(
        ENG.EnginePlan(), kron.n, ALPHA, 1e-4) == "dense"
    # loose eps on a big n: cap is far below n, auto must go sparse
    assert LC.resolve_frontier_mode(
        ENG.EnginePlan(), 1 << 20, ALPHA, 3e-2) == "sparse"
    with pytest.raises(ValueError):
        LC.resolve_frontier_mode(
            ENG.EnginePlan(frontier_mode="bogus"), kron.n, ALPHA, 1e-2)
    res = LC.local_cluster(kron, np.array([3], np.int32), ALPHA, 1e-4)
    assert res.frontier is None and not res.spilled   # auto stayed dense


# ---------------------------------------------------------------------------
# streaming: sparse answers over deltas == fresh static session
# ---------------------------------------------------------------------------

def test_stream_sparse_localcluster_matches_static(kron):
    rng = np.random.default_rng(7)
    edges = np.asarray(kron.edges)
    keep = rng.permutation(edges.shape[0])
    initial, arriving = edges[keep[:-200]], edges[keep[-200:]]
    sess = StreamSession(DynamicGraph.from_edges(kron.n, initial), kind="bf",
                         storage_budget=1.0)
    seeds = np.array([3, 17, 101], np.int32)
    kw = dict(frontier_mode="sparse", frontier_cap=256)
    sess.apply_delta(inserts=arriving[:120])
    mid = sess.local_cluster(seeds, ALPHA, 5e-3, **kw)     # interleaved query
    assert mid.frontier is not None
    sess.apply_delta(inserts=arriving[120:],
                     deletes=initial[rng.choice(initial.shape[0], 15,
                                                replace=False)])
    res_stream = sess.local_cluster(seeds, ALPHA, 5e-3, **kw)
    assert res_stream.frontier is not None and not res_stream.spilled

    gs = G.from_edge_array(sess.dyn.n, sess.dyn.edge_array())
    mt = sess.maintainer
    sk = SK.build(gs, mt.kind, words=mt.words, num_hashes=mt.num_hashes,
                  seed=mt.seed)
    res_static = ENG.session(gs, sk, plan=sess.session.plan).local_cluster(
        seeds, ALPHA, 5e-3, **kw)
    np.testing.assert_array_equal(np.asarray(res_stream.order),
                                  np.asarray(res_static.order))
    np.testing.assert_array_equal(np.asarray(res_stream.conductance),
                                  np.asarray(res_static.conductance))
    np.testing.assert_array_equal(np.asarray(res_stream.best_conductance),
                                  np.asarray(res_static.best_conductance))
    np.testing.assert_array_equal(np.asarray(res_stream.frontier.idx),
                                  np.asarray(res_static.frontier.idx))


def test_server_serves_sparse_localcluster(kron):
    sess = StreamSession(DynamicGraph.from_graph(kron), kind="bf",
                         storage_budget=1.0, frontier_mode="sparse",
                         frontier_cap=256)
    srv = BatchedQueryServer(sess)
    rids = [srv.submit_local_cluster(s, eps=5e-3) for s in (3, 17, 101)]
    out = srv.flush()
    direct = sess.local_cluster(np.array([3, 17, 101], np.int32), ALPHA,
                                5e-3)
    for i, rid in enumerate(rids):
        val = out[rid].value
        assert val["size"] == int(direct.best_size[i])
        np.testing.assert_array_equal(val["members"], direct.members(i))


# ---------------------------------------------------------------------------
# memory: O(S/(alpha·eps)) buffers, never O(S·n)
# ---------------------------------------------------------------------------

def test_memory_scales_with_support_bound_not_n():
    eps = 5e-2
    bound = math.ceil(1.0 / (ALPHA * eps))               # ACL support bound
    seeds = np.array([1, 2, 3], np.int32)
    caps, small_n = [], None
    for scale in (8, 10):                                # n = 256, 1024
        g = G.kronecker(scale, 6, seed=2)
        fr = LC.ppr_push_sparse(g, seeds, ALPHA, eps)
        assert not bool(fr.overflowed)
        caps.append(fr.cap)
        # pow2 bucketing costs at most 2x over the analytic bound
        assert fr.cap <= 2 * bound
        # peak residual-buffer bytes: exactly S·cap floats, independent of n
        assert fr.r.nbytes == seeds.size * fr.cap * 4
        assert fr.p.nbytes == seeds.size * fr.cap * 4
        small_n = small_n or g.n
    assert caps[0] == caps[1]                 # grew n 4x, buffers unchanged
    # and the dense residual it replaces is strictly O(S·n)
    g = G.kronecker(10, 6, seed=2)
    _, r_dense, _ = LC.ppr_push(g, seeds, ALPHA, eps)
    assert r_dense.nbytes == seeds.size * g.n * 4
    assert r_dense.nbytes >= 4 * fr.r.nbytes


def test_frontier_cap_for_clamps_and_buckets():
    assert LC.frontier_cap_for(0.15, 5e-2, n=1 << 20) == 256
    assert LC.frontier_cap_for(0.15, 5e-2, n=64) == 64       # pow2(n) clamp
    assert LC.frontier_cap_for(0.15, 1e-2, n=1 << 20, override=100) == 128
    assert LC.frontier_cap_for(0.5, 0.5, n=1 << 20) == 4     # lo clamp ≥ 2
