import os
import sys

# Make src/ importable without install; keep the default single CPU device
# (the dry-run driver sets its own device count in a separate process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# Hypothesis profiles: CI's nightly job exports HYPOTHESIS_PROFILE=nightly.
# Tests that pin explicit @settings raise their counts by reading the env
# var themselves (pins override profiles); this registration covers any
# future unpinned @given property and keeps newer hypothesis versions (which
# auto-load the profile named by the env var) from failing on an
# unregistered name.
try:
    from hypothesis import settings as _hsettings

    _hsettings.register_profile("nightly", max_examples=100, deadline=None)
    try:
        if os.environ.get("HYPOTHESIS_PROFILE"):
            _hsettings.load_profile(os.environ["HYPOTHESIS_PROFILE"])
    except Exception:
        # an unregistered profile name from the developer's shell must not
        # fail collection of the whole suite — keep the default profile
        pass
except ImportError:
    pass


def pytest_addoption(parser):
    """Register the `cov_ratchet` ini key (nightly coverage floor).

    The value itself is consumed by CI's nightly job, which greps it out of
    pytest.ini and passes it as --cov-fail-under; registering it here keeps
    local pytest runs from warning about an unknown ini option.
    """
    parser.addini(
        "cov_ratchet",
        "nightly coverage ratchet percentage (source of --cov-fail-under)",
        default="0",
    )


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_collection_modifyitems(config, items):
    """Auto-skip @pytest.mark.tpu tests when no TPU backend is present."""
    import jax

    if jax.default_backend() == "tpu":
        return
    skip_tpu = pytest.mark.skip(reason="requires a TPU backend")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)
