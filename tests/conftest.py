import os
import sys

# Make src/ importable without install; keep the default single CPU device
# (the dry-run driver sets its own device count in a separate process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
