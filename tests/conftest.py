import os
import sys

# Make src/ importable without install; keep the default single CPU device
# (the dry-run driver sets its own device count in a separate process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_collection_modifyitems(config, items):
    """Auto-skip @pytest.mark.tpu tests when no TPU backend is present."""
    import jax

    if jax.default_backend() == "tpu":
        return
    skip_tpu = pytest.mark.skip(reason="requires a TPU backend")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)
