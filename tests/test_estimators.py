"""Estimator correctness + property-based accuracy/bound tests (hypothesis).

Falls back to the deterministic replay shim in `_hypothesis_fallback` when
hypothesis is not installed, so the module always collects; CI installs the
real hypothesis via requirements-dev.txt.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # minimal environments
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import bounds as B
from repro.core import estimators as E
from repro.core.hashing import np_hash_u32
from repro.core.sketches import PAD_HASH, KMV_PAD, pack_bits


# ---------------------------------------------------------------------------
# helpers: build sketches of *arbitrary sets* (paper §IV works on any sets)
# ---------------------------------------------------------------------------

def bf_of(elems, words, b, seed=0):
    bits = np.zeros(words * 32, dtype=bool)
    for i in range(b):
        pos = np_hash_u32(np.asarray(list(elems), np.uint32),
                          (i + seed * 0x9E3779B9) & 0xFFFFFFFF) % (words * 32)
        bits[pos] = True
    return jnp.asarray(pack_bits(jnp.asarray(bits))[None])  # [1, words]


def khash_of(elems, k, universe, seed=0):
    elems = np.asarray(sorted(elems), np.uint32)
    out = np.full(k, universe, np.int32)
    for i in range(k):
        h = np_hash_u32(elems, (i + seed * 0x9E3779B9) & 0xFFFFFFFF)
        out[i] = elems[np.argmin(h)]
    return jnp.asarray(out[None])


def sets_strategy():
    return st.lists(st.integers(0, 4999), min_size=1, max_size=400,
                    unique=True)


# ---------------------------------------------------------------------------
# Bloom filters
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(sets_strategy(), st.integers(1, 3))
def test_bf_size_estimator_reasonable(xs, b):
    """Swamidass |X|_S within the Prop A.1 MSE-implied band (loose 6σ)."""
    words = 64  # 2048 bits — large enough that the bound is meaningful
    row = bf_of(xs, words, b)
    est = float(E.bf_size_swamidass(row, b)[0])
    mse = B.bf_linear_mse_bound(len(xs), words * 32, b)
    assert abs(est - len(xs)) <= 6 * np.sqrt(mse) + 3


@settings(max_examples=25, deadline=None)
@given(sets_strategy(), sets_strategy())
def test_bf_and_estimator_tracks_intersection(xs, ys):
    words, b = 64, 2
    inter = len(set(xs) & set(ys))
    rx, ry = bf_of(xs, words, b), bf_of(ys, words, b)
    est = float(E.bf_intersection_and(rx, ry, b)[0])
    # cross-collision inflation bound: E[extra ones] <= B p_x p_y
    mse = B.bf_and_mse_bound(max(inter, 1), words * 32, b)
    px = len(xs) * b / (words * 32)
    py = len(ys) * b / (words * 32)
    slack = words * 32 * px * py / b
    assert abs(est - inter) <= 6 * np.sqrt(mse) + 2 * slack + 3


def test_bf_and_exact_in_large_limit():
    """With B >> b|X| the AND estimator converges to the true value."""
    xs = list(range(100))
    ys = list(range(50, 150))
    row_x = bf_of(xs, 4096, 2)
    row_y = bf_of(ys, 4096, 2)
    est = float(E.bf_intersection_and(row_x, row_y, 2)[0])
    assert abs(est - 50) < 5


def test_bf_or_identity():
    xs = list(range(80))
    ys = list(range(40, 120))
    rx, ry = bf_of(xs, 2048, 2), bf_of(ys, 2048, 2)
    est = float(E.bf_intersection_or(rx, ry, 2, jnp.asarray([80.0]), jnp.asarray([80.0]))[0])
    assert abs(est - 40) < 6


# ---------------------------------------------------------------------------
# MinHash
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(sets_strategy(), sets_strategy())
def test_khash_within_exponential_bound(xs, ys):
    k = 128
    inter = len(set(xs) & set(ys))
    mx, my = khash_of(xs, k, 5000), khash_of(ys, k, 5000)
    est = float(E.khash_intersection(mx, my, jnp.asarray([float(len(xs))]),
                                     jnp.asarray([float(len(ys))]), 5000)[0])
    # invert Prop IV.2 at delta=1e-4: t = (|X|+|Y|)·sqrt(ln(2/δ)/(2k))
    t = (len(xs) + len(ys)) * np.sqrt(np.log(2 / 1e-4) / (2 * k))
    assert abs(est - inter) <= t + 1


def test_khash_jaccard_identical_sets():
    mx = khash_of(range(100), 32, 5000)
    assert float(E.khash_jaccard(mx, mx, 5000)[0]) == 1.0


def test_minhash_intersection_formula():
    # J/(1+J)·(|X|+|Y|) with J = i/(x+y-i)
    for i, x, y in [(10, 40, 50), (0, 5, 9), (30, 30, 30)]:
        j = i / (x + y - i)
        out = float(E.minhash_intersection(jnp.float32(j), jnp.float32(x), jnp.float32(y)))
        assert abs(out - i) < 1e-3


# ---------------------------------------------------------------------------
# KMV
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 100000), min_size=200, max_size=2000, unique=True))
def test_kmv_size_estimator(xs):
    from repro.core.hashing import hash_unit_interval
    k = 64
    h = np.asarray(hash_unit_interval(jnp.asarray(np.asarray(xs, np.uint32)), 0))
    row = jnp.asarray(np.sort(h)[:k][None])
    est = float(E.kmv_size(row)[0])
    # Prop A.7: deviation beyond 50% has tiny probability at k=64
    assert abs(est - len(xs)) < 0.5 * len(xs)


def test_kmv_partial_sketch_is_exact():
    from repro.core.hashing import hash_unit_interval
    xs = np.arange(10, dtype=np.uint32)
    h = np.sort(np.asarray(hash_unit_interval(jnp.asarray(xs), 0)))
    row = np.full(32, KMV_PAD, np.float32)
    row[:10] = h
    est = float(E.kmv_size(jnp.asarray(row[None]))[0])
    assert est == 10.0


# ---------------------------------------------------------------------------
# bounds module sanity
# ---------------------------------------------------------------------------

def test_bounds_monotone_in_t():
    for t1, t2 in [(1.0, 5.0), (2.0, 20.0)]:
        assert B.minhash_deviation_bound(100, 100, 64, t1) >= \
            B.minhash_deviation_bound(100, 100, 64, t2)
        assert B.bf_and_deviation_bound(50, 2048, 2, t1) >= \
            B.bf_and_deviation_bound(50, 2048, 2, t2)


def test_minhash_k_inversion():
    k = B.minhash_k_for_accuracy(100, 100, t=20, delta=0.01)
    assert B.minhash_deviation_bound(100, 100, k, 20) <= 0.011


def test_tc_bounds_shrink_with_k():
    deg = np.full(100, 10)
    assert B.tc_minhash_deviation_bound(deg, 256, 50.0) <= \
        B.tc_minhash_deviation_bound(deg, 16, 50.0)
    assert B.tc_minhash_deviation_bound_bounded_degree(deg, 256, 50.0) <= 1.0
