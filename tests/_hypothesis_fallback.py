"""Deterministic stand-in for `hypothesis` when it is not installed.

CI installs the real hypothesis (requirements-dev.txt) and gets full
property-based testing with shrinking. On minimal environments this shim
keeps the property suites (`test_estimators.py`, `test_stream.py`,
`test_stream_equivalence.py`) collecting and running: `@given` replays each
property over a fixed number of seeded pseudo-random samples, which
preserves the assertions' coverage without adding a dependency.

Only the tiny subset of the hypothesis API those suites use is implemented:
`given`, `settings(max_examples=, deadline=)`, `strategies.integers`, and
`strategies.lists(..., unique=True)`. The stream suites raise their own
example counts under `HYPOTHESIS_PROFILE=nightly` by reading the env var
directly, which works identically with the shim and the real library.
"""
from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def _lists(elem: _Strategy, min_size: int = 0, max_size: int = 10,
           unique: bool = False) -> _Strategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        out, seen = [], set()
        budget = 20 * size + 100
        while len(out) < size and budget:
            budget -= 1
            x = elem.draw(rng)
            if unique:
                if x in seen:
                    continue
                seen.add(x)
            out.append(x)
        return out
    return _Strategy(draw)


class strategies:
    integers = staticmethod(_integers)
    lists = staticmethod(_lists)


def settings(max_examples: int = 10, deadline=None, **_ignored):
    # no profile scaling here: suites that raise their counts under
    # HYPOTHESIS_PROFILE=nightly read the env var themselves (explicit
    # @settings pins override profiles under real hypothesis too, so this
    # keeps shim and real-library behavior identical)
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy, **kwstrats: _Strategy):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the strategy parameters (it would resolve them as fixtures)
        def wrapper():
            rng = np.random.default_rng(0)
            for _ in range(getattr(wrapper, "_max_examples", 10)):
                fn(*(s.draw(rng) for s in strats),
                   **{k: s.draw(rng) for k, s in kwstrats.items()})
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
