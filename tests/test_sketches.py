"""Sketch construction: determinism, np/jax twins, membership semantics."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import graph as G, sketches as S
from repro.core.hashing import hash_u32, np_hash_u32


def test_hash_np_jax_twins():
    xs = np.arange(1000, dtype=np.uint32)
    for seed in (0, 1, 12345):
        a = np.asarray(hash_u32(jnp.asarray(xs), seed))
        b = np_hash_u32(xs, seed)
        assert np.array_equal(a, b)


def test_hash_avalanche():
    xs = np.arange(4096, dtype=np.uint32)
    h = np_hash_u32(xs, 3)
    # bit balance: each output bit ~50% set
    bits = ((h[:, None] >> np.arange(32)[None, :]) & 1).mean(axis=0)
    assert np.all(bits > 0.45) and np.all(bits < 0.55)


@pytest.fixture(scope="module")
def g():
    return G.erdos_renyi(300, 0.05, seed=7)


def test_bloom_np_equals_jax(g):
    for b in (1, 2, 4):
        bf = S.build_bloom(g, words=8, num_hashes=b, seed=5)
        bf_np = S.build_bloom_np(g, words=8, num_hashes=b, seed=5)
        assert np.array_equal(np.asarray(bf), bf_np)


def test_bloom_membership_no_false_negatives(g):
    words, b, seed = 8, 2, 5
    bf = S.build_bloom(g, words, b, seed)
    total_bits = words * 32
    for v in [0, 5, 77]:
        nbrs = G.neighbors_np(g, v)
        if len(nbrs) == 0:
            continue
        got = S.bloom_membership(bf[v], jnp.asarray(nbrs), g.n, b, total_bits, seed)
        assert bool(np.all(np.asarray(got))), "bloom filters never have false negatives"


def test_khash_elements_are_neighbors(g):
    kh = np.asarray(S.build_khash(g, k=8, seed=3))
    for v in [1, 10, 100]:
        nbrs = set(G.neighbors_np(g, v).tolist())
        elems = set(int(e) for e in kh[v] if e < g.n)
        assert elems <= nbrs


def test_1hash_sorted_and_unique(g):
    oh = np.asarray(S.build_1hash(g, k=8, seed=3))
    hs = np.asarray(S.onehash_values(jnp.asarray(oh), g.n, 3))
    for v in range(0, g.n, 37):
        row_h = hs[v][oh[v] < g.n]
        assert np.all(np.diff(row_h.astype(np.int64)) >= 0)
        valid = oh[v][oh[v] < g.n]
        assert len(set(valid.tolist())) == len(valid)


def test_kmv_sorted_unit_interval(g):
    kv = np.asarray(S.build_kmv(g, k=8, seed=3))
    valid = kv[kv < 1.5]
    assert np.all(valid > 0) and np.all(valid <= 1.0)


def test_budget_sizing():
    n, m = 10_000, 200_000
    w = S.bloom_words_for_budget(n, m, 0.25)
    total_bits = n * w * 32
    csr_bits = (2 * m + n + 1) * 32
    assert total_bits <= 1.35 * 0.25 * csr_bits  # within rounding slack
    k = S.minhash_k_for_budget(n, m, 0.25)
    assert n * k <= 1.35 * 0.25 * (2 * m + n + 1)


def test_bloom_words_always_even():
    """Word counts round UP to a multiple of 2 (64-bit lanes) — including
    when the odd value comes from the min_words clamp, the case the old
    `words + (words % 2)` formulation leaked through."""
    for n, m, s, min_words in [
        (100, 300, 0.25, 2),     # budget-driven sizing
        (100, 300, 1e-6, 3),     # odd min_words clamp must still round up
        (100, 300, 1e-6, 1),
        (1000, 50_000, 0.33, 2),
        (17, 40, 0.5, 5),
    ]:
        w = S.bloom_words_for_budget(n, m, s, min_words=min_words)
        assert w % 2 == 0, (n, m, s, min_words, w)
        assert w >= min_words
    # round-up never shrinks below the budget-implied word count
    assert S.bloom_words_for_budget(100, 300, 0.25) >= 2


def test_pack_unpack_roundtrip(rng):
    bits = jnp.asarray(rng.random((5, 96)) < 0.3)
    packed = S.pack_bits(bits)
    assert packed.dtype == jnp.uint32
    assert np.array_equal(np.asarray(S.unpack_bits(packed)), np.asarray(bits))
