"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("e,w", [(1, 2), (7, 2), (64, 16), (257, 30), (1000, 70)])
def test_bf_intersect_pairs_sweep(e, w, rng):
    a = jnp.asarray(rng.integers(0, 2**32, size=(e, w), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, size=(e, w), dtype=np.uint32))
    np.testing.assert_array_equal(np.asarray(ops.bf_intersect_pairs(a, b)),
                                  np.asarray(ref.bf_intersect_pairs(a, b)))


@pytest.mark.parametrize("blocks", [(16, 8), (64, 64), (256, 512)])
def test_bf_intersect_block_shapes(blocks, rng):
    be, bw = blocks
    a = jnp.asarray(rng.integers(0, 2**32, size=(100, 20), dtype=np.uint32))
    b = jnp.asarray(rng.integers(0, 2**32, size=(100, 20), dtype=np.uint32))
    out = ops.bf_intersect_pairs(a, b, block_e=be, block_w=bw)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.bf_intersect_pairs(a, b)))


def test_bf_intersect3(rng):
    a, b, c = (jnp.asarray(rng.integers(0, 2**32, size=(77, 12), dtype=np.uint32))
               for _ in range(3))
    np.testing.assert_array_equal(np.asarray(ops.bf_intersect3_pairs(a, b, c)),
                                  np.asarray(ref.bf_intersect3_pairs(a, b, c)))


@pytest.mark.parametrize("n,e,w", [(16, 40, 4), (100, 333, 18), (5, 9, 2)])
def test_bf_edge_intersect_gather(n, e, w, rng):
    bloom = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    edges = jnp.asarray(rng.integers(0, n, size=(e, 2), dtype=np.int32))
    np.testing.assert_array_equal(np.asarray(ops.bf_edge_intersect(bloom, edges)),
                                  np.asarray(ref.bf_edge_intersect(bloom, edges)))


def _dedup_rows(x, sentinel):
    x = np.sort(x, axis=1)
    d = np.concatenate([np.zeros((x.shape[0], 1), bool), x[:, 1:] == x[:, :-1]], axis=1)
    return np.where(d, sentinel, x).astype(np.int32)


@pytest.mark.parametrize("e,k", [(5, 4), (100, 16), (300, 33)])
def test_mh_intersect_sweep(e, k, rng):
    sent = 10_000
    a = jnp.asarray(_dedup_rows(rng.choice(sent, size=(e, k)), sent))
    b = jnp.asarray(_dedup_rows(rng.choice(sent, size=(e, k)), sent))
    np.testing.assert_array_equal(np.asarray(ops.mh_intersect_pairs(a, b, sent)),
                                  np.asarray(ref.mh_intersect_pairs(a, b, sent)))


def test_khash_match(rng):
    sent = 999
    a = jnp.asarray(rng.integers(0, sent, size=(64, 8), dtype=np.int32))
    b = jnp.asarray(np.where(rng.random((64, 8)) < 0.5, np.asarray(a), 7))
    np.testing.assert_array_equal(np.asarray(ops.khash_match_pairs(a, b, sent)),
                                  np.asarray(ref.khash_match_pairs(a, b, sent)))


def test_kernel_against_known_popcounts():
    a = jnp.asarray(np.array([[0xFFFFFFFF, 0x0], [0xF0F0F0F0, 0xFFFF0000]], np.uint32))
    b = jnp.asarray(np.array([[0xFFFF0000, 0x0], [0xFFFFFFFF, 0x0000FFFF]], np.uint32))
    out = np.asarray(ops.bf_intersect_pairs(a, b))
    assert out.tolist() == [16, 16]
