"""PG001 near-miss twin: the same shapes, each one legal."""
import threading


class GoodServer:
    """Same guarded fields as the bad twin, disciplined accesses only."""

    _GUARDED_BY = {
        "_queue": "_lock|_cond",
        "_stats": "write:_lock",
    }

    def __init__(self):
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._queue = []
        self._stats = {}

    def submit(self, item):
        """Locked append — either lock of the `_lock|_cond` pair counts."""
        with self._cond:
            self._queue.append(item)

    def tally(self, name):
        """Write under the lock; the read in `len` below is free because
        `_stats` is write-guarded."""
        with self._lock:
            self._stats[name] = self._stats.get(name, 0) + 1

    def stat_count(self):
        """Unlocked *read* of a write-guarded field: legal by design."""
        return len(self._stats)

    def _drain_locked(self):
        """`_locked` suffix: callers own self._lock, accesses are free."""
        out, self._queue = self._queue, []
        return out

    def drain(self):
        """Lock, then delegate to the `_locked` internal."""
        with self._lock:
            return self._drain_locked()
