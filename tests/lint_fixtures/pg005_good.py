"""PG005 near-miss twin: full footprint coverage for every kind."""


class Footprint:
    """Stand-in for repro.engine.Footprint."""

    @staticmethod
    def of(*vertex_sets):
        return vertex_sets

    @staticmethod
    def whole_graph():
        return None


class GoodQueryServer:
    """Every submitted kind is declared, and the flush path backs each
    declaration: an exact Footprint for similarity, a whole-graph marker
    in the tc branch."""

    _KIND_FOOTPRINTS = {
        "similarity": "exact",
        "tc": "whole_graph",
    }

    def __init__(self):
        self._queue = []
        self._cache = {}

    def _submit(self, kind, key):
        self._queue.append((kind, key))
        return len(self._queue)

    def submit_similarity(self, pairs):
        return self._submit("similarity", ("similarity", len(pairs)))

    def submit_triangle_count(self):
        return self._submit("tc", ("tc",))

    def flush_one(self, kind, key, payload):
        if kind == "similarity":
            value = payload.compute_pairs()
            fp = Footprint.of(payload.pairs)
        elif kind == "tc":
            value = payload.triangle_count()
            fp = Footprint.whole_graph()
        else:
            raise ValueError(kind)
        self._cache[key] = (value, fp)
        return value
