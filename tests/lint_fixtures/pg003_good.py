"""PG003 near-miss twin: the same flows, bucket-disciplined."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.plan import pow2_bucket


@jax.jit
def _kernel(buf):
    return buf.sum()


def upload_bucketed_buffer(requests):
    """The size passes through pow2_bucket before the buffer is built:
    bounded program set, no finding."""
    buf = np.zeros((pow2_bucket(len(requests), 64), 2), np.int32)
    buf[:len(requests)] = requests
    return jnp.asarray(buf)


def call_jit_with_bucketed_ctor(xs, arr):
    """Same shape as the bad twin, cleansed by the bucket helper."""
    count = pow2_bucket(arr.shape[0] + len(xs), 64)
    return _kernel(np.zeros(count, np.float32))


def host_only_raw_size(requests):
    """Raw len() sizing is fine when the buffer never crosses a device
    boundary — host-side accounting has no recompile cost."""
    buf = np.zeros(len(requests), np.int64)
    return buf.sum()
