"""PG004 negative fixture: silent host syncs inside spans / jitted code."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace


def sum_under_span(xs):
    """.item() inside a trace.span body -> PG004: the span charges the
    device wait to whichever span happens to synchronize first."""
    with trace.span("fixture.sum") as sp:
        total = jnp.asarray(xs).sum()
        value = total.item()
        sp.set(rows=len(xs))
    return value

def copy_unfenced(xs):
    """np.asarray on an unfenced device value inside a span -> PG004."""
    with trace.span("fixture.copy"):
        cards = jnp.asarray(xs) * 2
        host = np.asarray(cards)
    return host


@jax.jit
def jitted_item(buf):
    """Materializing a tracer inside a jitted function -> PG004."""
    return buf.sum().item()
