"""PG002 near-miss twin: every legal publication shape."""


class GoodSession:
    """Serving-view mutators that respect fork-invalidate-publish."""

    def __init__(self, view):
        self._serving = view
        self._listeners = []

    def _publish_invalid(self, vertices):
        for fn in list(self._listeners):
            fn(vertices)

    def _publish_view(self, view):
        """The single `_serving` store lives in the publish helper — one
        publication, no invalidation: legal."""
        self._serving = view

    def apply_delta(self, delta):
        """Canonical order: invalidate, then publish exactly once. The
        conditional invalidation (no-op-delta shape) is fine — a no-op
        publication has nothing to invalidate."""
        new_view = delta.build()
        if delta.touched.size:
            self._publish_invalid(delta.touched)
        self._publish_view(new_view)

    def restore(self, view):
        """Publish without any invalidation: legal (fresh state, nothing
        cached against it yet)."""
        self._publish_view(view)
