"""PG005 negative fixture: query kinds without a footprint contract."""


class Footprint:
    """Stand-in for repro.engine.Footprint."""

    @staticmethod
    def of(*vertex_sets):
        return vertex_sets

    @staticmethod
    def whole_graph():
        return None


class BadQueryServer:
    """Submits kinds but declares no _KIND_FOOTPRINTS map at all, so every
    new kind silently enters the cache without an invalidation contract."""

    def __init__(self):
        self._queue = []

    def _submit(self, kind, key):
        self._queue.append((kind, key))
        return len(self._queue)

    def submit_similarity(self, pairs):
        return self._submit("similarity", ("similarity", len(pairs)))

    def submit_triangle_count(self):
        return self._submit("tc", ("tc",))


class IncompleteQueryServer:
    """Declares a map, but one submitted kind is missing from it, one
    declared kind is never submitted, and the declared whole-graph kind
    has no Footprint.whole_graph() branch backing it."""

    _KIND_FOOTPRINTS = {
        "tc": "whole_graph",
        "linkpred": "exact",
    }

    def __init__(self):
        self._queue = []

    def _submit(self, kind, key):
        self._queue.append((kind, key))
        return len(self._queue)

    def submit_similarity(self, pairs):
        return self._submit("similarity", ("similarity", len(pairs)))

    def submit_triangle_count(self):
        return self._submit("tc", ("tc",))
