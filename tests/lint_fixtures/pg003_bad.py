"""PG003 negative fixture: raw traffic sizes reaching jit/device edges."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def _kernel(buf):
    return buf.sum()


def upload_raw_buffer(requests):
    """Buffer sized directly by len(traffic) -> PG003 at the jnp.asarray
    boundary: every distinct request count compiles a fresh program."""
    buf = np.zeros((len(requests), 2), np.int32)
    return jnp.asarray(buf)


def call_jit_with_raw_ctor(xs, arr):
    """A raw-sized constructor expression passed straight into a jitted
    callable -> PG003 (size flows through a local and a shape read)."""
    count = arr.shape[0]
    return _kernel(np.zeros(count + len(xs), np.float32))
