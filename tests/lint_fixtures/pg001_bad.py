"""PG001 negative fixture: guarded fields touched outside their lock."""
import threading


class BadServer:
    """Declares _GUARDED_BY, then breaks every rule it states."""

    _GUARDED_BY = {
        "_queue": "_lock",
        "_stats": "write:_lock",
    }

    def __init__(self):
        self._lock = threading.Lock()
        self._queue = []           # exempt: construction is single-owner
        self._stats = {}

    def submit(self, item):
        """Unlocked append to a fully guarded field -> PG001."""
        self._queue.append(item)

    def tally(self, name):
        """Unlocked subscript-increment of a write-guarded field -> PG001."""
        self._stats[name] = self._stats.get(name, 0) + 1

    def drain_later(self):
        """A closure escapes the with block: its accesses run unlocked
        whenever the callback fires -> PG001 inside the nested def."""
        with self._lock:
            def cb():
                self._queue.clear()
        return cb
