"""PG004 near-miss twin: the same copies, fenced or moved out."""
import jax.numpy as jnp
import numpy as np

from repro.obs import trace


def sum_after_span(xs):
    """The reduction is fenced on the span; the host read happens after
    the span exits, so the wait is attributed to the span that launched
    the work."""
    with trace.span("fixture.sum") as sp:
        total = jnp.asarray(xs).sum()
        sp.fence(total)
        sp.set(rows=len(xs))
    return total.item()

def copy_fenced(xs):
    """np.asarray inside the span is fine once the value is fenced —
    span exit blocks before the clock read, so timing stays honest."""
    with trace.span("fixture.copy") as sp:
        cards = jnp.asarray(xs) * 2
        sp.fence(cards)
        host = np.asarray(cards)
    return host


def host_cast_in_span(rows):
    """np.asarray on plain host data (a list) is not a device sync; the
    literal argument shape keeps it out of PG004's net by design."""
    with trace.span("fixture.host"):
        arr = np.asarray([int(r) for r in rows])
    return arr
