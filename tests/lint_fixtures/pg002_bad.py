"""PG002 negative fixture: publication ordering violations."""


class BadSession:
    """Serving-view mutators that break fork-invalidate-publish."""

    def __init__(self, view):
        self._serving = view
        self._listeners = []

    def _publish_invalid(self, vertices):
        for fn in list(self._listeners):
            fn(vertices)

    def _publish_view(self, view):
        self._serving = view

    def apply_delta_wrong_order(self, delta):
        """Publishes the new view BEFORE the invalidation feed -> PG002:
        a flush can capture the new view while stale cache entries live."""
        new_view = delta.build()
        self._publish_view(new_view)
        self._publish_invalid(delta.touched)

    def apply_delta_double_publish(self, delta):
        """Two publications in one mutation -> PG002: readers between the
        swaps observe a half-mutated generation."""
        self._publish_invalid(delta.touched)
        self._serving = delta.build_partial()
        self._serving = delta.build()
