"""Tests for tools/pgcheck: the AST invariant checker itself.

Three layers:

* **fixtures** — each ``tests/lint_fixtures/pg00N_bad.py`` trips exactly
  its pass (and nothing else); each ``pg00N_good.py`` near-miss twin is
  completely clean, so the passes discriminate, not pattern-match;
* **mechanics** — suppression comments, the baseline ratchet, config-error
  findings, and the CLI's exit codes;
* **the repo itself** — ``src/repro/stream`` + ``src/repro/engine`` carry
  zero findings (the tier-1 regression CI enforces via the lint job), and
  deleting one ``with self._lock:`` from the server re-introduces one.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "lint_fixtures"
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.pgcheck.driver import check_source, pass_ids, run_paths  # noqa: E402
from tools.pgcheck.model import Baseline, split_findings  # noqa: E402


def _check_fixture(name):
    path = FIXTURES / name
    return check_source(name, path.read_text(encoding="utf-8"))


# ----------------------------------------------------------------------
# fixtures: every bad file trips exactly its pass; every twin is clean
# ----------------------------------------------------------------------

BAD_EXPECT = {
    "pg001_bad.py": ("PG001", 3),   # unlocked append, subscript, closure
    "pg002_bad.py": ("PG002", 2),   # publish-before-invalidate, double pub
    "pg003_bad.py": ("PG003", 2),   # raw buffer, raw-sized ctor into jit
    "pg004_bad.py": ("PG004", 3),   # .item in span, unfenced copy, jit item
    "pg005_bad.py": ("PG005", 4),   # no map, missing kind, no branch, stale
}


@pytest.mark.parametrize("name", sorted(BAD_EXPECT))
def test_bad_fixture_trips_exactly_its_pass(name):
    expected_pass, expected_count = BAD_EXPECT[name]
    findings = _check_fixture(name)
    assert findings, f"{name} produced no findings"
    assert {f.pass_id for f in findings} == {expected_pass}, \
        [f.render() for f in findings]
    assert len(findings) == expected_count, [f.render() for f in findings]


@pytest.mark.parametrize("name", [n.replace("_bad", "_good")
                                  for n in sorted(BAD_EXPECT)])
def test_good_twin_is_clean(name):
    findings = _check_fixture(name)
    assert findings == [], [f.render() for f in findings]


def test_findings_carry_location_scope_and_hint():
    findings = _check_fixture("pg001_bad.py")
    f = next(f for f in findings if "submit" in f.scope)
    assert f.path == "pg001_bad.py"
    assert f.line > 1 and f.scope == "BadServer.submit"
    assert f.hint        # every PG001 finding ships a fix hint
    rendered = f.render()
    assert f"pg001_bad.py:{f.line}" in rendered and "PG001" in rendered


# ----------------------------------------------------------------------
# mechanics: suppression, baseline ratchet, config errors, CLI
# ----------------------------------------------------------------------

_SUPPRESSIBLE = """import threading

class C:
    _GUARDED_BY = {"_q": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._q = []

    def poke(self):
        self._q.append(1)@@MARKER@@
"""


def _suppressible(marker=""):
    return _SUPPRESSIBLE.replace("@@MARKER@@", marker)


def test_line_suppression_disables_named_pass():
    clean = _suppressible("  # pgcheck: disable=PG001")
    assert check_source("c.py", clean) == []
    allof = _suppressible("  # pgcheck: disable=all")
    assert check_source("c.py", allof) == []
    wrong = _suppressible("  # pgcheck: disable=PG004")
    assert [f.pass_id for f in check_source("c.py", wrong)] == ["PG001"]


def test_baseline_grandfathers_by_scope_not_line(tmp_path):
    findings = check_source("c.py", _suppressible())
    assert [f.pass_id for f in findings] == ["PG001"]
    baseline_file = tmp_path / "baseline.json"
    Baseline.write(str(baseline_file), findings)
    baseline = Baseline.load(str(baseline_file))
    # same violation, shifted lines: still grandfathered (scope-keyed)
    shifted = "# a comment\n# another\n" + _suppressible()
    new, old = split_findings(check_source("c.py", shifted), baseline)
    assert new == [] and len(old) == 1
    # a different method is a new finding, not grandfathered
    other = _suppressible() + \
        "\n    def poke2(self):\n        self._q.append(2)\n"
    new, old = split_findings(check_source("c.py", other), baseline)
    assert len(new) == 1 and new[0].scope == "C.poke2"


def test_malformed_guard_map_is_a_config_finding():
    src = ("class C:\n"
           "    _GUARDED_BY = {'_q': some_variable}\n"
           "    def poke(self):\n"
           "        pass\n")
    findings = check_source("c.py", src)
    assert len(findings) == 1 and findings[0].pass_id == "PG001"
    assert "literal" in findings[0].message


def test_syntax_error_reports_pg000_not_crash():
    findings = check_source("c.py", "def broken(:\n")
    assert [f.pass_id for f in findings] == ["PG000"]


def test_pass_catalog_is_complete():
    assert pass_ids() == ["PG001", "PG002", "PG003", "PG004", "PG005"]


def _run_cli(*args, cwd=REPO):
    return subprocess.run([sys.executable, "-m", "tools.pgcheck", *args],
                          cwd=cwd, capture_output=True, text=True)


def test_cli_exit_codes_and_baseline_flow(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(_suppressible(), encoding="utf-8")
    res = _run_cli(str(bad))
    assert res.returncode == 1 and "PG001" in res.stdout
    # --write-baseline grandfathers it; --baseline then passes
    baseline = tmp_path / "baseline.json"
    res = _run_cli(str(bad), "--write-baseline", str(baseline))
    assert res.returncode == 0
    doc = json.loads(baseline.read_text(encoding="utf-8"))
    assert doc["version"] == 1 and len(doc["entries"]) == 1
    res = _run_cli(str(bad), "--baseline", str(baseline))
    assert res.returncode == 0 and "baselined" in res.stdout
    # --select skips the only firing pass
    res = _run_cli(str(bad), "--select", "PG004")
    assert res.returncode == 0
    res = _run_cli(str(bad), "--select", "PG999")
    assert res.returncode == 2


# ----------------------------------------------------------------------
# the repo itself
# ----------------------------------------------------------------------

def test_stream_and_engine_are_clean():
    """Tier-1 regression: the serving tier and engine carry zero findings
    (the checked-in baseline is empty — nothing is grandfathered)."""
    findings = run_paths([str(REPO / "src" / "repro" / "stream"),
                          str(REPO / "src" / "repro" / "engine")],
                         root=str(REPO))
    assert findings == [], [f.render() for f in findings]
    assert len(Baseline.load(str(REPO / "pgcheck_baseline.json"))) == 0


def test_whole_src_tree_is_clean():
    """The full `python -m tools.pgcheck src/repro` CI gate, in-process."""
    findings = run_paths([str(REPO / "src" / "repro")], root=str(REPO))
    assert findings == [], [f.render() for f in findings]


def test_deleting_a_server_lock_fails_the_gate():
    """Dropping one `with self._lock:` from BatchedQueryServer._pad_add
    must re-introduce a PG001 finding — the checker guards the real code,
    not just fixtures."""
    path = REPO / "src" / "repro" / "stream" / "server.py"
    src = path.read_text(encoding="utf-8")
    guarded = ("        with self._lock:\n"
               "            self._pad[name][0] += real\n"
               "            self._pad[name][1] += padded\n")
    unguarded = ("        self._pad[name][0] += real\n"
                 "        self._pad[name][1] += padded\n")
    assert guarded in src, "server.py _pad_add changed; update this test"
    broken = src.replace(guarded, unguarded)
    findings = check_source("src/repro/stream/server.py", broken)
    pg001 = [f for f in findings if f.pass_id == "PG001"]
    assert len(pg001) == 2 and \
        all(f.scope == "BatchedQueryServer._pad_add" for f in pg001)
