"""Data pipeline determinism + MinHash dedup (the paper inside the LM stack)."""
import numpy as np

from repro.data import SyntheticLMData, TokenBatcher, minhash_dedup, document_sketches
from repro.data.dedup import jaccard_estimate, k_for


def test_pipeline_deterministic():
    d1 = SyntheticLMData(vocab_size=100, seq_len=32, seed=3)
    d2 = SyntheticLMData(vocab_size=100, seq_len=32, seed=3)
    b1 = d1.batch(5, 8)
    b2 = d2.batch(5, 8)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = d1.batch(6, 8)
    assert not np.array_equal(b1["inputs"], b3["inputs"])


def test_pipeline_labels_shifted():
    d = SyntheticLMData(vocab_size=50, seq_len=16, seed=0)
    b = d.batch(0, 4)
    assert b["inputs"].shape == (4, 16) and b["labels"].shape == (4, 16)


def test_pipeline_is_learnable():
    """Order-2 structure: next-token entropy far below uniform."""
    d = SyntheticLMData(vocab_size=1000, seq_len=64, seed=1, branch=2)
    b = d.batch(0, 64)
    # bigram count: given (mode unknown) the branch=2 table bounds entropy
    pairs = {}
    for row in np.concatenate([b["inputs"], b["labels"][:, -1:]], axis=1):
        for a, c in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), set()).add(int(c))
    avg_branch = np.mean([len(v) for v in pairs.values()])
    assert avg_branch < 32  # << vocab 1000


def test_token_batcher():
    docs = [np.arange(10), np.arange(100, 130)]
    tb = TokenBatcher(docs, seq_len=8)
    assert tb.num_batches(2) == 2
    b = tb.batch(0, 2)
    assert b["inputs"].shape == (2, 8)


def _doc(rng, n=400):
    return rng.integers(0, 1000, size=n, dtype=np.int64)


def test_dedup_drops_planted_duplicates():
    rng = np.random.default_rng(0)
    base = [_doc(rng) for _ in range(20)]
    # plant near-duplicates: copy with 2% token noise
    dups = []
    for d in base[:8]:
        d2 = d.copy()
        idx = rng.choice(len(d2), size=len(d2) // 50, replace=False)
        d2[idx] = rng.integers(0, 1000, size=len(idx))
        dups.append(d2)
    docs = base + dups
    keep, stats = minhash_dedup(docs, threshold=0.6, k=64)
    assert keep[:20].all(), "originals kept"
    assert (~keep[20:]).sum() >= 6, f"planted dups should drop: {stats}"


def test_dedup_keeps_distinct_docs():
    rng = np.random.default_rng(1)
    docs = [_doc(rng) for _ in range(30)]
    keep, _ = minhash_dedup(docs, threshold=0.6, k=64)
    assert keep.all()


def test_sketch_jaccard_estimates_true_jaccard():
    rng = np.random.default_rng(2)
    a = _doc(rng, 2000)
    b = a.copy()
    b[:1000] = rng.integers(0, 1000, size=1000)  # ~50% shingle overlap
    sk = document_sketches([a, b], k=256)
    j = jaccard_estimate(sk[0], sk[1])
    assert 0.05 < j < 0.8


def test_k_for_bound_inversion():
    k = k_for(0.1, 0.01)
    # Hoeffding: 2 exp(-2 k t^2) <= delta
    assert 2 * np.exp(-2 * k * 0.1**2) <= 0.0101
