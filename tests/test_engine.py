"""Batched mining engine: block-gather kernels, plan routing, sessions."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import engine as eng
from repro.core import graph as G, sketches as S
from repro.core import triangle_count, four_clique_count, jarvis_patrick
from repro.core.algorithms.tc import local_clustering_coefficient
from repro.core.intersect import make_pair_cardinality_fn
from repro.distributed import sharding
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def g():
    return G.erdos_renyi(200, 0.07, seed=7)


@pytest.fixture(scope="module")
def sk(g):
    return S.build(g, "bf", 0.33, num_hashes=2, seed=1)


# ---------------------------------------------------------------------------
# block-gather kernels vs the reference popcount path (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_e", [1, 8, 64])
@pytest.mark.parametrize("n,e,w", [(16, 40, 4), (100, 333, 18), (5, 9, 2),
                                   (64, 63, 6)])
def test_block_gather_edge_kernel(n, e, w, block_e, rng):
    bloom = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    edges = jnp.asarray(rng.integers(0, n, size=(e, 2), dtype=np.int32))
    out = ops.bf_edge_intersect(bloom, edges, block_e=block_e)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.bf_edge_intersect(bloom, edges)))


@pytest.mark.parametrize("block_e", [1, 8, 64])
@pytest.mark.parametrize("n,t,w", [(16, 40, 4), (50, 129, 10), (7, 3, 2)])
def test_block_gather_triple_kernel(n, t, w, block_e, rng):
    bloom = jnp.asarray(rng.integers(0, 2**32, size=(n, w), dtype=np.uint32))
    triples = jnp.asarray(rng.integers(0, n, size=(t, 3), dtype=np.int32))
    out = ops.bf_edge_intersect3(bloom, triples, block_e=block_e)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.bf_edge_intersect3(bloom, triples)))


def test_block_gather_ragged_word_axis(rng):
    # W not a multiple of block_w: wrapper must zero-pad the word axis
    bloom = jnp.asarray(rng.integers(0, 2**32, size=(30, 7), dtype=np.uint32))
    edges = jnp.asarray(rng.integers(0, 30, size=(21, 2), dtype=np.int32))
    out = ops.bf_edge_intersect(bloom, edges, block_e=8, block_w=4)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.bf_edge_intersect(bloom, edges)))


# ---------------------------------------------------------------------------
# plan / fold / layout
# ---------------------------------------------------------------------------

def test_fold_and_map_chunking_equivalence(g, sk):
    fn = eng.pair_cardinality_fn(g, sk, eng.EnginePlan())
    base = fn(g.edges)
    for chunk in (17, 64, 10**6):
        plan = eng.EnginePlan(edge_chunk=chunk)
        vals = eng.map_edges(g.edges, fn, plan)
        np.testing.assert_allclose(np.asarray(vals), np.asarray(base), rtol=1e-6)
        total = eng.fold_edges(
            g.edges, lambda p, m: jnp.sum(jnp.where(m, fn(p), 0.0)), plan)
        np.testing.assert_allclose(float(total), float(jnp.sum(base)), rtol=1e-5)


def test_degree_order_is_a_permutation(g):
    edges_s, inv = eng.order_edges_by_hub(g, g.edges)
    # same multiset of edges, and inv restores the original order
    np.testing.assert_array_equal(np.asarray(jnp.take(edges_s, inv, axis=0)),
                                  np.asarray(g.edges))
    du = np.asarray(jnp.take(g.deg, edges_s[:, 0]))
    dv = np.asarray(jnp.take(g.deg, edges_s[:, 1]))
    hub_deg = np.maximum(du, dv)
    buckets = np.frexp(np.maximum(hub_deg, 1).astype(np.float32))[1]
    assert (np.diff(buckets) <= 0).all()          # hubs lead the schedule


def test_edge_cardinalities_order_invariant(g, sk):
    plain = eng.edge_cardinalities(g, sk, eng.EnginePlan(degree_order=False))
    ordered = eng.edge_cardinalities(g, sk, eng.EnginePlan(degree_order=True))
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(ordered))


def test_resolve_plan_rejects_unknown_kwargs(g, sk):
    with pytest.raises(TypeError):
        eng.resolve_plan(None, g, sk, {"edge_chnk": 4})


def test_explicit_plan_survives_resolution(g, sk):
    plan = eng.EnginePlan(edge_chunk=256, block_e=4)
    assert eng.resolve_plan(plan, g, sk, {}) is plan
    # and four_clique_count must not override an explicit plan's chunking
    a = float(four_clique_count(g, sk, plan=eng.EnginePlan(edge_chunk=32)))
    b = float(four_clique_count(g, sk, plan=eng.EnginePlan(edge_chunk=10**6)))
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_kernel_ops_handle_empty_inputs(sk):
    out = ops.bf_edge_intersect(sk.data, jnp.zeros((0, 2), jnp.int32))
    assert out.shape == (0,) and out.dtype == jnp.int32
    out3 = ops.bf_edge_intersect3(sk.data, jnp.zeros((0, 3), jnp.int32))
    assert out3.shape == (0,)


# ---------------------------------------------------------------------------
# engine path vs the legacy per-edge estimator path: bit-identical
# ---------------------------------------------------------------------------

def test_engine_tc_bit_identical_to_card_fn_path(g, sk):
    fn = make_pair_cardinality_fn(g, sk)
    legacy = float(jnp.sum(fn(g.edges)) / 3.0)
    plan = eng.EnginePlan(degree_order=False)
    assert float(triangle_count(g, sk, plan=plan)) == legacy
    # kernel path: same integer popcounts -> same estimates, same fold order
    plan_k = eng.EnginePlan(use_kernel=True, degree_order=False)
    assert float(triangle_count(g, sk, plan=plan_k)) == legacy


def test_engine_4clique_bit_identical_between_paths(g, sk):
    plain = float(four_clique_count(g, sk,
                                    plan=eng.EnginePlan(edge_chunk=256,
                                                        degree_order=False)))
    kern = float(four_clique_count(g, sk,
                                   plan=eng.EnginePlan(edge_chunk=256,
                                                       use_kernel=True,
                                                       degree_order=False)))
    assert plain == kern


def test_engine_exact_tc_matches_oracle(g):
    from repro.core.exact import exact_triangle_count
    got = float(triangle_count(g, plan=eng.EnginePlan(edge_chunk=64)))
    assert got == float(int(exact_triangle_count(g)))


# ---------------------------------------------------------------------------
# multi-query session
# ---------------------------------------------------------------------------

def test_session_shares_one_edge_pass(g, sk):
    sess = eng.session(g, sk)
    first = sess.edge_cardinalities()
    assert sess.edge_cardinalities() is first      # cached, not recomputed
    np.testing.assert_allclose(float(sess.triangle_count()),
                               float(triangle_count(
                                   g, sk, plan=sess.plan)), rtol=1e-6)
    lcc = sess.local_clustering()
    np.testing.assert_allclose(
        np.asarray(lcc),
        np.asarray(local_clustering_coefficient(g, sk, plan=sess.plan)),
        rtol=1e-6)
    labels, num = sess.jarvis_patrick("jaccard", 0.05)
    labels2, num2 = jarvis_patrick(g, sk, "jaccard", 0.05, plan=sess.plan)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(labels2))
    assert int(num) == int(num2)


def test_session_builds_sketch_from_kind(g):
    sess = eng.session(g, "bf", storage_budget=0.33, num_hashes=2, seed=1)
    assert sess.sketch is not None and sess.sketch.kind == "bf"
    assert sess.stats()["sketch_bytes"] > 0
    assert float(sess.triangle_count()) > 0


def test_session_exact_mode(g):
    from repro.core.exact import exact_triangle_count
    sess = eng.session(g, None)
    assert float(sess.triangle_count()) == float(int(exact_triangle_count(g)))


# ---------------------------------------------------------------------------
# edge-axis sharding (single-device mesh: correctness of the seam)
# ---------------------------------------------------------------------------

def test_sharded_fold_matches_local(g, sk):
    plan = eng.EnginePlan(edge_chunk=64, shard_edges=True, degree_order=False)
    base = float(triangle_count(g, sk, plan=plan.with_(shard_edges=False)))
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    with sharding.use_rules(mesh):
        sharded = float(triangle_count(g, sk, plan=plan))
    np.testing.assert_allclose(sharded, base, rtol=1e-5)
    # without an active mesh the sharded plan falls back to the local fold
    assert float(triangle_count(g, sk, plan=plan)) == base
