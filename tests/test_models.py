"""Per-architecture smoke tests (reduced configs) + decode consistency."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro import configs as C
from repro.models import (reduced, init_params, forward, loss_fn, init_cache,
                          decode_step, build_plan, params_logical_axes,
                          cache_logical_axes, SHAPES)
from repro.models.model import _is_axes_leaf


def _reduced(arch, **kw):
    cfg = C.get(arch)
    return dataclasses.replace(reduced(cfg), dtype="float32", **kw)


@pytest.mark.parametrize("arch", C.registry())
def test_arch_smoke_forward_and_loss(arch):
    r = _reduced(arch)
    p = init_params(r, jax.random.PRNGKey(0))
    B, S = 2, 16
    inputs = (jnp.zeros((B, S), jnp.int32) if r.input_mode == "tokens"
              else jnp.zeros((B, S, r.d_model), jnp.float32))
    batch = {"inputs": inputs, "labels": jnp.ones((B, S), jnp.int32)}
    logits = forward(p, r, inputs)
    assert logits.shape == (B, S, r.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits[..., :r.vocab_size])))
    loss = float(loss_fn(p, r, batch))
    assert np.isfinite(loss) and loss > 0


@pytest.mark.parametrize("arch", C.registry())
def test_arch_axes_tree_matches_params(arch):
    r = _reduced(arch)
    p = init_params(r, jax.random.PRNGKey(0))
    ax = params_logical_axes(r)
    assert jax.tree.structure(p) == jax.tree.structure(ax, is_leaf=_is_axes_leaf)
    # every axes tuple has the same rank as its param
    flat_p = jax.tree.leaves(p)
    flat_a = jax.tree.leaves(ax, is_leaf=_is_axes_leaf)
    for arr, axes in zip(flat_p, flat_a):
        assert arr.ndim == len(axes), (arr.shape, axes)


@pytest.mark.parametrize("arch", C.registry())
def test_arch_cache_axes_tree(arch):
    r = _reduced(arch)
    cache = init_cache(r, 2, 8)
    cax = cache_logical_axes(r)
    assert jax.tree.structure(cache) == jax.tree.structure(cax, is_leaf=_is_axes_leaf)


@pytest.mark.parametrize("arch", ["qwen3_8b", "gemma_2b", "h2o_danube3_4b",
                                  "deepseek_v3", "mamba2_130m",
                                  "jamba15_large", "musicgen_large"])
def test_decode_matches_forward(arch):
    kw = {"capacity_factor": 4.0} if C.get(arch).moe_num_experts else {}
    r = _reduced(arch, **kw)
    key = jax.random.PRNGKey(1)
    p = init_params(r, key)
    B, S = 2, 16
    if r.input_mode == "tokens":
        inp = jax.random.randint(key, (B, S), 0, r.vocab_size)
        step_in = lambda t: inp[:, t:t + 1]
    else:
        inp = jax.random.normal(key, (B, S, r.d_model)) * 0.1
        step_in = lambda t: inp[:, t:t + 1]
    full = forward(p, r, inp)
    cache = init_cache(r, B, S)
    outs = []
    for t in range(S):
        lg, cache = decode_step(p, cache, r, step_in(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full)))
    scale = float(jnp.max(jnp.abs(full)))
    assert err < 2e-3 * max(scale, 1.0), (arch, err, scale)


def test_swa_ring_buffer_bounded_cache():
    r = _reduced("h2o_danube3_4b")
    assert r.sliding_window == 64
    cache = init_cache(r, 2, 1024)
    k_shape = cache["blocks"][0][0]["k"].shape
    assert k_shape[2] == 64  # ring buffer == window, not context


def test_mla_cache_is_compressed():
    r = _reduced("deepseek_v3")
    cache = init_cache(r, 2, 32)
    layer0 = cache["blocks"][0][0]
    assert set(layer0.keys()) == {"ckv", "krope"}
    assert layer0["ckv"].shape[-1] == r.kv_lora_rank


def test_plans():
    assert [b.repeat for b in build_plan(C.get("deepseek_v3"))] == [3, 58]
    jb = build_plan(C.get("jamba15_large"))
    assert len(jb) == 1 and jb[0].repeat == 9 and len(jb[0].sigs) == 8
    assert [b.repeat for b in build_plan(C.get("qwen3_8b"))] == [36]


def test_full_config_param_counts():
    """Total parameter counts sit near the published sizes."""
    expect = {"deepseek_v3": (600e9, 720e9), "phi35_moe": (38e9, 46e9),
              "qwen3_8b": (7e9, 9.5e9), "gemma_2b": (2.0e9, 3.2e9),
              "jamba15_large": (330e9, 440e9), "qwen2_vl_72b": (62e9, 80e9)}
    for arch, (lo, hi) in expect.items():
        n = C.get(arch).total_params()
        assert lo <= n <= hi, (arch, n)


def test_shapes_table():
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288
    assert C.shapes_for("mamba2_130m")[-1] == "long_500k"
    assert "long_500k" not in C.shapes_for("qwen3_8b")


def test_training_reduces_loss_small_model():
    """A tiny transformer learns a repeating pattern (integration test)."""
    from repro.optim import AdamW
    from repro.distributed.step import make_train_step, init_train_state
    r = _reduced("qwen3_8b", vocab_size=64)
    opt = AdamW(learning_rate=3e-3, keep_master=False)
    state = init_train_state(r, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(r, opt))
    # repeating token pattern
    pat = jnp.tile(jnp.arange(8, dtype=jnp.int32), (4, 4))  # [4, 32]
    batch = {"inputs": pat, "labels": jnp.roll(pat, -1, axis=1)}
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses[::10]
