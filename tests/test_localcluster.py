"""Local clustering tests: push vs power iteration, sweep bounds, streaming.

Covers the satellite checklist: (1) PPR forward push against a dense
power-iteration reference within the ACL truncation bound, (2) exact sweep
increments against brute force, (3) sketch-gated sweep conductance within
the ``core.bounds``-derived interval of the exact sweep on Kronecker graphs,
(4) determinism under seed-batch permutation — hardened into real hypothesis
properties (permutation invariance, duplicate-seed dedup, ``alpha→1``
degeneracy) that scale up under ``HYPOTHESIS_PROFILE=nightly``, (5) streamed
answers over ``DynamicGraph.view()`` bit-identical to a fresh static
session, and (6) the pow2 seed-batch bucketing that keeps ragged batches on
one compiled push program.
"""
import functools
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import bounds, graph as G, sketches as SK
from repro.core.algorithms import localcluster as LC
from repro import engine as ENG
from repro.stream import BatchedQueryServer, DynamicGraph, StreamSession

ALPHA = 0.15
# explicit @settings pins override any loaded hypothesis profile, so the
# nightly raise must come from the env var directly (same contract as
# tests/test_stream.py)
N_EXAMPLES = 25 if os.environ.get("HYPOTHESIS_PROFILE") == "nightly" else 5


@functools.lru_cache(maxsize=None)
def _kron():
    # plain cached builder for @given-wrapped properties, which can't take
    # fixtures under the fallback shim (zero-arg wrapper)
    return G.kronecker(8, 8, seed=1)


@pytest.fixture(scope="module")
def kron():
    return _kron()


@pytest.fixture(scope="module")
def community():
    return G.random_bipartite_community(300, 4, 0.2, 0.004, seed=3)


# ---------------------------------------------------------------------------
# PPR push
# ---------------------------------------------------------------------------

def test_push_matches_power_iteration(kron):
    eps = 1e-5
    seeds = np.array([3, 17, 101], np.int32)
    p, r, iters = LC.ppr_push(kron, seeds, ALPHA, eps, max_iters=500)
    assert int(iters) < 500
    ref = LC.ppr_power_iteration(kron, seeds, ALPHA, iters=400)
    # ACL truncation: 0 <= ref - p <= eps * deg coordinatewise (plus float32
    # slack); residuals below threshold at termination
    err = np.asarray(ref) - np.asarray(p)
    bound = eps * np.asarray(kron.deg, np.float64)[None, :] + 1e-4
    assert (err <= bound).all()
    assert (err >= -1e-4).all()
    thresh = eps * np.maximum(np.asarray(kron.deg, np.float64), 1.0)
    assert (np.asarray(r) < thresh[None, :] + 1e-7).all()


def test_push_mass_conservation(kron):
    seeds = np.array([5], np.int32)
    p, r, _ = LC.ppr_push(kron, seeds, ALPHA, 1e-4)
    total = float(np.asarray(p).sum() + np.asarray(r).sum())
    # every unit of pushed mass splits alpha -> p, (1-alpha) -> r; the sum
    # p + r only decreases by the teleport share of pushed residual, and
    # never increases
    assert 0.0 < total <= 1.0 + 1e-5
    assert float(np.asarray(p).sum()) > 0.0


def test_push_isolated_seed():
    g = G.from_edge_array(4, np.array([[1, 2]]))   # vertex 0 isolated
    p, r, _ = LC.ppr_push(g, np.array([0], np.int32), ALPHA, 1e-4)
    assert np.asarray(p)[0, 0] == pytest.approx(1.0)
    assert float(np.asarray(r).sum()) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# sweep cut
# ---------------------------------------------------------------------------

def _brute_conductance(g, order, sup):
    """Reference φ(S_j) for every prefix, via adjacency sets."""
    adj = [set(G.neighbors_np(g, v).tolist()) for v in range(g.n)]
    deg = np.asarray(g.deg)
    vols = 2 * g.m
    out, sset, vol, cut = [], set(), 0, 0
    for j in range(sup):
        v = int(order[j])
        inter = len(adj[v] & sset)
        cut += int(deg[v]) - 2 * inter
        vol += int(deg[v])
        denom = min(vol, vols - vol)
        out.append(cut / denom if denom > 0 else np.inf)
        sset.add(v)
    return np.asarray(out)


def test_exact_sweep_matches_bruteforce(community):
    seeds = np.array([5, 100], np.int32)
    res = LC.local_cluster(community, seeds, ALPHA, 1e-5, sketch=None)
    order = np.asarray(res.order)
    phi = np.asarray(res.conductance)
    for s in range(len(seeds)):
        sup = int(np.asarray(res.support)[s])
        ref = _brute_conductance(community, order[s], sup)
        np.testing.assert_allclose(phi[s, :sup], ref, rtol=1e-5, atol=1e-6)


def test_best_prefix_recovers_planted_community(community):
    # a seed inside a planted community should find a low-conductance
    # cluster; exact sweep φ must beat the whole-graph-random baseline
    res = LC.local_cluster(community, np.array([5], np.int32), ALPHA, 1e-5)
    assert float(res.best_conductance[0]) < 0.15
    assert 10 < int(res.best_size[0]) < community.n // 2


def test_sketch_sweep_within_bounds(kron):
    seeds = np.array([3, 17, 101, 200], np.int32)
    sk = SK.build(kron, "bf", storage_budget=2.0)
    res_e = LC.local_cluster(kron, seeds, ALPHA, 1e-4, sketch=None)
    res_b = LC.local_cluster(kron, seeds, ALPHA, 1e-4, sketch=sk)
    deg = np.asarray(kron.deg)
    order = np.asarray(res_e.order)
    phi_e = np.asarray(res_e.conductance)
    phi_b = np.asarray(res_b.conductance)
    checked = 0
    for s in range(len(seeds)):
        sup = int(np.asarray(res_e.support)[s])
        degs = deg[order[s, :sup]]
        vol = np.cumsum(degs)
        denom = np.minimum(vol, 2 * kron.m - vol)
        half = bounds.sweep_conductance_interval(
            degs, denom, sk.total_bits, sk.num_hashes, delta=0.05)
        ok = np.isfinite(phi_e[s, :sup]) & np.isfinite(phi_b[s, :sup])
        diff = np.abs(np.where(ok, phi_e[s, :sup], 0.0)
                      - np.where(ok, phi_b[s, :sup], 0.0))
        assert (diff[ok] <= half[ok]).all()
        checked += int(ok.sum())
    assert checked > 100          # the assertion actually exercised prefixes


def test_seed_batch_order_determinism(kron):
    seeds = np.array([3, 17, 101, 200], np.int32)
    perm = np.array([2, 0, 3, 1])
    sk = SK.build(kron, "bf", storage_budget=1.0)
    res_a = LC.local_cluster(kron, seeds, ALPHA, 1e-4, sketch=sk)
    res_p = LC.local_cluster(kron, seeds[perm], ALPHA, 1e-4, sketch=sk)
    np.testing.assert_array_equal(np.asarray(res_a.order)[perm],
                                  np.asarray(res_p.order))
    np.testing.assert_array_equal(np.asarray(res_a.conductance)[perm],
                                  np.asarray(res_p.conductance))
    np.testing.assert_array_equal(np.asarray(res_a.best_size)[perm],
                                  np.asarray(res_p.best_size))


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(pseed=st.integers(0, 1_000_000),
       seeds_list=st.lists(st.integers(0, 255), min_size=2, max_size=6,
                           unique=True))
def test_property_seed_batch_permutation_invariance(pseed, seeds_list):
    # per-seed answers are row-independent: any permutation of the batch
    # permutes the outputs bit-for-bit (no cross-row leakage through the
    # batched push/sweep or the pow2 padding)
    kron = _kron()
    seeds = np.asarray(seeds_list, np.int32)
    perm = np.random.default_rng(pseed).permutation(seeds.size)
    res_a = LC.local_cluster(kron, seeds, ALPHA, 1e-3)
    res_p = LC.local_cluster(kron, seeds[perm], ALPHA, 1e-3)
    np.testing.assert_array_equal(np.asarray(res_a.order)[perm],
                                  np.asarray(res_p.order))
    np.testing.assert_array_equal(np.asarray(res_a.conductance)[perm],
                                  np.asarray(res_p.conductance))
    np.testing.assert_array_equal(np.asarray(res_a.best_size)[perm],
                                  np.asarray(res_p.best_size))


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(a=st.integers(0, 255), b=st.integers(0, 255))
def test_property_duplicate_seeds_dedup(a, b):
    # duplicates are first-class (the server pads batches by repeating a
    # seed): copies produce bit-identical rows to each other and to the
    # dedup'd batch
    kron = _kron()
    res_dup = LC.local_cluster(kron, np.array([a, b, a], np.int32),
                               ALPHA, 1e-3)
    res_uni = LC.local_cluster(kron, np.array([a, b], np.int32), ALPHA, 1e-3)
    for field in ("order", "conductance", "best_size", "support"):
        dup = np.asarray(getattr(res_dup, field))
        uni = np.asarray(getattr(res_uni, field))
        np.testing.assert_array_equal(dup[0], dup[2], field)
        np.testing.assert_array_equal(dup[:2], uni, field)


@settings(max_examples=N_EXAMPLES, deadline=None)
@given(seed=st.integers(0, 255))
def test_property_alpha_to_one_collapses_to_seed(seed):
    # alpha → 1: the walk teleports home almost surely, so PPR mass
    # concentrates on the seed itself and the push converges immediately
    kron = _kron()
    alpha = 0.999
    p, r, iters = LC.ppr_push(kron, np.array([seed], np.int32), alpha, 1e-3)
    p, r = np.asarray(p)[0], np.asarray(r)[0]
    assert int(np.argmax(p)) == seed
    assert p[seed] >= alpha - 1e-6                 # teleport share stays home
    off = p.sum() - p[seed] + r.sum()
    assert off <= (1.0 - alpha) + 1e-6
    assert int(iters) <= 2


def test_ragged_seed_batches_share_one_compile(kron):
    # the pow2 seed bucketing bounds XLA compiles: every ragged batch size
    # in (4, 8] lands on the same compiled program for both push layouts
    LC.ppr_push(kron, np.arange(8, dtype=np.int32), ALPHA, 1e-3)
    LC.ppr_push_sparse(kron, np.arange(8, dtype=np.int32), ALPHA, 1e-3)
    dense_before = LC._ppr_push_impl._cache_size()
    sparse_before = LC._ppr_push_sparse_impl._cache_size()
    for s in (5, 6, 7, 8):
        p, _, _ = LC.ppr_push(kron, np.arange(s, dtype=np.int32), ALPHA, 1e-3)
        assert p.shape == (s, kron.n)              # pad rows sliced back off
        fr = LC.ppr_push_sparse(kron, np.arange(s, dtype=np.int32), ALPHA,
                                1e-3)
        assert fr.idx.shape[0] == s
    assert LC._ppr_push_impl._cache_size() == dense_before
    assert LC._ppr_push_sparse_impl._cache_size() == sparse_before


def test_plan_sweep_cap_bounds_prefix(kron):
    res = LC.local_cluster(kron, np.array([3], np.int32), ALPHA, 1e-4,
                           sweep_cap=32)
    assert np.asarray(res.order).shape[1] == 32
    assert int(res.best_size[0]) <= 32


def test_members_and_session_entrypoint(kron):
    sess = ENG.session(kron, "bf", storage_budget=1.0)
    res = sess.local_cluster(np.array([3, 17], np.int32))
    mem = res.members(0)
    assert mem.shape[0] == int(res.best_size[0])
    assert len(set(mem.tolist())) == mem.shape[0]      # no duplicates
    assert (mem < kron.n).all()


# ---------------------------------------------------------------------------
# bounds helpers
# ---------------------------------------------------------------------------

def test_sweep_bound_monotone_and_sizing():
    degs = np.full(64, 8.0)
    r1 = bounds.sweep_cut_rmse(degs, 4096, 2)
    assert (np.diff(r1) >= 0).all()                    # accumulates
    r2 = bounds.sweep_cut_rmse(degs, 16384, 2)
    assert r2[-1] < r1[-1]                             # more bits, less error
    w_loose = bounds.bloom_words_for_conductance(0.5, 8, 64, 2000)
    w_tight = bounds.bloom_words_for_conductance(0.05, 8, 64, 2000)
    assert w_tight >= w_loose >= 2


# ---------------------------------------------------------------------------
# streaming: localcluster over DynamicGraph.view() == fresh static session
# ---------------------------------------------------------------------------

def test_stream_localcluster_matches_static(kron):
    rng = np.random.default_rng(7)
    edges = np.asarray(kron.edges)
    keep = rng.permutation(edges.shape[0])
    initial, arriving = edges[keep[:-200]], edges[keep[-200:]]
    st = StreamSession(DynamicGraph.from_edges(kron.n, initial), kind="bf",
                      storage_budget=1.0)
    st.apply_delta(inserts=arriving[:120])
    st.apply_delta(inserts=arriving[120:],
                   deletes=initial[rng.choice(initial.shape[0], 15,
                                              replace=False)])
    seeds = np.array([3, 17, 101], np.int32)
    res_stream = st.local_cluster(seeds, ALPHA, 1e-4)

    gs = G.from_edge_array(st.dyn.n, st.dyn.edge_array())
    mt = st.maintainer
    sk = SK.build(gs, mt.kind, words=mt.words, num_hashes=mt.num_hashes,
                  seed=mt.seed)
    res_static = ENG.session(gs, sk, plan=st.session.plan).local_cluster(
        seeds, ALPHA, 1e-4)
    np.testing.assert_array_equal(np.asarray(res_stream.order),
                                  np.asarray(res_static.order))
    np.testing.assert_array_equal(np.asarray(res_stream.conductance),
                                  np.asarray(res_static.conductance))
    np.testing.assert_array_equal(np.asarray(res_stream.best_conductance),
                                  np.asarray(res_static.best_conductance))


def test_server_localcluster_batching(kron):
    st = StreamSession(DynamicGraph.from_graph(kron), kind="bf",
                      storage_budget=1.0)
    srv = BatchedQueryServer(st)
    rids = [srv.submit_local_cluster(s) for s in (3, 17, 101)]
    rid_other = srv.submit_local_cluster(3, alpha=0.3)    # separate group
    out = srv.flush()
    direct = st.local_cluster(np.array([3, 17, 101], np.int32))
    for i, rid in enumerate(rids):
        val = out[rid].value
        assert val["size"] == int(direct.best_size[i])
        assert val["conductance"] == pytest.approx(
            float(direct.best_conductance[i]))
        np.testing.assert_array_equal(val["members"], direct.members(i))
    assert out[rid_other].value["size"] >= 1
