"""Probabilistic set representations of vertex neighborhoods (ProbGraph §II-D).

All builders are pure functions of the padded adjacency and return fixed-size
per-vertex sketch arrays — the fixed size is the point: it turns skewed set
algebra into perfectly regular, shardable tensor ops (paper Fig. 1, panel 5).

Representations:
  * Bloom filter  : uint32[n, words]  (B = 32*words bits, b hash functions)
  * k-Hash MinHash: int32 [n, k]      (argmin element per hash function)
  * 1-Hash MinHash: int32 [n, k]      (elements with k smallest hashes, sorted
                                       by hash; sentinel-padded)
  * KMV           : float32[n, k]     (k smallest hash values in (0,1];
                                       pad = 2.0)

Sentinel for missing elements is ``n`` (== number of vertices), which can
never be a real vertex id.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .hashing import hash_u32, hash_unit_interval, np_hash_u32

PAD_HASH = np.uint32(0xFFFFFFFF)
KMV_PAD = np.float32(2.0)


# ----------------------------------------------------------------------------
# Storage-budget parameterization (paper §V-A)
# ----------------------------------------------------------------------------

def bloom_words_for_budget(n: int, m: int, s: float, min_words: int = 2) -> int:
    """Bloom words/vertex so total sketch bits ≈ s × CSR bits (CSR ≈ (2m+n)·32)."""
    csr_bits = (2 * m + n + 1) * 32
    bits_per_vertex = max(1.0, s * csr_bits / max(n, 1))
    words = int(np.ceil(bits_per_vertex / 32.0))
    # round UP to a multiple of 2 words (64-bit lanes) for vectorization;
    # clamping to min_words happens first so an odd min_words cannot leak an
    # odd word count through
    words = max(words, min_words)
    words += words % 2
    return words


def minhash_k_for_budget(n: int, m: int, s: float, min_k: int = 4) -> int:
    """k so total MinHash storage ≈ s × CSR storage (Wk bits per vertex)."""
    csr_words = 2 * m + n + 1
    k = int(np.floor(s * csr_words / max(n, 1)))
    return max(min_k, k)


# ----------------------------------------------------------------------------
# Bloom filters
# ----------------------------------------------------------------------------

def _positions(adj: jax.Array, n: int, num_hashes: int, total_bits: int, seed) -> Tuple[jax.Array, jax.Array]:
    """Bit positions [rows, d_max, b] + validity mask for padded adjacency."""
    valid = adj < n
    safe = jnp.where(valid, adj, 0)
    seeds = jnp.arange(num_hashes, dtype=jnp.uint32) + jnp.uint32(seed) * jnp.uint32(0x9E3779B9)
    h = hash_u32(safe[..., None], seeds)  # [rows, d_max, b]
    pos = (h % jnp.uint32(total_bits)).astype(jnp.int32)
    return pos, valid


def bloom_rows(adj_rows: jax.Array, n: int, words: int, num_hashes: int = 2,
               seed: int = 0) -> jax.Array:
    """Bloom rows for a block of padded adjacency rows (pad value == n).

    The per-chunk body of :func:`build_bloom`, exposed so streaming
    maintenance can selectively rebuild dirty rows through the exact same
    code path (results are independent of the rows' padded width).
    """
    total_bits = words * 32
    rows = adj_rows.shape[0]
    pos, valid = _positions(adj_rows, n, num_hashes, total_bits, seed)
    row_idx = jnp.broadcast_to(jnp.arange(rows)[:, None, None], pos.shape)
    bits = jnp.zeros((rows, total_bits), dtype=jnp.bool_)
    bits = bits.at[row_idx.reshape(-1), jnp.where(
        jnp.broadcast_to(valid[..., None], pos.shape), pos, 0).reshape(-1)].max(
        jnp.broadcast_to(valid[..., None], pos.shape).reshape(-1))
    return pack_bits(bits)


def build_bloom(graph: Graph, words: int, num_hashes: int = 2, seed: int = 0,
                chunk: int = 4096) -> jax.Array:
    """Pure-JAX Bloom construction: uint32[n, words].

    Scatters boolean bits per chunk of vertices (duplicate positions are
    benign for OR), then bit-packs 32→1. Work O(b·Σd_v), depth O(log(b·d))
    (paper Table V).
    """
    fn = functools.partial(bloom_rows, n=graph.n, words=words,
                           num_hashes=num_hashes, seed=seed)
    return _map_vertex_chunks(fn, graph.adj, chunk, (words,), jnp.uint32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """bool[..., 32*w] -> uint32[..., w]."""
    *lead, total = bits.shape
    w = total // 32
    b32 = bits.reshape(*lead, w, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(b32 << shifts, axis=-1).astype(jnp.uint32)


def unpack_bits(wordsarr: jax.Array) -> jax.Array:
    """uint32[..., w] -> bool[..., 32*w]."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (wordsarr[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(*wordsarr.shape[:-1], wordsarr.shape[-1] * 32).astype(jnp.bool_)


def build_bloom_np(graph: Graph, words: int, num_hashes: int = 2, seed: int = 0) -> np.ndarray:
    """Fast host-side construction with np.bitwise_or.at (one-shot builds)."""
    n = graph.n
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    deg = np.diff(indptr)
    rows = np.repeat(np.arange(n), deg)
    total_bits = words * 32
    out = np.zeros((n, words), dtype=np.uint32)
    golden = 0x9E3779B9
    for i in range(num_hashes):
        s = np.uint32((i + seed * golden) & 0xFFFFFFFF)
        pos = np_hash_u32(indices, int(s)) % total_bits
        np.bitwise_or.at(out, (rows, pos >> 5), np.uint32(1) << (pos & 31))
    return out


def bloom_membership(bloom_row: jax.Array, candidates: jax.Array, n: int,
                     num_hashes: int, total_bits: int, seed: int = 0) -> jax.Array:
    """Query x ∈ X for a batch of candidates against one Bloom row.

    bloom_row: uint32[words]; candidates: int32[...]; returns bool[...].
    """
    valid = candidates < n
    safe = jnp.where(valid, candidates, 0)
    seeds = jnp.arange(num_hashes, dtype=jnp.uint32) + jnp.uint32(seed) * jnp.uint32(0x9E3779B9)
    h = hash_u32(safe[..., None], seeds)
    pos = (h % jnp.uint32(total_bits)).astype(jnp.int32)
    word = pos >> 5
    bit = (pos & 31).astype(jnp.uint32)
    got = (bloom_row[word] >> bit) & jnp.uint32(1)
    return jnp.all(got == 1, axis=-1) & valid


# ----------------------------------------------------------------------------
# MinHash (k-Hash): one argmin per hash function (multiset semantics)
# ----------------------------------------------------------------------------

def khash_rows(adj_rows: jax.Array, n: int, k: int, seed: int = 0) -> jax.Array:
    """k-Hash rows for a block of padded adjacency rows (pad value == n)."""
    valid = adj_rows < n
    safe = jnp.where(valid, adj_rows, 0)
    seeds = jnp.arange(k, dtype=jnp.uint32) + jnp.uint32(seed) * jnp.uint32(0x9E3779B9)
    h = hash_u32(safe[..., None], seeds)               # [rows, d_max, k]
    h = jnp.where(valid[..., None], h, PAD_HASH)
    arg = jnp.argmin(h, axis=1)                         # [rows, k]
    elems = jnp.take_along_axis(adj_rows, arg, axis=1)  # may pick pad if empty
    any_valid = jnp.any(valid, axis=1, keepdims=True)
    return jnp.where(any_valid, elems, n).astype(jnp.int32)


def build_khash(graph: Graph, k: int, seed: int = 0, chunk: int = 4096) -> jax.Array:
    """int32[n, k]: element with the smallest h_i among N_v, per hash fn i.

    Empty neighborhoods yield the sentinel ``n``. Work O(k·Σd_v),
    depth O(log d) (paper Table V).
    """
    fn = functools.partial(khash_rows, n=graph.n, k=k, seed=seed)
    return _map_vertex_chunks(fn, graph.adj, chunk, (k,), jnp.int32)


# ----------------------------------------------------------------------------
# MinHash (1-Hash): k smallest under a single hash function, sorted by hash
# ----------------------------------------------------------------------------

def onehash_rows(adj_rows: jax.Array, n: int, k: int, seed: int = 0) -> jax.Array:
    """1-Hash rows for a block of padded adjacency rows (pad value == n).

    Requires rows sorted ascending (pads last) so the stable argsort breaks
    hash ties by element id — the invariant both `Graph.adj` and the
    streaming `DynamicGraph` maintain.
    """
    valid = adj_rows < n
    safe = jnp.where(valid, adj_rows, 0)
    h = hash_u32(safe, jnp.uint32(seed))
    h = jnp.where(valid, h, PAD_HASH)
    order = jnp.argsort(h, axis=1)[:, :k]
    elems = jnp.take_along_axis(adj_rows, order, axis=1)
    hsel = jnp.take_along_axis(h, order, axis=1)
    return jnp.where(hsel == PAD_HASH, n, elems).astype(jnp.int32)


def build_1hash(graph: Graph, k: int, seed: int = 0, chunk: int = 4096) -> jax.Array:
    """int32[n, k]: elements with the k smallest h(x), ascending by hash.

    Rows with d_v < k are sentinel-padded. Work O(Σd_v), depth O(log d).
    """
    fn = functools.partial(onehash_rows, n=graph.n, k=k, seed=seed)
    return _map_vertex_chunks(fn, graph.adj, chunk, (k,), jnp.int32)


def onehash_values(sketch: jax.Array, n: int, seed: int = 0) -> jax.Array:
    """Recompute hash values of a 1-Hash sketch (uint32; pads -> 0xFFFFFFFF)."""
    valid = sketch < n
    h = hash_u32(jnp.where(valid, sketch, 0), jnp.uint32(seed))
    return jnp.where(valid, h, PAD_HASH)


# ----------------------------------------------------------------------------
# KMV: k smallest hash values mapped to (0, 1]  (paper §IX)
# ----------------------------------------------------------------------------

def kmv_rows(adj_rows: jax.Array, n: int, k: int, seed: int = 0) -> jax.Array:
    """KMV rows for a block of padded adjacency rows (pad value == n)."""
    valid = adj_rows < n
    safe = jnp.where(valid, adj_rows, 0)
    h = hash_unit_interval(safe, jnp.uint32(seed))
    h = jnp.where(valid, h, KMV_PAD)
    return jnp.sort(h, axis=1)[:, :k]


def build_kmv(graph: Graph, k: int, seed: int = 0, chunk: int = 4096) -> jax.Array:
    """float32[n, k]: k smallest unit-interval hashes, ascending; pad = 2.0."""
    fn = functools.partial(kmv_rows, n=graph.n, k=k, seed=seed)
    return _map_vertex_chunks(fn, graph.adj, chunk, (k,), jnp.float32)


# ----------------------------------------------------------------------------
# shared chunked-map driver
# ----------------------------------------------------------------------------

def _map_vertex_chunks(fn, adj: jax.Array, chunk: int, out_tail: Tuple[int, ...], dtype):
    n = adj.shape[0]
    if n <= chunk:
        return fn(adj)
    pad_rows = (-n) % chunk
    adj_p = jnp.pad(adj, ((0, pad_rows), (0, 0)), constant_values=n)
    blocks = adj_p.reshape(-1, chunk, adj.shape[1])
    out = jax.lax.map(fn, blocks)
    return out.reshape(-1, *out_tail)[:n].astype(dtype)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SketchSet:
    """A named bundle of sketches for one graph (what `ProbGraph(g, ...)` is
    in the paper's Listing 6). Registered as a pytree (data = leaf) so it
    can be passed through jit as a runtime argument."""
    data: jax.Array             # per-vertex sketch matrix
    kind: str = dataclasses.field(metadata=dict(static=True))
    num_hashes: int = dataclasses.field(metadata=dict(static=True))
    k: int = dataclasses.field(metadata=dict(static=True))
    seed: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def total_bits(self) -> int:
        if self.kind == "bf":
            return self.data.shape[1] * 32
        return 0


def build(graph: Graph, kind: str, storage_budget: float = 0.25,
          num_hashes: int = 2, seed: int = 0, words: int | None = None,
          k: int | None = None) -> SketchSet:
    """Paper Listing 6 entry point: ProbGraph(g, KIND, s)."""
    if kind == "bf":
        w = words if words is not None else bloom_words_for_budget(graph.n, graph.m, storage_budget)
        return SketchSet(data=build_bloom(graph, w, num_hashes, seed), kind="bf",
                         num_hashes=num_hashes, k=0, seed=seed, n=graph.n)
    kk = k if k is not None else minhash_k_for_budget(graph.n, graph.m, storage_budget)
    if kind in ("kh", "1h", "kmv"):
        builder = {"kh": build_khash, "1h": build_1hash, "kmv": build_kmv}[kind]
        return SketchSet(data=builder(graph, kk, seed), kind=kind,
                         num_hashes=0, k=kk, seed=seed, n=graph.n)
    raise ValueError(f"unknown sketch kind: {kind}")
