"""ProbGraph core: probabilistic set representations for graph mining.

Paper: Besta et al., "ProbGraph: High-Performance and High-Accuracy Graph
Mining with Probabilistic Set Representations" (CS.DC 2022).
"""
from . import bounds, estimators, exact, graph, hashing, intersect, sketches
from .graph import Graph, from_edge_array, erdos_renyi, kronecker, barabasi_albert
from .sketches import SketchSet, build
from .intersect import make_pair_cardinality_fn
from .algorithms import (
    triangle_count,
    five_clique_count,
    four_clique_count,
    jarvis_patrick,
    pair_similarity,
    link_prediction_effectiveness,
)

__all__ = [
    "Graph", "from_edge_array", "erdos_renyi", "kronecker", "barabasi_albert",
    "SketchSet", "build", "make_pair_cardinality_fn",
    "triangle_count", "five_clique_count", "four_clique_count", "jarvis_patrick",
    "pair_similarity", "link_prediction_effectiveness",
    "bounds", "estimators", "exact", "graph", "hashing", "intersect", "sketches",
]
