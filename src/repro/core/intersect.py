"""Uniform |N_u ∩ N_v| providers: exact or any ProbGraph estimator.

`make_pair_cardinality_fn(graph, sketch)` returns a batched pure function
pairs[P,2] -> float32[P] — the paper's "plug in PG routines in place of
exact set intersections" (Listing 6). Estimator *selection* lives here;
*execution* (chunking, padding, degree-ordered layout, kernel block shapes,
edge sharding) is the batched mining engine's job: algorithms consume this
seam through `repro.engine` and an `EnginePlan`.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import estimators as est
from .exact import exact_pair_cardinalities
from .graph import Graph
from .sketches import SketchSet, onehash_values

CardFn = Callable[[jax.Array], jax.Array]


def make_pair_cardinality_fn(graph: Graph, sketch: Optional[SketchSet] = None,
                             *, use_kernel: bool = False,
                             variant: str = "union",
                             estimator: Optional[str] = None,
                             block_e: int = 8, block_w: int = 512) -> CardFn:
    """Build the batched pairs[P, 2] -> float32[P] cardinality provider."""
    if sketch is None:
        def exact_fn(pairs: jax.Array) -> jax.Array:
            return exact_pair_cardinalities(graph, pairs).astype(jnp.float32)
        return exact_fn

    kind = estimator or sketch.kind
    deg = graph.deg

    if sketch.kind == "bf":
        # Both dispatch paths (fused Pallas pass / jnp gather) are lowerings
        # of the same compiled set expression, so their integer popcounts —
        # and therefore the float estimates — are bit-identical. The lazy
        # import keeps the core -> engine edge out of module load order.
        from ..engine import setexpr

        data = sketch.data
        b = sketch.num_hashes
        total_bits = data.shape[1] * 32
        u_row, v_row = setexpr.rows(2)
        expr = (u_row | v_row) if kind == "bf_or" else (u_row & v_row)
        ce = setexpr.compile_expr(expr, block_e=block_e, block_w=block_w,
                                  use_kernel=use_kernel)

        def bf_fn(pairs: jax.Array) -> jax.Array:
            """Per-pair BF estimate from the compiled expression's ones."""
            ones = ce.ones(data, pairs)
            if kind == "bf_l":
                return ones.astype(jnp.float32) / b
            if kind == "bf_or":
                du = jnp.take(deg, pairs[:, 0]).astype(jnp.float32)
                dv = jnp.take(deg, pairs[:, 1]).astype(jnp.float32)
                union_est = est.bf_intersection_and_from_ones(
                    ones, total_bits, b)
                return du + dv - union_est
            return est.bf_intersection_and_from_ones(ones, total_bits, b)
        return bf_fn

    if sketch.kind == "kh":
        def kh_fn(pairs: jax.Array) -> jax.Array:
            ru = jnp.take(sketch.data, pairs[:, 0], axis=0)
            rv = jnp.take(sketch.data, pairs[:, 1], axis=0)
            du = jnp.take(deg, pairs[:, 0])
            dv = jnp.take(deg, pairs[:, 1])
            return est.khash_intersection(ru, rv, du, dv, sketch.n)
        return kh_fn

    if sketch.kind == "1h":
        def oneh_fn(pairs: jax.Array) -> jax.Array:
            ru = jnp.take(sketch.data, pairs[:, 0], axis=0)
            rv = jnp.take(sketch.data, pairs[:, 1], axis=0)
            du = jnp.take(deg, pairs[:, 0])
            dv = jnp.take(deg, pairs[:, 1])
            hu = onehash_values(ru, sketch.n, sketch.seed)
            hv = onehash_values(rv, sketch.n, sketch.seed)
            return est.onehash_intersection(ru, rv, hu, hv, du, dv, sketch.n, variant)
        return oneh_fn

    if sketch.kind == "kmv":
        def kmv_fn(pairs: jax.Array) -> jax.Array:
            ru = jnp.take(sketch.data, pairs[:, 0], axis=0)
            rv = jnp.take(sketch.data, pairs[:, 1], axis=0)
            du = jnp.take(deg, pairs[:, 0])
            dv = jnp.take(deg, pairs[:, 1])
            return est.kmv_intersection(ru, rv, du, dv)
        return kmv_fn

    raise ValueError(f"unknown sketch kind {sketch.kind}")
