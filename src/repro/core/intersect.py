"""Uniform |N_u ∩ N_v| providers: exact or any ProbGraph estimator.

`make_pair_cardinality_fn(graph, sketch)` returns a batched pure function
pairs[P,2] -> float32[P] — the paper's "plug in PG routines in place of
exact set intersections" (Listing 6). Estimator *selection* lives here;
*execution* (chunking, padding, degree-ordered layout, kernel block shapes,
edge sharding) is the batched mining engine's job: algorithms consume this
seam through `repro.engine` and an `EnginePlan`.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import estimators as est
from .exact import exact_pair_cardinalities
from .graph import Graph
from .sketches import SketchSet, onehash_values

CardFn = Callable[[jax.Array], jax.Array]


def make_pair_cardinality_fn(graph: Graph, sketch: Optional[SketchSet] = None,
                             use_kernel: bool = False, variant: str = "union",
                             estimator: Optional[str] = None,
                             block_e: int = 8, block_w: int = 512) -> CardFn:
    if sketch is None:
        def exact_fn(pairs: jax.Array) -> jax.Array:
            return exact_pair_cardinalities(graph, pairs).astype(jnp.float32)
        return exact_fn

    kind = estimator or sketch.kind
    deg = graph.deg

    if sketch.kind == "bf":
        data = sketch.data
        b = sketch.num_hashes
        total_bits = data.shape[1] * 32
        if use_kernel:
            from repro.kernels import ops as kops

            def bf_kernel_fn(pairs: jax.Array) -> jax.Array:
                ones = kops.bf_edge_intersect(data, pairs, block_e=block_e,
                                              block_w=block_w)
                if kind == "bf_l":
                    return ones.astype(jnp.float32) / b
                return est.bf_intersection_and_from_ones(ones, total_bits, b)
            return bf_kernel_fn

        def bf_fn(pairs: jax.Array) -> jax.Array:
            ru = jnp.take(data, pairs[:, 0], axis=0)
            rv = jnp.take(data, pairs[:, 1], axis=0)
            if kind == "bf_l":
                return est.bf_intersection_limit(ru, rv, b)
            if kind == "bf_or":
                du = jnp.take(deg, pairs[:, 0])
                dv = jnp.take(deg, pairs[:, 1])
                return est.bf_intersection_or(ru, rv, b, du, dv)
            return est.bf_intersection_and(ru, rv, b)
        return bf_fn

    if sketch.kind == "kh":
        def kh_fn(pairs: jax.Array) -> jax.Array:
            ru = jnp.take(sketch.data, pairs[:, 0], axis=0)
            rv = jnp.take(sketch.data, pairs[:, 1], axis=0)
            du = jnp.take(deg, pairs[:, 0])
            dv = jnp.take(deg, pairs[:, 1])
            return est.khash_intersection(ru, rv, du, dv, sketch.n)
        return kh_fn

    if sketch.kind == "1h":
        def oneh_fn(pairs: jax.Array) -> jax.Array:
            ru = jnp.take(sketch.data, pairs[:, 0], axis=0)
            rv = jnp.take(sketch.data, pairs[:, 1], axis=0)
            du = jnp.take(deg, pairs[:, 0])
            dv = jnp.take(deg, pairs[:, 1])
            hu = onehash_values(ru, sketch.n, sketch.seed)
            hv = onehash_values(rv, sketch.n, sketch.seed)
            return est.onehash_intersection(ru, rv, hu, hv, du, dv, sketch.n, variant)
        return oneh_fn

    if sketch.kind == "kmv":
        def kmv_fn(pairs: jax.Array) -> jax.Array:
            ru = jnp.take(sketch.data, pairs[:, 0], axis=0)
            rv = jnp.take(sketch.data, pairs[:, 1], axis=0)
            du = jnp.take(deg, pairs[:, 0])
            dv = jnp.take(deg, pairs[:, 1])
            return est.kmv_intersection(ru, rv, du, dv)
        return kmv_fn

    raise ValueError(f"unknown sketch kind {sketch.kind}")
