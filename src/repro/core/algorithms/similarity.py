"""Vertex similarity measures (paper Listing 3).

Jaccard / Overlap / Common / Total derive from |N_u∩N_v| + exact degrees.
Adamic-Adar / Resource-Allocation need the intersection *elements*: the
sketch path enumerates u's neighbors (CSR) and tests membership in B_v via
the Bloom query — the paper's "set membership" primitive.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ... import engine as eng
from ..exact import exact_pair_intersection_elements
from ..graph import Graph
from ..sketches import SketchSet, bloom_membership


def similarity_from_cardinalities(inter: jax.Array, du: jax.Array,
                                  dv: jax.Array, measure: str) -> jax.Array:
    """Derive a cardinality-based similarity from |N_u∩N_v| + degrees.

    The shared scoring step of Listing 3/4: one per-edge cardinality pass
    (e.g. a MiningSession's cache) feeds any of these measures.
    """
    if measure == "common":
        return inter
    if measure == "total":
        return du + dv - inter
    if measure == "jaccard":
        return inter / jnp.maximum(du + dv - inter, 1.0)
    if measure == "overlap":
        return inter / jnp.maximum(jnp.minimum(du, dv), 1.0)
    raise ValueError(measure)


def pair_similarity(graph: Graph, pairs: jax.Array, measure: str,
                    sketch: Optional[SketchSet] = None,
                    plan: Optional[eng.EnginePlan] = None, **kw) -> jax.Array:
    """measure ∈ {jaccard, overlap, common, total, adamic_adar, resource_alloc}."""
    du = jnp.take(graph.deg, pairs[:, 0]).astype(jnp.float32)
    dv = jnp.take(graph.deg, pairs[:, 1]).astype(jnp.float32)

    if measure in ("jaccard", "overlap", "common", "total"):
        plan = eng.resolve_plan(plan, graph, sketch, kw)
        inter = eng.edge_cardinalities(graph, sketch, plan, edges=pairs)
        return similarity_from_cardinalities(inter, du, dv, measure)

    if measure in ("adamic_adar", "resource_alloc"):
        n = graph.n
        if sketch is None:
            elems = exact_pair_intersection_elements(graph, pairs)   # [P, d_max]
        elif sketch.kind == "bf":
            cand = jnp.take(graph.adj, pairs[:, 0], axis=0)          # N_u elements
            rows_v = jnp.take(sketch.data, pairs[:, 1], axis=0)
            total_bits = sketch.data.shape[1] * 32
            member = jax.vmap(
                lambda row, c: bloom_membership(row, c, n, sketch.num_hashes,
                                                total_bits, sketch.seed))(rows_v, cand)
            elems = jnp.where(member, cand, n)
        else:
            raise ValueError(f"{measure} needs exact or BF representation")
        dw = jnp.take(graph.deg, jnp.where(elems < n, elems, 0)).astype(jnp.float32)
        if measure == "adamic_adar":
            w = 1.0 / jnp.maximum(jnp.log(jnp.maximum(dw, 2.0)), 1e-6)
        else:
            w = 1.0 / jnp.maximum(dw, 1.0)
        return jnp.sum(jnp.where(elems < n, w, 0.0), axis=1)

    raise ValueError(measure)
