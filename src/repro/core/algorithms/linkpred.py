"""Link-prediction effectiveness testing (paper Listing 5).

Remove a random subset E_rndm of edges, score candidate pairs on the sparse
graph with a similarity measure S, predict the top-|E_rndm| pairs, and report
ef = |E_predict ∩ E_rndm| / |E_rndm|. Candidates are distance-2 pairs of the
sparse graph (wedge endpoints) — scoring all O(n²) non-edges is neither what
practitioners do nor what the measures can rank meaningfully.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp

from ... import engine as eng
from ..graph import Graph, from_edge_array
from ..sketches import SketchSet, build
from .similarity import pair_similarity


def split_edges(graph: Graph, removed_fraction: float, seed: int = 0
                ) -> Tuple[Graph, np.ndarray]:
    """Returns (sparse graph, removed edge array [R,2])."""
    rng = np.random.default_rng(seed)
    edges = np.asarray(graph.edges)
    m = edges.shape[0]
    r = max(1, int(removed_fraction * m))
    idx = rng.permutation(m)
    removed = edges[idx[:r]]
    kept = edges[idx[r:]]
    sparse = from_edge_array(graph.n, kept, pad_to_max_degree=None)
    return sparse, removed


def _distance2_candidates(sparse: Graph, limit: int = 2_000_000) -> np.ndarray:
    """Distance-2 non-adjacent pairs (u < w) of the sparse graph."""
    indptr = np.asarray(sparse.indptr)
    indices = np.asarray(sparse.indices)
    n = sparse.n
    pairs = set()
    edge_set = set()
    e = np.asarray(sparse.edges)
    for u, v in e:
        edge_set.add((int(u), int(v)))
    for v in range(n):
        nbrs = indices[indptr[v]:indptr[v + 1]]
        for i in range(len(nbrs)):
            for j in range(i + 1, len(nbrs)):
                a, b = int(nbrs[i]), int(nbrs[j])
                if a > b:
                    a, b = b, a
                if (a, b) not in edge_set:
                    pairs.add((a, b))
                    if len(pairs) >= limit:
                        break
    if not pairs:
        return np.zeros((0, 2), dtype=np.int32)
    return np.asarray(sorted(pairs), dtype=np.int32)


def link_prediction_effectiveness(graph: Graph, measure: str = "common",
                                  removed_fraction: float = 0.1,
                                  sketch_kind: Optional[str] = None,
                                  storage_budget: float = 0.25,
                                  num_hashes: int = 2, seed: int = 0,
                                  plan: Optional[eng.EnginePlan] = None) -> float:
    """Full Listing-5 protocol; returns ef ∈ [0, 1]."""
    sparse, removed = split_edges(graph, removed_fraction, seed)
    candidates = _distance2_candidates(sparse)
    if candidates.shape[0] == 0:
        return 0.0
    sketch: Optional[SketchSet] = None
    if sketch_kind is not None:
        sketch = build(sparse, sketch_kind, storage_budget,
                       num_hashes=num_hashes, seed=seed)
    scores = np.asarray(
        pair_similarity(sparse, jnp.asarray(candidates), measure, sketch,
                        plan=plan))
    r = removed.shape[0]
    top = np.argsort(-scores, kind="stable")[:r]
    predicted = {(int(a), int(b)) for a, b in candidates[top]}
    truth = {(int(a), int(b)) for a, b in removed}
    return len(predicted & truth) / r
