"""Jarvis–Patrick clustering (paper Listing 4).

Two vertices u, v end up in the same cluster iff they are adjacent AND their
vertex similarity passes a threshold. Similarity ∈ {common (|N_u∩N_v| ≥ τ),
jaccard, overlap} — all driven by the |X∩Y| provider, exact or sketched.

Connected components over the kept edges run as data-parallel min-label
propagation (scatter-min + gather until fixpoint) — the shared-memory
union-find of the CPU implementation does not map to SPMD; label propagation
has depth O(diameter·log n) and is the standard XLA-friendly CC.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ... import engine as eng
from ..graph import Graph
from ..sketches import SketchSet


def _connected_components(n: int, edges: jax.Array, keep: jax.Array,
                          max_iters: int = 200) -> jax.Array:
    u, v = edges[:, 0], edges[:, 1]

    def body(state):
        labels, _, it = state
        lu = jnp.take(labels, u)
        lv = jnp.take(labels, v)
        new_edge_label = jnp.minimum(lu, lv)
        src_u = jnp.where(keep, new_edge_label, lu)
        src_v = jnp.where(keep, new_edge_label, lv)
        new = labels.at[u].min(src_u)
        new = new.at[v].min(src_v)
        # pointer jumping: labels <- labels[labels] (halves chain length)
        new = jnp.take(new, new)
        changed = jnp.any(new != labels)
        return new, changed, it + 1

    def cond(state):
        _, changed, it = state
        return changed & (it < max_iters)

    labels0 = jnp.arange(n, dtype=jnp.int32)
    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.bool_(True), jnp.int32(0)))
    return labels


def jarvis_patrick(graph: Graph, sketch: Optional[SketchSet] = None,
                   similarity: str = "common", threshold: float = 2.0,
                   plan: Optional[eng.EnginePlan] = None,
                   edge_cards: Optional[jax.Array] = None, **kw):
    """Returns (labels int32[n], num_clusters int32).

    similarity: 'common' (|N_u∩N_v| ≥ threshold), 'jaccard' or 'overlap'
    (ratio ≥ threshold). ``edge_cards`` lets a MiningSession reuse its
    shared per-edge cardinality pass.
    """
    from .similarity import similarity_from_cardinalities

    edges = graph.edges
    if edge_cards is None:
        plan = eng.resolve_plan(plan, graph, sketch, kw)
        edge_cards = eng.edge_cardinalities(graph, sketch, plan)
    du = jnp.take(graph.deg, edges[:, 0]).astype(jnp.float32)
    dv = jnp.take(graph.deg, edges[:, 1]).astype(jnp.float32)
    score = similarity_from_cardinalities(edge_cards, du, dv, similarity)
    keep = score >= threshold
    labels = _connected_components(graph.n, edges, keep)
    # count distinct labels among non-isolated semantics: every vertex is its
    # own cluster when no kept edge touches it (paper counts all clusters)
    num = jnp.sum(labels == jnp.arange(graph.n, dtype=jnp.int32))
    return labels, num
