"""Triangle Counting (paper Listing 1 + §VII estimators TC_★).

TC_★ = (1/3) Σ_{(u,v)∈E} |N_u ∩ N_v|_★ over canonical edges. Exact when
card_fn is the galloping baseline; an AU/CN (and for kH, MLE) estimator when
card_fn is a ProbGraph estimator (Thm VII.1 gives the tail bounds).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..graph import Graph
from ..intersect import CardFn, fold_edges, make_pair_cardinality_fn
from ..sketches import SketchSet


def triangle_count(graph: Graph, sketch: Optional[SketchSet] = None,
                   card_fn: Optional[CardFn] = None,
                   edge_chunk: int = 65536, **kw) -> jax.Array:
    """Returns float32 TC estimate (exact integer value if sketch is None)."""
    fn = card_fn or make_pair_cardinality_fn(graph, sketch, **kw)

    def chunk(pairs, mask):
        vals = fn(pairs)
        return jnp.sum(jnp.where(mask, vals, 0.0))

    return fold_edges(graph.edges, chunk, edge_chunk) / 3.0


def local_clustering_coefficient(graph: Graph, sketch: Optional[SketchSet] = None,
                                 **kw) -> jax.Array:
    """Per-vertex clustering coefficient c_v = 2·t_v / (d_v (d_v−1)) where t_v
    sums |N_u∩N_v| over v's incident edges (a TC application, paper §III-A)."""
    fn = make_pair_cardinality_fn(graph, sketch, **kw)
    edges = graph.edges
    vals = fn(edges)
    tv = jnp.zeros(graph.n, jnp.float32)
    tv = tv.at[edges[:, 0]].add(vals)
    tv = tv.at[edges[:, 1]].add(vals)
    d = graph.deg.astype(jnp.float32)
    denom = jnp.maximum(d * (d - 1.0), 1.0)
    return tv / denom
