"""Triangle Counting (paper Listing 1 + §VII estimators TC_★).

TC_★ = (1/3) Σ_{(u,v)∈E} |N_u ∩ N_v|_★ over canonical edges. Exact when
card_fn is the galloping baseline; an AU/CN (and for kH, MLE) estimator when
card_fn is a ProbGraph estimator (Thm VII.1 gives the tail bounds).

Execution (chunking, padding, kernel dispatch, edge sharding) is delegated
to the batched mining engine: pass an ``EnginePlan`` or the legacy kwargs
(``edge_chunk=``, ``use_kernel=``, ...), which resolve to one.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ... import engine as eng
from ..graph import Graph
from ..intersect import CardFn
from ..sketches import SketchSet


def triangle_count(graph: Graph, sketch: Optional[SketchSet] = None,
                   card_fn: Optional[CardFn] = None,
                   plan: Optional[eng.EnginePlan] = None, **kw) -> jax.Array:
    """Returns float32 TC estimate (exact integer value if sketch is None)."""
    plan = eng.resolve_plan(plan, graph, sketch, kw)
    return eng.sum_edge_cardinalities(graph, sketch, plan, card_fn) / 3.0


def local_clustering_coefficient(graph: Graph, sketch: Optional[SketchSet] = None,
                                 plan: Optional[eng.EnginePlan] = None,
                                 edge_cards: Optional[jax.Array] = None,
                                 **kw) -> jax.Array:
    """Per-vertex clustering coefficient c_v = 2·t_v / (d_v (d_v−1)) where t_v
    sums |N_u∩N_v| over v's incident edges (a TC application, paper §III-A).

    ``edge_cards`` lets a MiningSession reuse its shared per-edge pass.
    """
    if edge_cards is None:
        plan = eng.resolve_plan(plan, graph, sketch, kw)
        edge_cards = eng.edge_cardinalities(graph, sketch, plan)
    edges = graph.edges
    tv = jnp.zeros(graph.n, jnp.float32)
    tv = tv.at[edges[:, 0]].add(edge_cards)
    tv = tv.at[edges[:, 1]].add(edge_cards)
    d = graph.deg.astype(jnp.float32)
    denom = jnp.maximum(d * (d - 1.0), 1.0)
    return tv / denom
