"""4-Clique Counting (paper Listing 2, reformulated to expose |X∩Y∩Z|).

Formulation: enumerate ordered triangles u<v<w (edge (u,v) × wedge w∈N_v,
w>v, plus the closing test w∈N_u), then

    cc4 = (1/4) Σ_{triangles u<v<w} |N_u ∩ N_v ∩ N_w|

since each 4-clique {a<b<c<d} contains 4 triangles and the 4th vertex is
counted by the triple intersection exactly once per triangle (self-ids are
excluded automatically: u ∉ N_u). Triple intersections:

  exact : two chained gallops                   O(d log d) / wedge
  BF    : popcount(Bu AND Bv AND Bw), Eq. 2     O(B/W)     / wedge
  kH    : 3-way aligned matches; |∩3| = J3(S1−S2)/(1−J3) with pairwise
          MinHash estimates plugged in          O(k)       / wedge

The closing test w∈N_u uses the BF membership query when a BF sketch is
given (fully sketch-resident, like the paper's set-centric formulation) and
an exact binary search otherwise.

Chunking/padding is the engine's (``EnginePlan``); on the BF kernel path the
per-chunk wedge triples flatten into one (u, v, w) list and the triple
popcounts come from the 3-way block-gather Pallas kernel — identical integer
popcounts to the jnp gather, so estimates are bit-identical.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ... import engine as eng
from .. import estimators as est
from ..graph import Graph
from ..sketches import SketchSet, bloom_membership
from ..estimators import khash_jaccard, minhash_intersection


def four_clique_count(graph: Graph, sketch: Optional[SketchSet] = None,
                      plan: Optional[eng.EnginePlan] = None,
                      exact_closing_test: bool = False, **kw) -> jax.Array:
    n, d_max = graph.n, graph.d_max
    adj, deg = graph.adj, graph.deg

    kind = sketch.kind if sketch is not None else "exact"
    if plan is None:
        # wedge chunks are [C, d_max]-shaped, so default far below the
        # pair-fold chunk; an explicit plan's edge_chunk wins untouched
        kw.setdefault("edge_chunk", 1024)
    plan = eng.resolve_plan(plan, graph, sketch, kw)

    def wedge_values(pairs, mask):
        """For an edge chunk [C,2]: sum over qualifying wedges of |∩3|."""
        u, v = pairs[:, 0], pairs[:, 1]
        nv = jnp.take(adj, v, axis=0)                      # [C, d_max] candidates w
        w_ok = (nv < n) & (nv > v[:, None]) & mask[:, None]

        # closing test: w ∈ N_u
        if kind == "bf" and not exact_closing_test:
            rows_u = jnp.take(sketch.data, u, axis=0)
            total_bits = sketch.data.shape[1] * 32
            member = jax.vmap(
                lambda row, cand: bloom_membership(row, cand, n, sketch.num_hashes,
                                                   total_bits, sketch.seed)
            )(rows_u, nv)
        else:
            rows_adj_u = jnp.take(adj, u, axis=0)
            pos = jnp.clip(jax.vmap(jnp.searchsorted)(rows_adj_u, nv), 0, d_max - 1)
            member = jnp.take_along_axis(rows_adj_u, pos, axis=1) == nv
        tri = w_ok & member                                # [C, d_max] triangle mask

        if kind == "exact":
            # |N_u ∩ N_v ∩ N_w| via chained gallops
            rows_u_adj = jnp.take(adj, u, axis=0)
            rows_v_adj = jnp.take(adj, v, axis=0)
            posv = jnp.clip(jax.vmap(jnp.searchsorted)(rows_v_adj, rows_u_adj), 0, d_max - 1)
            inter_uv = jnp.where(
                (jnp.take_along_axis(rows_v_adj, posv, axis=1) == rows_u_adj)
                & (rows_u_adj < n), rows_u_adj, n)          # [C, d_max] elements
            w_rows = jnp.take(adj, jnp.where(tri, nv, 0), axis=0)  # [C,d_max,d_max]
            posw = jnp.clip(
                jax.vmap(jax.vmap(jnp.searchsorted, in_axes=(0, None)))(w_rows, inter_uv),
                0, d_max - 1)
            hits = (jnp.take_along_axis(w_rows, posw, axis=2)
                    == inter_uv[:, None, :]) & (inter_uv[:, None, :] < n)
            triple = jnp.sum(hits, axis=2).astype(jnp.float32)    # [C, d_max]
        elif kind == "bf":
            b = sketch.num_hashes
            total_bits = sketch.data.shape[1] * 32
            w_safe = jnp.where(tri, nv, 0)
            # engine's 3-way popcount provider: block-gather kernel when
            # planned, broadcast jnp gather otherwise
            ones = eng.wedge_triple_ones(sketch, u, v, w_safe, plan)
            triple = est.bf_intersection_and_from_ones(ones, total_bits, b)
        elif kind == "kh":
            mu = jnp.take(sketch.data, u, axis=0)[:, None, :]
            mv = jnp.take(sketch.data, v, axis=0)[:, None, :]
            mw = jnp.take(sketch.data, jnp.where(tri, nv, 0), axis=0)
            k = sketch.k
            valid3 = (mu < n) & (mv < n) & (mw < n)
            j3 = jnp.sum((mu == mv) & (mv == mw) & valid3, axis=-1).astype(jnp.float32) / k
            du = jnp.take(deg, u).astype(jnp.float32)[:, None]
            dv = jnp.take(deg, v).astype(jnp.float32)[:, None]
            dw = jnp.take(deg, jnp.where(tri, nv, 0)).astype(jnp.float32)
            s1 = du + dv + dw
            # pairwise estimates for inclusion-exclusion
            iuv = minhash_intersection(khash_jaccard(mu, mv, n), du, dv)
            iuw = minhash_intersection(khash_jaccard(mu, mw, n), du, dw)
            ivw = minhash_intersection(khash_jaccard(mv, mw, n), dv, dw)
            s2 = iuv + iuw + ivw
            j3 = jnp.minimum(j3, 0.999)
            triple = jnp.maximum(j3 * (s1 - s2) / (1.0 - j3), 0.0)
        else:
            raise ValueError(f"4-clique not supported for sketch kind {kind}")

        return jnp.sum(jnp.where(tri, triple, 0.0))

    return eng.fold_edges(graph.edges, wedge_values, plan) / 4.0
