"""k-Clique Counting (paper Listing 2, reformulated to expose |X∩Y∩Z|).

Formulation: enumerate ordered triangles u<v<w (edge (u,v) × wedge w∈N_v,
w>v, plus the closing test w∈N_u), then

    cc4 = (1/4) Σ_{triangles u<v<w} |N_u ∩ N_v ∩ N_w|

since each 4-clique {a<b<c<d} contains 4 triangles and the 4th vertex is
counted by the triple intersection exactly once per triangle (self-ids are
excluded automatically: u ∉ N_u). Triple intersections:

  exact : two chained gallops                   O(d log d) / wedge
  BF    : popcount(Bu AND Bv AND Bw), Eq. 2     O(B/W)     / wedge
  kH    : 3-way aligned matches; |∩3| = J3(S1−S2)/(1−J3) with pairwise
          MinHash estimates plugged in          O(k)       / wedge

The closing test w∈N_u uses the BF membership query when a BF sketch is
given (fully sketch-resident, like the paper's set-centric formulation) and
an exact binary search otherwise.

Chunking/padding is the engine's (``EnginePlan``); on the BF kernel path the
per-chunk wedge triples flatten into one (u, v, w) list and the triple
popcounts come from the compiled 3-way AND set expression — identical
integer popcounts to the jnp gather, so estimates are bit-identical.

``five_clique_count`` extends the same scheme one level: enumerate 4-cliques
u<v<w<x from each canonical edge (both w and x drawn from N_v, closed
against N_u and each other), then

    cc5 = (1/5) Σ_{4-cliques u<v<w<x} |N_u ∩ N_v ∩ N_w ∩ N_x|

with the 4-way intersection served by the engine's compiled 4-way AND
expression (``eng.wedge_quad_ones``) — the first workload that needed no
new hand-rolled kernel. See ``core.bounds.bf_kway_and_mse_bound`` for why
the direct k-way AND estimator is preferred over 2^k−1-term
inclusion–exclusion.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ... import engine as eng
from .. import estimators as est
from ..graph import Graph
from ..sketches import SketchSet, bloom_membership
from ..estimators import khash_jaccard, minhash_intersection


def four_clique_count(graph: Graph, sketch: Optional[SketchSet] = None,
                      plan: Optional[eng.EnginePlan] = None,
                      exact_closing_test: bool = False, **kw) -> jax.Array:
    """Scalar 4-clique count: (1/4) Σ_{triangles u<v<w} |N_u ∩ N_v ∩ N_w|."""
    n, d_max = graph.n, graph.d_max
    adj, deg = graph.adj, graph.deg

    kind = sketch.kind if sketch is not None else "exact"
    if plan is None:
        # wedge chunks are [C, d_max]-shaped, so default far below the
        # pair-fold chunk; an explicit plan's edge_chunk wins untouched
        kw.setdefault("edge_chunk", 1024)
    plan = eng.resolve_plan(plan, graph, sketch, kw)

    def wedge_values(pairs, mask):
        """For an edge chunk [C,2]: sum over qualifying wedges of |∩3|."""
        u, v = pairs[:, 0], pairs[:, 1]
        nv = jnp.take(adj, v, axis=0)                      # [C, d_max] candidates w
        w_ok = (nv < n) & (nv > v[:, None]) & mask[:, None]

        # closing test: w ∈ N_u
        if kind == "bf" and not exact_closing_test:
            rows_u = jnp.take(sketch.data, u, axis=0)
            total_bits = sketch.data.shape[1] * 32
            member = jax.vmap(
                lambda row, cand: bloom_membership(row, cand, n, sketch.num_hashes,
                                                   total_bits, sketch.seed)
            )(rows_u, nv)
        else:
            rows_adj_u = jnp.take(adj, u, axis=0)
            pos = jnp.clip(jax.vmap(jnp.searchsorted)(rows_adj_u, nv), 0, d_max - 1)
            member = jnp.take_along_axis(rows_adj_u, pos, axis=1) == nv
        tri = w_ok & member                                # [C, d_max] triangle mask

        if kind == "exact":
            # |N_u ∩ N_v ∩ N_w| via chained gallops
            rows_u_adj = jnp.take(adj, u, axis=0)
            rows_v_adj = jnp.take(adj, v, axis=0)
            posv = jnp.clip(jax.vmap(jnp.searchsorted)(rows_v_adj, rows_u_adj), 0, d_max - 1)
            inter_uv = jnp.where(
                (jnp.take_along_axis(rows_v_adj, posv, axis=1) == rows_u_adj)
                & (rows_u_adj < n), rows_u_adj, n)          # [C, d_max] elements
            w_rows = jnp.take(adj, jnp.where(tri, nv, 0), axis=0)  # [C,d_max,d_max]
            posw = jnp.clip(
                jax.vmap(jax.vmap(jnp.searchsorted, in_axes=(0, None)))(w_rows, inter_uv),
                0, d_max - 1)
            hits = (jnp.take_along_axis(w_rows, posw, axis=2)
                    == inter_uv[:, None, :]) & (inter_uv[:, None, :] < n)
            triple = jnp.sum(hits, axis=2).astype(jnp.float32)    # [C, d_max]
        elif kind == "bf":
            b = sketch.num_hashes
            total_bits = sketch.data.shape[1] * 32
            w_safe = jnp.where(tri, nv, 0)
            # engine's 3-way popcount provider: block-gather kernel when
            # planned, broadcast jnp gather otherwise
            ones = eng.wedge_triple_ones(sketch, u, v, w_safe, plan)
            triple = est.bf_intersection_and_from_ones(ones, total_bits, b)
        elif kind == "kh":
            mu = jnp.take(sketch.data, u, axis=0)[:, None, :]
            mv = jnp.take(sketch.data, v, axis=0)[:, None, :]
            mw = jnp.take(sketch.data, jnp.where(tri, nv, 0), axis=0)
            k = sketch.k
            valid3 = (mu < n) & (mv < n) & (mw < n)
            j3 = jnp.sum((mu == mv) & (mv == mw) & valid3, axis=-1).astype(jnp.float32) / k
            du = jnp.take(deg, u).astype(jnp.float32)[:, None]
            dv = jnp.take(deg, v).astype(jnp.float32)[:, None]
            dw = jnp.take(deg, jnp.where(tri, nv, 0)).astype(jnp.float32)
            s1 = du + dv + dw
            # pairwise estimates for inclusion-exclusion
            iuv = minhash_intersection(khash_jaccard(mu, mv, n), du, dv)
            iuw = minhash_intersection(khash_jaccard(mu, mw, n), du, dw)
            ivw = minhash_intersection(khash_jaccard(mv, mw, n), dv, dw)
            s2 = iuv + iuw + ivw
            j3 = jnp.minimum(j3, 0.999)
            triple = jnp.maximum(j3 * (s1 - s2) / (1.0 - j3), 0.0)
        else:
            raise ValueError(f"4-clique not supported for sketch kind {kind}")

        return jnp.sum(jnp.where(tri, triple, 0.0))

    return eng.fold_edges(graph.edges, wedge_values, plan) / 4.0


def five_clique_count(graph: Graph, sketch: Optional[SketchSet] = None,
                      plan: Optional[eng.EnginePlan] = None,
                      exact_closing_test: bool = False, **kw) -> jax.Array:
    """Scalar 5-clique count via 4-way sketch intersections.

    Enumerates each 4-clique {u<v<w<x} exactly once from its canonical edge
    (u, v): both w and x are drawn from N_v (they must neighbor v), closed
    against N_u and against each other, with v < w < x. Then

        cc5 = (1/5) Σ_{4-cliques} |N_u ∩ N_v ∩ N_w ∩ N_x|

    since each 5-clique contains five 4-cliques and the fifth vertex is in
    the 4-way intersection exactly once per 4-clique (u ∉ N_u excludes the
    clique's own vertices). The 4-way intersection is the compiled 4-way
    AND set expression via :func:`repro.engine.engine.wedge_quad_ones` —
    no new kernel. Exact and BF sketch paths; other kinds raise.
    """
    n, d_max = graph.n, graph.d_max
    adj = graph.adj

    kind = sketch.kind if sketch is not None else "exact"
    if kind not in ("exact", "bf"):
        raise ValueError(f"5-clique not supported for sketch kind {kind}")
    if plan is None:
        # wedge-pair chunks are [C, d_max, d_max]-shaped, one order heavier
        # than the 4-clique wedges; an explicit plan's edge_chunk wins
        kw.setdefault("edge_chunk", 256)
    plan = eng.resolve_plan(plan, graph, sketch, kw)

    def wedge_pair_values(pairs, mask):
        """For an edge chunk [C,2]: sum over qualifying 4-cliques of |∩4|."""
        u, v = pairs[:, 0], pairs[:, 1]
        nv = jnp.take(adj, v, axis=0)                # [C, d] candidates w, x
        w_ok = (nv < n) & (nv > v[:, None]) & mask[:, None]
        safe = jnp.where(nv < n, nv, 0)

        # closing tests: candidate ∈ N_u, and x ∈ N_w for candidate pairs
        if kind == "bf" and not exact_closing_test:
            total_bits = sketch.data.shape[1] * 32
            rows_u = jnp.take(sketch.data, u, axis=0)
            member_u = jax.vmap(
                lambda row, cand: bloom_membership(
                    row, cand, n, sketch.num_hashes, total_bits, sketch.seed)
            )(rows_u, nv)
            rows_w = jnp.take(sketch.data, safe, axis=0)      # [C, d, words]
            adj_wx = jax.vmap(jax.vmap(
                lambda row, cand: bloom_membership(
                    row, cand, n, sketch.num_hashes, total_bits, sketch.seed),
                in_axes=(0, None)))(rows_w, nv)               # [C, d, d]
        else:
            rows_adj_u = jnp.take(adj, u, axis=0)
            pos = jnp.clip(jax.vmap(jnp.searchsorted)(rows_adj_u, nv),
                           0, d_max - 1)
            member_u = jnp.take_along_axis(rows_adj_u, pos, axis=1) == nv
            w_rows = jnp.take(adj, safe, axis=0)              # [C, d, cap]
            posx = jnp.clip(
                jax.vmap(jax.vmap(jnp.searchsorted,
                                  in_axes=(0, None)))(w_rows, nv),
                0, d_max - 1)
            adj_wx = (jnp.take_along_axis(w_rows, posx, axis=2)
                      == nv[:, None, :]) & (nv[:, None, :] < n)
        tri = w_ok & member_u                                 # [C, d]
        # 4-clique mask over candidate pairs (i -> w, j -> x): both close
        # the (u, v) edge, x > w orders the pair, (w, x) must be an edge
        quad = (tri[:, :, None] & tri[:, None, :]
                & (nv[:, None, :] > nv[:, :, None]) & adj_wx)  # [C, d, d]

        if kind == "exact":
            rows_u_adj = jnp.take(adj, u, axis=0)
            rows_v_adj = jnp.take(adj, v, axis=0)
            posv = jnp.clip(
                jax.vmap(jnp.searchsorted)(rows_v_adj, rows_u_adj),
                0, d_max - 1)
            inter_uv = jnp.where(
                (jnp.take_along_axis(rows_v_adj, posv, axis=1) == rows_u_adj)
                & (rows_u_adj < n), rows_u_adj, n)            # [C, cap]
            w_adj = jnp.take(adj, safe, axis=0)               # [C, d, cap]
            pos4 = jnp.clip(
                jax.vmap(jax.vmap(jnp.searchsorted,
                                  in_axes=(0, None)))(w_adj, inter_uv),
                0, d_max - 1)
            # hits[c, i, e]: does element e of N_u ∩ N_v also neighbor
            # candidate i? |∩4| for pair (i, j) is then Σ_e hits_i · hits_j
            hits = ((jnp.take_along_axis(w_adj, pos4, axis=2)
                     == inter_uv[:, None, :])
                    & (inter_uv[:, None, :] < n)).astype(jnp.float32)
            quad_val = jnp.einsum("cie,cje->cij", hits, hits)
        else:
            b = sketch.num_hashes
            total_bits = sketch.data.shape[1] * 32
            w_safe = jnp.where(tri, nv, 0)
            ones = eng.wedge_quad_ones(sketch, u, v, w_safe, w_safe, plan)
            quad_val = est.bf_intersection_and_from_ones(ones, total_bits, b)

        return jnp.sum(jnp.where(quad, quad_val, 0.0))

    return eng.fold_edges(graph.edges, wedge_pair_values, plan) / 5.0
