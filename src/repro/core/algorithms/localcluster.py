"""Local graph clustering: batched PPR forward push + sketch-gated sweep cuts.

The seed-centric workload (Andersen–Chung–Lang / PPR-Nibble, parallelized as
in Shun et al. 2016 and frontier-formulated as in GBBS): given seed vertices,
find low-conductance clusters around them without touching the whole graph's
combinatorics. Two phases, both expressed as the regular batched tensor work
the engine already emits:

  1. **Forward push** — approximate personalized PageRank, in one of two
     frontier layouts selected by ``plan.frontier_mode``:

     * **dense** — ``r`` residual and ``p`` estimate as ``[S, n]`` float
       tensors; one synchronous push step activates *every* vertex over the
       ACL threshold at once and propagates mass through an edge-parallel
       scatter-add over ``graph.edges``. Simple and fast while ``[S, n]``
       fits, fatal at web scale.
     * **sparse** — the Shun et al. frontier-sparse formulation: each seed's
       support lives in a capped ``[S, cap]`` index+value table (``idx``
       ascending vertex ids padded with the sentinel ``n``, plus ``p``/``r``
       values), with ``cap = O(1/(alpha·eps))`` from the ACL work bound,
       pow2-bucketed so ragged (alpha, eps) choices reuse compiles. A push
       round gathers the active rows' padded adjacency, then merges table
       and neighbor contributions with one stable sort-by-id + segment
       scatter-add — memory scales with the support, never ``n``. If a
       round ever produces more than ``cap`` distinct support vertices the
       whole batch *spills*: the overflow flag aborts the loop and the
       caller re-runs the dense push. Spill is a performance event, never a
       correctness event (invariant 10 in docs/ARCHITECTURE.md).

     Both layouts implement the same synchronous ACL dynamics, so they agree
     within float associativity (and exactly on support/sweep order in
     practice); every consumer downstream of the push sees one result type.

  2. **Sweep cut** — order vertices by degree-normalized PPR mass and scan
     prefixes ``S_1 ⊂ S_2 ⊂ …``, picking the prefix with minimum conductance
     ``φ(S) = cut(S) / min(vol(S), vol(V∖S))``. The expensive term is the
     per-step ``|N(v_j) ∩ S_{j-1}|`` (cut increment = ``d(v_j) − 2·|N(v_j) ∩
     S_{j-1}|``). The sketch-gated path replaces it with ProbGraph set
     algebra: the swept prefix is itself a Bloom filter (exclusive prefix-OR
     of single-vertex bit rows under the *same* hash family as the
     neighborhood sketch), so every increment is one AND+popcount between
     ``B(N(v_j))`` and ``B(S_{j-1})`` — ``bf_edge_intersect``-style work,
     optionally routed through the Pallas pair kernel. The exact fallback
     counts swept-rank hits through the padded adjacency.

``core.bounds.sweep_cut_rmse`` / ``bloom_words_for_conductance`` make the
sketch knob quantitative: size the Bloom filter from a target conductance
error instead of guessing.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from ... import engine as eng
from ...obs import metrics as obs_metrics
from ...obs import trace
from ..estimators import bf_intersection_and_from_ones
from ..graph import Graph
from ..sketches import SketchSet, bloom_rows


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SparseFrontier:
    """Capped per-seed PPR support: the sparse push's index+value buffers.

    Each seed's support is a row of ``cap`` slots holding ascending vertex
    ids (``idx``; unused slots carry the sentinel ``n``) with the matching
    PPR estimate ``p`` and residual ``r`` values. Memory is ``O(S · cap)``
    with ``cap = O(1/(alpha·eps))`` — independent of ``n``.

    Attributes:
      idx: int32[S, cap]   support vertex ids, ascending per row; pad = n.
      p:   float32[S, cap] PPR estimates aligned with ``idx``.
      r:   float32[S, cap] final residuals aligned with ``idx``.
      iterations: int32    push rounds executed.
      overflowed: bool[]   True when some round needed more than ``cap``
                           distinct support vertices — the buffers are then
                           truncated mid-round and MUST NOT be consumed;
                           callers re-run the dense push (a spill).
      n: static int        vertex count (the id sentinel).
    """

    idx: jax.Array
    p: jax.Array
    r: jax.Array
    iterations: jax.Array
    overflowed: jax.Array
    n: int = dataclasses.field(metadata=dict(static=True))

    @property
    def cap(self) -> int:
        """Slots per seed (the pow2-bucketed frontier capacity)."""
        return int(self.idx.shape[1])

    def sizes(self):
        """int64[S]: occupied slots (support size) per seed (host-side)."""
        import numpy as np
        return np.sum(np.asarray(self.idx) < self.n, axis=1).astype(np.int64)

    def densify(self):
        """Scatter back to dense ``(p, r)`` float32[S, n] (test/debug aid —
        materializes exactly what the dense push would have produced, up to
        float summation order)."""
        s_batch = self.idx.shape[0]
        rows = jnp.arange(s_batch)[:, None]
        # width n+1 gives sentinel ids a scratch column sliced away below
        p = jnp.zeros((s_batch, self.n + 1), jnp.float32)
        r = jnp.zeros((s_batch, self.n + 1), jnp.float32)
        p = p.at[rows, self.idx].add(self.p, mode="drop")
        r = r.at[rows, self.idx].add(self.r, mode="drop")
        return p[:, :self.n], r[:, :self.n]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LocalClusterResult:
    """Per-seed output of :func:`local_cluster` (a batched sweep).

    Attributes:
      order:       int32[S, k]   sweep order (vertices by descending p/deg;
                                 entries past ``support`` are padding).
      conductance: float32[S, k] conductance of each swept prefix (``inf``
                                 at invalid prefixes: empty, full-volume, or
                                 past the seed's support).
      best_idx:    int32[S]      prefix index minimizing conductance.
      best_conductance: float32[S] the minimum conductance itself (``inf``
                                 when the seed admits no valid prefix).
      best_size:   int32[S]      cluster size = best_idx + 1, or 0 when no
                                 valid prefix exists (isolated seed /
                                 whole-volume support) — ``members`` is
                                 then empty.
      support:     int32[S]      number of vertices with positive PPR mass
                                 that entered the sweep (≤ k).
      ppr:         float32[S, n] the approximate PPR vectors (dense push
                                 output; ``None`` on the sparse path, where
                                 the same data lives in ``frontier``).
      residual:    float32[S, n] the final push residuals (dense path only;
                                 ``None`` on the sparse path).
      frontier:    the :class:`SparseFrontier` buffers (sparse path only;
                                 ``None`` on the dense path).
      iterations:  int32         push iterations until convergence/cap.
      spilled:     static bool   True when the sparse push overflowed its
                                 cap and the answer was recomputed densely —
                                 a performance event, never a correctness
                                 event.
    """

    order: jax.Array
    conductance: jax.Array
    best_idx: jax.Array
    best_conductance: jax.Array
    best_size: jax.Array
    support: jax.Array
    ppr: Optional[jax.Array]
    residual: Optional[jax.Array]
    iterations: jax.Array
    frontier: Optional[SparseFrontier] = None
    spilled: bool = dataclasses.field(default=False,
                                      metadata=dict(static=True))

    def members(self, s: int):
        """Vertex ids of seed ``s``'s best cluster (host-side convenience)."""
        import numpy as np
        k = int(np.asarray(self.best_size)[s])
        return np.asarray(self.order)[s, :k]

    def footprint(self, s: int):
        """Vertex ids seed ``s``'s answer depends on (sorted int64).

        Every vertex that ever held PPR mass or residual during the push:
        the push dynamics read only these vertices' degrees and incident
        edges (a vertex whose residual never crossed the ACL threshold still
        gates on ``r[v] ≥ eps·d(v)``, so its *degree* is load-bearing), and
        the sweep reads only rows/degrees of the swept support — a subset.
        This is the serving-tier cache's invalidation set; conductance
        additionally depends on the total volume ``2m``, which the cache
        guards separately (see ``stream.cache``). On the sparse path the
        set falls out of the index buffer directly (already id-sorted), so
        footprints cost ``O(cap)`` instead of an ``O(n)`` dense scan.
        """
        import numpy as np
        if self.frontier is not None:
            idx = np.asarray(self.frontier.idx[s])
            p = np.asarray(self.frontier.p[s])
            r = np.asarray(self.frontier.r[s])
            keep = (idx < self.frontier.n) & ((p > 0) | (r > 0))
            return idx[keep].astype(np.int64)
        p = np.asarray(self.ppr[s])
        r = np.asarray(self.residual[s])
        return np.nonzero((p > 0) | (r > 0))[0].astype(np.int64)


# ----------------------------------------------------------------------------
# phase 1: batched approximate PPR (ACL forward push, synchronous frontier)
# ----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "max_iters"))
def _ppr_push_impl(deg: jax.Array, edges: jax.Array, seeds: jax.Array,
                   alpha, eps, *, n: int, max_iters: int):
    """Jitted push body over raw arrays (not the Graph pytree, whose static
    ``n_edges`` would retrace per streaming delta); ``edges`` is pow2-padded
    with sentinel (n, n) rows whose scatter contributions drop."""
    deg = deg.astype(jnp.float32)
    s_batch = seeds.shape[0]
    thresh = eps * jnp.maximum(deg, 1.0)

    p0 = jnp.zeros((s_batch, n), jnp.float32)
    r0 = p0.at[jnp.arange(s_batch), seeds].add(1.0)

    def body(state):
        p, r, it = state
        active = r >= thresh[None, :]
        push = jnp.where(active, r, 0.0)
        # isolated vertices (deg 0) absorb their whole mass into p
        p = p + jnp.where(deg[None, :] > 0, alpha * push, push)
        give = jnp.where(deg[None, :] > 0,
                         (1.0 - alpha) * push / jnp.maximum(deg[None, :], 1.0),
                         0.0)
        # edge-parallel propagate: each canonical edge carries mass both
        # ways; sentinel pad rows scatter out of bounds and are dropped
        recv = jnp.zeros_like(r)
        recv = recv.at[:, edges[:, 1]].add(
            give[:, jnp.minimum(edges[:, 0], n - 1)], mode="drop")
        recv = recv.at[:, edges[:, 0]].add(
            give[:, jnp.minimum(edges[:, 1], n - 1)], mode="drop")
        return p, jnp.where(active, 0.0, r) + recv, it + 1

    def cond(state):
        _, r, it = state
        return jnp.any(r >= thresh[None, :]) & (it < max_iters)

    p, r, iters = jax.lax.while_loop(cond, body, (p0, r0, jnp.int32(0)))
    return p, r, iters


def _padded_edges(graph: Graph) -> jax.Array:
    """graph.edges padded to a pow2 bucket with sentinel (n, n) rows, so the
    jitted push compiles once per size class instead of once per delta."""
    m = graph.edges.shape[0]
    m_b = eng.plan.pow2_bucket(m)
    if m_b == m:
        return graph.edges
    pad = jnp.full((m_b - m, 2), graph.n, graph.edges.dtype)
    return jnp.concatenate([graph.edges, pad], axis=0)


def _padded_seeds(seeds: jax.Array):
    """Pad a seed batch to its pow2 bucket by repeating the first seed.

    Push rows are fully independent (per-row state, per-row updates), and
    the loop's stop condition is a max over rows, so duplicating an existing
    row changes neither the surviving rows' values nor the iteration count —
    slicing the pad rows off afterwards is bit-identical to running the
    ragged batch. This bounds XLA recompiles to one per (n, edge-bucket,
    seed-bucket) class instead of one per distinct ragged batch size.
    """
    s = seeds.shape[0]
    s_b = eng.plan.pow2_bucket(s)
    if s_b == s:
        return seeds, s
    fill = seeds[0] if s else jnp.int32(0)
    pad = jnp.full((s_b - s,), fill, seeds.dtype)
    return jnp.concatenate([seeds, pad]), s


def ppr_push(graph: Graph, seeds: jax.Array, alpha: float = 0.15,
             eps: float = 1e-4, max_iters: int = 200):
    """Batched ACL forward push: approximate PPR for a batch of seeds.

    Args:
      graph:     the (frozen or view) graph; only ``deg`` and ``edges`` are
                 read, so the result is independent of adjacency padding.
      seeds:     int32[S] seed vertex ids (duplicates allowed — pad a batch
                 by repeating any seed and drop the copies).
      alpha:     teleport probability of the underlying random walk.
      eps:       push tolerance — iterate until every residual satisfies
                 ``r[v] < eps·max(d(v), 1)``.
      max_iters: hard cap on synchronous push rounds.

    Returns:
      ``(p, r, iters)``: PPR estimates float32[S, n], final residuals
      float32[S, n], and the int32 number of rounds executed. The ACL
      invariant bounds the truncation: ``p ≤ ppr_exact ≤ p + eps·deg``
      coordinatewise (in exact arithmetic). The implementation is jitted
      with ``alpha``/``eps`` as traced scalars and both the edge list and
      the seed batch padded to pow2 buckets, so repeated serving calls —
      including across streaming deltas, where ``m`` changes every batch,
      and ragged ad-hoc seed batches — reuse one compiled program per
      (n, edge-bucket, seed-bucket) class.
    """
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    seeds_b, s = _padded_seeds(seeds)
    p, r, iters = _ppr_push_impl(graph.deg, _padded_edges(graph), seeds_b,
                                 jnp.float32(alpha), jnp.float32(eps),
                                 n=graph.n, max_iters=max_iters)
    return p[:s], r[:s], iters


# ----------------------------------------------------------------------------
# phase 1 (sparse): capped-frontier push — memory O(S/(alpha·eps)), not O(S·n)
# ----------------------------------------------------------------------------

# auto mode only goes sparse when the capped buffers undercut the dense
# [S, n] tensors by at least this factor — below that, the dense push's
# simpler rounds win and nothing is at risk of spilling
_AUTO_SPARSE_FACTOR = 8


def frontier_cap_for(alpha: float, eps: float, n: int,
                     override: Optional[int] = None) -> int:
    """Sparse-frontier capacity: pow2 bucket of the ACL support bound.

    The push performs at most ``1/(alpha·eps)`` pushes total (each push on
    ``v`` retires ``≥ alpha·eps·d(v)`` residual mass from an invariant total
    of 1), so the support it can ever touch is ``O(1/(alpha·eps))`` —
    independent of ``n``. The bucket is clamped to ``pow2(n)`` (a cap above
    that buys nothing) and to ≥ 2 so the degenerate single-slot table never
    compiles. ``override`` (``plan.frontier_cap``) replaces the bound but is
    bucketed the same way; undersizing only risks a spill, never a wrong
    answer.
    """
    if override is not None:
        cap = int(override)
    else:
        cap = int(math.ceil(1.0 / (float(alpha) * float(eps))))
    return min(eng.plan.pow2_bucket(cap, lo=2), eng.plan.pow2_bucket(n, lo=2))


def resolve_frontier_mode(plan: eng.EnginePlan, n: int, alpha: float,
                          eps: float) -> str:
    """Dense-vs-sparse plan selection ("auto" resolves by cap-vs-n ratio)."""
    mode = plan.frontier_mode
    if mode not in ("auto", "dense", "sparse"):
        raise ValueError(f"unknown frontier_mode: {mode!r}")
    if mode != "auto":
        return mode
    cap = frontier_cap_for(alpha, eps, n, plan.frontier_cap)
    return "sparse" if cap * _AUTO_SPARSE_FACTOR <= n else "dense"


@functools.partial(jax.jit, static_argnames=("n", "cap", "max_iters"))
def _ppr_push_sparse_impl(deg: jax.Array, adj: jax.Array, seeds: jax.Array,
                          alpha, eps, *, n: int, cap: int, max_iters: int):
    """Jitted sparse push: per-seed ``[S, cap]`` id-sorted support tables.

    One round: gather the active entries' padded adjacency rows, then merge
    the table with the neighbor contributions via a stable sort by vertex id
    + segment-head scatter-add (duplicate ids compact into one slot). Ids
    stay ascending per row, so the table doubles as the sorted support set.
    Overflow (> ``cap`` distinct ids after a merge) raises a flag that stops
    the loop; the truncated buffers must then be discarded by the caller.
    """
    deg = deg.astype(jnp.float32)
    s_batch = seeds.shape[0]
    width = adj.shape[1]
    rows = jnp.arange(s_batch)[:, None]

    idx0 = jnp.full((s_batch, cap), n, jnp.int32).at[:, 0].set(seeds)
    p0 = jnp.zeros((s_batch, cap), jnp.float32)
    r0 = p0.at[:, 0].set(1.0)

    def entry_deg(idx):
        """Degrees of table entries; sentinel slots read as degree 0."""
        return jnp.where(idx < n, jnp.take(deg, jnp.minimum(idx, n - 1)), 0.0)

    def body(state):
        idx, p, r, it, ovf = state
        valid = idx < n
        d = entry_deg(idx)
        active = valid & (r >= eps * jnp.maximum(d, 1.0))
        push = jnp.where(active, r, 0.0)
        # isolated vertices (deg 0) absorb their whole mass into p
        p = p + jnp.where(d > 0, alpha * push, push)
        give = jnp.where(d > 0,
                         (1.0 - alpha) * push / jnp.maximum(d, 1.0), 0.0)
        r = jnp.where(active, 0.0, r)
        # neighbor contributions of the active entries ([S, cap, W] gather;
        # adjacency pad and inactive lanes park on the id sentinel n)
        nbrs = jnp.take(adj, jnp.minimum(idx, n - 1), axis=0)
        live = active[:, :, None] & (nbrs < n)
        cand_id = jnp.where(live, nbrs, n).reshape(s_batch, cap * width)
        cand_r = jnp.where(live, give[:, :, None],
                           0.0).reshape(s_batch, cap * width)
        # sort-merge: table ∪ candidates by id, compact duplicate ids into
        # the segment head's slot via rank = cumsum(head) - 1
        all_id = jnp.concatenate([idx, cand_id], axis=1)
        all_p = jnp.concatenate([p, jnp.zeros_like(cand_r)], axis=1)
        all_r = jnp.concatenate([r, cand_r], axis=1)
        perm = jnp.argsort(all_id, axis=1, stable=True)
        sid = jnp.take_along_axis(all_id, perm, axis=1)
        sp = jnp.take_along_axis(all_p, perm, axis=1)
        sr = jnp.take_along_axis(all_r, perm, axis=1)
        svalid = sid < n
        head = svalid & jnp.concatenate(
            [jnp.ones((s_batch, 1), bool), sid[:, 1:] != sid[:, :-1]], axis=1)
        rank = jnp.cumsum(head, axis=1) - 1
        ovf = ovf | jnp.any(jnp.sum(head, axis=1) > cap)
        rank = jnp.where(svalid, rank, cap)           # sentinels drop below
        new_idx = jnp.full((s_batch, cap), n, jnp.int32).at[
            rows, rank].min(sid, mode="drop")
        new_p = jnp.zeros((s_batch, cap), jnp.float32).at[
            rows, rank].add(sp, mode="drop")
        new_r = jnp.zeros((s_batch, cap), jnp.float32).at[
            rows, rank].add(sr, mode="drop")
        return new_idx, new_p, new_r, it + 1, ovf

    def cond(state):
        idx, _, r, it, ovf = state
        d = entry_deg(idx)
        any_active = jnp.any((idx < n) & (r >= eps * jnp.maximum(d, 1.0)))
        return any_active & (it < max_iters) & ~ovf

    return jax.lax.while_loop(
        cond, body, (idx0, p0, r0, jnp.int32(0), jnp.bool_(False)))


def ppr_push_sparse(graph: Graph, seeds: jax.Array, alpha: float = 0.15,
                    eps: float = 1e-4, max_iters: int = 200,
                    frontier_cap: Optional[int] = None) -> SparseFrontier:
    """Sparse-frontier ACL push: same dynamics as :func:`ppr_push`, memory
    ``O(S · cap)`` with ``cap = O(1/(alpha·eps))`` instead of ``O(S · n)``.

    Args:
      graph:        frozen Graph or streaming view; reads ``deg``/``adj``.
      seeds:        int32[S] seed vertex ids (pow2-padded internally).
      alpha, eps:   ACL parameters (traced scalars — no retrace per value).
      max_iters:    hard cap on synchronous push rounds.
      frontier_cap: capacity override; ``None`` sizes from the ACL bound
                    (see :func:`frontier_cap_for`).

    Returns:
      A :class:`SparseFrontier`. Check ``overflowed`` before consuming: a
      True flag means the cap was exceeded mid-round and the buffers are
      truncated — callers must fall back to the dense push (spill).
    """
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    seeds_b, s = _padded_seeds(seeds)
    cap = frontier_cap_for(alpha, eps, graph.n, frontier_cap)
    with trace.span("ppr.push", mode="sparse", n=int(graph.n), cap=int(cap),
                    seeds=int(s)) as sp:
        idx, p, r, iters, ovf = _ppr_push_sparse_impl(
            graph.deg, graph.adj, seeds_b, jnp.float32(alpha),
            jnp.float32(eps), n=graph.n, cap=cap, max_iters=max_iters)
        fr = SparseFrontier(idx=idx[:s], p=p[:s], r=r[:s], iterations=iters,
                            overflowed=ovf, n=graph.n)
        size = int(fr.sizes().max()) if s else 0
        sp.set(frontier_size=size, spilled=bool(fr.overflowed))
        obs_metrics.REGISTRY.histogram("ppr.frontier_size").observe(size)
    return fr


def ppr_power_iteration(graph: Graph, seeds: jax.Array, alpha: float = 0.15,
                        iters: int = 200) -> jax.Array:
    """Dense power-iteration PPR reference: ``p ← α·e_s + (1−α)·A D⁻¹ p``.

    The fixed point this converges to is exactly what :func:`ppr_push`
    approximates (same teleport convention), so it serves as the test oracle.
    Returns float32[S, n].
    """
    n = graph.n
    deg = graph.deg.astype(jnp.float32)
    edges = graph.edges
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    s_batch = seeds.shape[0]
    e_s = jnp.zeros((s_batch, n), jnp.float32).at[
        jnp.arange(s_batch), seeds].add(1.0)

    def step(p, _):
        give = jnp.where(deg[None, :] > 0, p / jnp.maximum(deg[None, :], 1.0),
                         0.0)
        recv = jnp.zeros_like(p)
        recv = recv.at[:, edges[:, 1]].add(give[:, edges[:, 0]])
        recv = recv.at[:, edges[:, 0]].add(give[:, edges[:, 1]])
        # deg-0 vertices hold their mass (matches push's absorb-to-p)
        hold = jnp.where(deg[None, :] > 0, 0.0, p)
        return alpha * e_s + (1.0 - alpha) * (recv + hold), None

    p, _ = jax.lax.scan(step, e_s, None, length=iters)
    return p


# ----------------------------------------------------------------------------
# phase 2: sweep cut with sketch-gated cut increments
# ----------------------------------------------------------------------------

def _vertex_bloom_rows(order: jax.Array, n: int, words: int, num_hashes: int,
                       seed: int) -> jax.Array:
    """uint32[S, k, words]: single-vertex Bloom rows for the sweep order.

    Built through the one shared builder (``sketches.bloom_rows`` on
    ``[S·k, 1]`` pseudo-adjacency rows; the sweep-pad sentinel ``n`` is
    exactly the builder's pad value), so the prefix filter *provably* uses
    the same hash family and bit layout as the neighborhood sketch — the
    property the AND/OR estimators depend on.
    """
    s_batch, k = order.shape
    rows = bloom_rows(order.reshape(-1, 1), n=n, words=words,
                      num_hashes=num_hashes, seed=seed)
    return rows.reshape(s_batch, k, words)


def _prefix_intersections(deg: jax.Array, adj: jax.Array, n: int,
                          order: jax.Array, sketch: Optional[SketchSet],
                          plan: eng.EnginePlan) -> jax.Array:
    """float32[S, k]: |N(order_j) ∩ {order_0..order_{j-1}}| per sweep step.

    Sketch path (kind == "bf"): exclusive prefix-OR of single-vertex Bloom
    rows gives ``B(S_{j-1})``; one AND+popcount against the neighborhood row
    ``B(N(order_j))`` per step, through the compiled 2-way AND set
    expression in dense form (fused Pallas pass when ``plan.use_kernel``,
    jnp otherwise). Exact path: gather each swept vertex's padded
    adjacency row and count neighbors whose sweep rank is smaller.
    """
    s_batch, k = order.shape
    if sketch is not None and sketch.kind == "bf":
        words = sketch.data.shape[1]
        total_bits = words * 32
        elem = _vertex_bloom_rows(order, n, words, sketch.num_hashes,
                                  sketch.seed)
        prefix_inc = jax.lax.associative_scan(jnp.bitwise_or, elem, axis=1)
        prefix = jnp.concatenate(
            [jnp.zeros((s_batch, 1, words), jnp.uint32),
             prefix_inc[:, :-1]], axis=1)                    # exclusive
        safe = jnp.where(order < n, order, 0)
        nbr_rows = jnp.take(sketch.data, safe, axis=0)       # [S, k, words]
        # inclusion–exclusion (the paper's OR estimator): both set sizes are
        # *known exactly* here — |N(v_j)| = d(v_j) and |S_{j-1}| = j — so only
        # the union size needs estimating. Unlike the AND form this stays
        # accurate while the prefix filter fills up: it saturates with the
        # union's fill fraction, which core.bounds.sweep_cut_rmse models.
        from ...engine import setexpr
        u_row, v_row = setexpr.rows(2)
        ce = setexpr.compile_expr(u_row & v_row, block_w=plan.block_w,
                                  use_kernel=plan.use_kernel)
        ones_and = ce.ones_rows(
            nbr_rows.reshape(-1, words),
            prefix.reshape(-1, words)).reshape(s_batch, k)
        ones_nbr = jnp.sum(jax.lax.population_count(nbr_rows), axis=-1)
        ones_pre = jnp.sum(jax.lax.population_count(prefix), axis=-1)
        ones_or = ones_nbr + ones_pre - ones_and
        union_est = bf_intersection_and_from_ones(ones_or, total_bits,
                                                  sketch.num_hashes)
        d_j = jnp.take(deg, safe).astype(jnp.float32)
        psize = jnp.arange(k, dtype=jnp.float32)[None, :]    # |S_{j-1}| = j
        est = d_j + psize - union_est
        # an intersection is bounded by the smaller of the two true sets
        return jnp.clip(est, 0.0, jnp.minimum(d_j, psize))

    # exact fallback: rank-compare through the padded adjacency
    rank = jnp.full((s_batch, n + 1), k, jnp.int32)
    rank = rank.at[jnp.arange(s_batch)[:, None],
                   jnp.minimum(order, n)].set(
        jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (s_batch, k)))
    rank = rank.at[:, n].set(k)                    # adjacency pad sentinel
    nbrs = jnp.take(adj, jnp.where(order < n, order, 0),
                    axis=0)                                  # [S, k, cap]
    nbr_rank = jnp.take_along_axis(
        rank, nbrs.reshape(s_batch, -1), axis=1).reshape(nbrs.shape)
    before = nbr_rank < jnp.arange(k, dtype=jnp.int32)[None, :, None]
    valid = nbrs < n
    return jnp.sum(before & valid, axis=-1).astype(jnp.float32)


def _sweep_scan(deg: jax.Array, adj: jax.Array, order: jax.Array,
                in_sweep: jax.Array, vol_total: jax.Array,
                sketch: Optional[SketchSet], plan: eng.EnginePlan, *, n: int):
    """Conductance scan over an already-derived sweep order.

    Shared verbatim by the dense and sparse sweep entries: given the same
    ``(order, in_sweep)`` it reads only ``deg``/``adj``/``vol_total``, so the
    two paths' conductance profiles are bit-identical whenever their orders
    agree (invariant 10 — the frontier layout may perturb PPR values in the
    last ulp, but never the profile arithmetic downstream of the order).
    """
    support = jnp.sum(in_sweep, axis=1).astype(jnp.int32)
    d_j = jnp.where(in_sweep, jnp.take(deg, jnp.minimum(order, n - 1)), 0.0)
    inter = jnp.where(
        in_sweep,
        _prefix_intersections(deg, adj, n, order, sketch, plan), 0.0)
    vol = jnp.cumsum(d_j, axis=1)
    cut = jnp.cumsum(d_j - 2.0 * inter, axis=1)
    cut = jnp.maximum(cut, 0.0)                # sketch noise can dip below 0
    vol_rest = vol_total - vol
    denom = jnp.minimum(vol, vol_rest)
    ok = in_sweep & (denom > 0.0)
    conductance = jnp.where(ok, cut / jnp.maximum(denom, 1.0), jnp.inf)
    return order, conductance, support


@functools.partial(jax.jit, static_argnames=("n", "plan"))
def _sweep_cut_impl(deg: jax.Array, adj: jax.Array, ppr: jax.Array,
                    vol_total: jax.Array, sketch: Optional[SketchSet],
                    plan: eng.EnginePlan, *, n: int):
    """Jitted dense sweep over raw arrays; ``vol_total`` (= 2m) arrives as a
    traced scalar so a streaming delta's changed edge count does not retrace
    (the Graph pytree's static ``n_edges`` would). ``top_k`` breaks score
    ties by smallest vertex id — the sparse entry matches this exactly."""
    deg = deg.astype(jnp.float32)
    score = ppr / jnp.maximum(deg[None, :], 1.0)
    k = max(1, min(int(plan.sweep_cap), n))
    top_score, order = jax.lax.top_k(score, k)
    in_sweep = top_score > 0.0                               # [S, k]
    order = jnp.where(in_sweep, order, n).astype(jnp.int32)  # pad -> sentinel
    return _sweep_scan(deg, adj, order, in_sweep, vol_total, sketch, plan,
                       n=n)


@functools.partial(jax.jit, static_argnames=("n", "plan"))
def _sweep_cut_sparse_impl(deg: jax.Array, adj: jax.Array, idx: jax.Array,
                           pval: jax.Array, vol_total: jax.Array,
                           sketch: Optional[SketchSet],
                           plan: eng.EnginePlan, *, n: int):
    """Jitted sparse sweep: derive the order from the ``[S, cap]`` support
    table instead of a dense ``[S, n]`` score tensor. The table is ascending
    by vertex id, so ``top_k`` over slots breaks score ties by smallest id —
    the same tie order the dense entry produces — and the shared scan then
    yields bit-identical conductance profiles on agreeing orders."""
    deg = deg.astype(jnp.float32)
    cap = idx.shape[1]
    valid = idx < n
    d = jnp.where(valid, jnp.take(deg, jnp.minimum(idx, n - 1)), 1.0)
    # invalid slots score -1 so they sort after every real (≥ 0) score
    score = jnp.where(valid, pval / jnp.maximum(d, 1.0), -1.0)
    k = max(1, min(int(plan.sweep_cap), cap, n))
    top_score, pos = jax.lax.top_k(score, k)
    order = jnp.take_along_axis(idx, pos, axis=1)
    in_sweep = top_score > 0.0                               # [S, k]
    order = jnp.where(in_sweep, order, n).astype(jnp.int32)  # pad -> sentinel
    return _sweep_scan(deg, adj, order, in_sweep, vol_total, sketch, plan,
                       n=n)


def sweep_cut(graph: Graph, ppr, sketch: Optional[SketchSet] = None,
              plan: Optional[eng.EnginePlan] = None):
    """Batched sweep-cut conductance scan over degree-normalized PPR mass.

    Args:
      graph:  the graph the PPR vectors live on.
      ppr:    float32[S, n] PPR estimates (from :func:`ppr_push`) or a
              :class:`SparseFrontier` (from :func:`ppr_push_sparse`) — the
              sparse form sweeps the support table directly and never
              materializes an ``[S, n]`` tensor.
      sketch: optional SketchSet; a Bloom sketch routes the cut increments
              through prefix-filter AND+popcounts, anything else (or None)
              uses the exact rank-compare fallback.
      plan:   EnginePlan; ``plan.sweep_cap`` bounds the swept prefix length
              and ``plan.use_kernel`` routes Bloom popcounts through the
              Pallas pair kernel.

    Returns:
      ``(order, conductance, support)`` — int32[S, k] sweep order,
      float32[S, k] per-prefix conductance (inf at invalid prefixes), and
      int32[S] number of positive-mass vertices swept.
    """
    plan = plan if plan is not None else eng.plan_for(graph, sketch)
    if isinstance(ppr, SparseFrontier):
        return _sweep_cut_sparse_impl(graph.deg, graph.adj, ppr.idx, ppr.p,
                                      jnp.float32(2.0 * graph.m), sketch,
                                      plan, n=graph.n)
    return _sweep_cut_impl(graph.deg, graph.adj, ppr,
                           jnp.float32(2.0 * graph.m), sketch, plan,
                           n=graph.n)


def local_cluster(graph: Graph, seeds, alpha: float = 0.15, eps: float = 1e-4,
                  sketch: Optional[SketchSet] = None,
                  plan: Optional[eng.EnginePlan] = None,
                  max_iters: int = 200, **kw) -> LocalClusterResult:
    """Seed-centric local clustering: PPR push then a sweep-cut scan.

    Args:
      graph:  frozen Graph or a streaming ``DynamicGraph.view()``.
      seeds:  int32[S] (or scalar) seed vertex ids.
      alpha:  PPR teleport probability.
      eps:    push tolerance (smaller = larger support, better clusters).
      sketch: optional SketchSet for sketch-gated cut increments ("bf" kind
              engages the prefix-filter path; others fall back to exact).
      plan:   EnginePlan or legacy kwargs (``sweep_cap=``, ``use_kernel=``,
              ``frontier_mode=``, ``frontier_cap=``).
      max_iters: push round cap.

    Returns:
      A :class:`LocalClusterResult` with per-seed sweep order, conductance
      profile, and the best (minimum-conductance) prefix. The push frontier
      layout follows ``plan.frontier_mode``; a sparse-path overflow spills
      to the dense push transparently (``result.spilled`` records it, the
      ``ppr.spill`` counter counts it — slower, never wrong).
    """
    plan = eng.resolve_plan(plan, graph, sketch, kw)
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    mode = resolve_frontier_mode(plan, graph.n, alpha, eps)
    frontier = None
    spilled = False
    if mode == "sparse":
        fr = ppr_push_sparse(graph, seeds, alpha, eps, max_iters,
                             plan.frontier_cap)
        if bool(fr.overflowed):
            # spill: the cap was exceeded mid-round, the buffers are
            # truncated — recompute densely (perf event, never correctness)
            spilled = True
            obs_metrics.REGISTRY.counter("ppr.spill").inc()
        else:
            frontier = fr
    if frontier is not None:
        p = r = None
        iters = frontier.iterations
        order, conductance, support = sweep_cut(graph, frontier, sketch, plan)
    else:
        p, r, iters = ppr_push(graph, seeds, alpha, eps, max_iters)
        order, conductance, support = sweep_cut(graph, p, sketch, plan)
    best_idx = jnp.argmin(conductance, axis=1).astype(jnp.int32)
    best_phi = jnp.take_along_axis(conductance, best_idx[:, None],
                                   axis=1)[:, 0]
    # an all-inf profile (isolated seed, no valid prefix) has no cluster:
    # report size 0 rather than a bogus 1-element prefix of sentinel ids
    best_size = jnp.where(jnp.isfinite(best_phi), best_idx + 1, 0)
    return LocalClusterResult(
        order=order, conductance=conductance, best_idx=best_idx,
        best_conductance=best_phi,
        best_size=best_size, support=support, ppr=p, residual=r,
        iterations=iters, frontier=frontier, spilled=spilled)
