"""Local graph clustering: batched PPR forward push + sketch-gated sweep cuts.

The seed-centric workload (Andersen–Chung–Lang / PPR-Nibble, parallelized as
in Shun et al. 2016 and frontier-formulated as in GBBS): given seed vertices,
find low-conductance clusters around them without touching the whole graph's
combinatorics. Two phases, both expressed as the regular batched tensor work
the engine already emits:

  1. **Forward push** — approximate personalized PageRank. The frontier is a
     dense float vector per seed (``r`` residual, ``p`` estimate, both
     ``[S, n]`` for a seed batch), and one synchronous push step activates
     *every* vertex over the ACL threshold at once: mass moves to ``p``
     (teleport share ``alpha``) and propagates to neighbors through an
     edge-parallel scatter-add over ``graph.edges`` — no per-vertex host
     loop, no ragged frontier, one `lax.while_loop`.

  2. **Sweep cut** — order vertices by degree-normalized PPR mass and scan
     prefixes ``S_1 ⊂ S_2 ⊂ …``, picking the prefix with minimum conductance
     ``φ(S) = cut(S) / min(vol(S), vol(V∖S))``. The expensive term is the
     per-step ``|N(v_j) ∩ S_{j-1}|`` (cut increment = ``d(v_j) − 2·|N(v_j) ∩
     S_{j-1}|``). The sketch-gated path replaces it with ProbGraph set
     algebra: the swept prefix is itself a Bloom filter (exclusive prefix-OR
     of single-vertex bit rows under the *same* hash family as the
     neighborhood sketch), so every increment is one AND+popcount between
     ``B(N(v_j))`` and ``B(S_{j-1})`` — ``bf_edge_intersect``-style work,
     optionally routed through the Pallas pair kernel. The exact fallback
     counts swept-rank hits through the padded adjacency.

``core.bounds.sweep_cut_rmse`` / ``bloom_words_for_conductance`` make the
sketch knob quantitative: size the Bloom filter from a target conductance
error instead of guessing.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ... import engine as eng
from ..estimators import bf_intersection_and_from_ones
from ..graph import Graph
from ..sketches import SketchSet, bloom_rows


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LocalClusterResult:
    """Per-seed output of :func:`local_cluster` (a batched sweep).

    Attributes:
      order:       int32[S, k]   sweep order (vertices by descending p/deg;
                                 entries past ``support`` are padding).
      conductance: float32[S, k] conductance of each swept prefix (``inf``
                                 at invalid prefixes: empty, full-volume, or
                                 past the seed's support).
      best_idx:    int32[S]      prefix index minimizing conductance.
      best_conductance: float32[S] the minimum conductance itself (``inf``
                                 when the seed admits no valid prefix).
      best_size:   int32[S]      cluster size = best_idx + 1, or 0 when no
                                 valid prefix exists (isolated seed /
                                 whole-volume support) — ``members`` is
                                 then empty.
      support:     int32[S]      number of vertices with positive PPR mass
                                 that entered the sweep (≤ k).
      ppr:         float32[S, n] the approximate PPR vectors (push output).
      residual:    float32[S, n] the final push residuals (the truncated
                                 mass; nonzero only on neighbors of the
                                 pushed support).
      iterations:  int32         push iterations until convergence/cap.
    """

    order: jax.Array
    conductance: jax.Array
    best_idx: jax.Array
    best_conductance: jax.Array
    best_size: jax.Array
    support: jax.Array
    ppr: jax.Array
    residual: jax.Array
    iterations: jax.Array

    def members(self, s: int):
        """Vertex ids of seed ``s``'s best cluster (host-side convenience)."""
        import numpy as np
        k = int(np.asarray(self.best_size)[s])
        return np.asarray(self.order)[s, :k]

    def footprint(self, s: int):
        """Vertex ids seed ``s``'s answer depends on (sorted int64).

        Every vertex that ever held PPR mass or residual during the push:
        the push dynamics read only these vertices' degrees and incident
        edges (a vertex whose residual never crossed the ACL threshold still
        gates on ``r[v] ≥ eps·d(v)``, so its *degree* is load-bearing), and
        the sweep reads only rows/degrees of the swept support — a subset.
        This is the serving-tier cache's invalidation set; conductance
        additionally depends on the total volume ``2m``, which the cache
        guards separately (see ``stream.cache``).
        """
        import numpy as np
        p = np.asarray(self.ppr[s])
        r = np.asarray(self.residual[s])
        return np.nonzero((p > 0) | (r > 0))[0].astype(np.int64)


# ----------------------------------------------------------------------------
# phase 1: batched approximate PPR (ACL forward push, synchronous frontier)
# ----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "max_iters"))
def _ppr_push_impl(deg: jax.Array, edges: jax.Array, seeds: jax.Array,
                   alpha, eps, *, n: int, max_iters: int):
    """Jitted push body over raw arrays (not the Graph pytree, whose static
    ``n_edges`` would retrace per streaming delta); ``edges`` is pow2-padded
    with sentinel (n, n) rows whose scatter contributions drop."""
    deg = deg.astype(jnp.float32)
    s_batch = seeds.shape[0]
    thresh = eps * jnp.maximum(deg, 1.0)

    p0 = jnp.zeros((s_batch, n), jnp.float32)
    r0 = p0.at[jnp.arange(s_batch), seeds].add(1.0)

    def body(state):
        p, r, it = state
        active = r >= thresh[None, :]
        push = jnp.where(active, r, 0.0)
        # isolated vertices (deg 0) absorb their whole mass into p
        p = p + jnp.where(deg[None, :] > 0, alpha * push, push)
        give = jnp.where(deg[None, :] > 0,
                         (1.0 - alpha) * push / jnp.maximum(deg[None, :], 1.0),
                         0.0)
        # edge-parallel propagate: each canonical edge carries mass both
        # ways; sentinel pad rows scatter out of bounds and are dropped
        recv = jnp.zeros_like(r)
        recv = recv.at[:, edges[:, 1]].add(
            give[:, jnp.minimum(edges[:, 0], n - 1)], mode="drop")
        recv = recv.at[:, edges[:, 0]].add(
            give[:, jnp.minimum(edges[:, 1], n - 1)], mode="drop")
        return p, jnp.where(active, 0.0, r) + recv, it + 1

    def cond(state):
        _, r, it = state
        return jnp.any(r >= thresh[None, :]) & (it < max_iters)

    p, r, iters = jax.lax.while_loop(cond, body, (p0, r0, jnp.int32(0)))
    return p, r, iters


def _padded_edges(graph: Graph) -> jax.Array:
    """graph.edges padded to a pow2 bucket with sentinel (n, n) rows, so the
    jitted push compiles once per size class instead of once per delta."""
    m = graph.edges.shape[0]
    m_b = eng.plan.pow2_bucket(m)
    if m_b == m:
        return graph.edges
    pad = jnp.full((m_b - m, 2), graph.n, graph.edges.dtype)
    return jnp.concatenate([graph.edges, pad], axis=0)


def ppr_push(graph: Graph, seeds: jax.Array, alpha: float = 0.15,
             eps: float = 1e-4, max_iters: int = 200):
    """Batched ACL forward push: approximate PPR for a batch of seeds.

    Args:
      graph:     the (frozen or view) graph; only ``deg`` and ``edges`` are
                 read, so the result is independent of adjacency padding.
      seeds:     int32[S] seed vertex ids (duplicates allowed — pad a batch
                 by repeating any seed and drop the copies).
      alpha:     teleport probability of the underlying random walk.
      eps:       push tolerance — iterate until every residual satisfies
                 ``r[v] < eps·max(d(v), 1)``.
      max_iters: hard cap on synchronous push rounds.

    Returns:
      ``(p, r, iters)``: PPR estimates float32[S, n], final residuals
      float32[S, n], and the int32 number of rounds executed. The ACL
      invariant bounds the truncation: ``p ≤ ppr_exact ≤ p + eps·deg``
      coordinatewise (in exact arithmetic). The implementation is jitted
      with ``alpha``/``eps`` as traced scalars and the edge list padded to a
      pow2 bucket, so repeated serving calls — including across streaming
      deltas, where ``m`` changes every batch — reuse one compiled program
      per (n, edge-bucket, seed-batch) class.
    """
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    return _ppr_push_impl(graph.deg, _padded_edges(graph), seeds,
                          jnp.float32(alpha), jnp.float32(eps),
                          n=graph.n, max_iters=max_iters)


def ppr_power_iteration(graph: Graph, seeds: jax.Array, alpha: float = 0.15,
                        iters: int = 200) -> jax.Array:
    """Dense power-iteration PPR reference: ``p ← α·e_s + (1−α)·A D⁻¹ p``.

    The fixed point this converges to is exactly what :func:`ppr_push`
    approximates (same teleport convention), so it serves as the test oracle.
    Returns float32[S, n].
    """
    n = graph.n
    deg = graph.deg.astype(jnp.float32)
    edges = graph.edges
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    s_batch = seeds.shape[0]
    e_s = jnp.zeros((s_batch, n), jnp.float32).at[
        jnp.arange(s_batch), seeds].add(1.0)

    def step(p, _):
        give = jnp.where(deg[None, :] > 0, p / jnp.maximum(deg[None, :], 1.0),
                         0.0)
        recv = jnp.zeros_like(p)
        recv = recv.at[:, edges[:, 1]].add(give[:, edges[:, 0]])
        recv = recv.at[:, edges[:, 0]].add(give[:, edges[:, 1]])
        # deg-0 vertices hold their mass (matches push's absorb-to-p)
        hold = jnp.where(deg[None, :] > 0, 0.0, p)
        return alpha * e_s + (1.0 - alpha) * (recv + hold), None

    p, _ = jax.lax.scan(step, e_s, None, length=iters)
    return p


# ----------------------------------------------------------------------------
# phase 2: sweep cut with sketch-gated cut increments
# ----------------------------------------------------------------------------

def _vertex_bloom_rows(order: jax.Array, n: int, words: int, num_hashes: int,
                       seed: int) -> jax.Array:
    """uint32[S, k, words]: single-vertex Bloom rows for the sweep order.

    Built through the one shared builder (``sketches.bloom_rows`` on
    ``[S·k, 1]`` pseudo-adjacency rows; the sweep-pad sentinel ``n`` is
    exactly the builder's pad value), so the prefix filter *provably* uses
    the same hash family and bit layout as the neighborhood sketch — the
    property the AND/OR estimators depend on.
    """
    s_batch, k = order.shape
    rows = bloom_rows(order.reshape(-1, 1), n=n, words=words,
                      num_hashes=num_hashes, seed=seed)
    return rows.reshape(s_batch, k, words)


def _prefix_intersections(deg: jax.Array, adj: jax.Array, n: int,
                          order: jax.Array, sketch: Optional[SketchSet],
                          plan: eng.EnginePlan) -> jax.Array:
    """float32[S, k]: |N(order_j) ∩ {order_0..order_{j-1}}| per sweep step.

    Sketch path (kind == "bf"): exclusive prefix-OR of single-vertex Bloom
    rows gives ``B(S_{j-1})``; one AND+popcount against the neighborhood row
    ``B(N(order_j))`` per step, through the compiled 2-way AND set
    expression in dense form (fused Pallas pass when ``plan.use_kernel``,
    jnp otherwise). Exact path: gather each swept vertex's padded
    adjacency row and count neighbors whose sweep rank is smaller.
    """
    s_batch, k = order.shape
    if sketch is not None and sketch.kind == "bf":
        words = sketch.data.shape[1]
        total_bits = words * 32
        elem = _vertex_bloom_rows(order, n, words, sketch.num_hashes,
                                  sketch.seed)
        prefix_inc = jax.lax.associative_scan(jnp.bitwise_or, elem, axis=1)
        prefix = jnp.concatenate(
            [jnp.zeros((s_batch, 1, words), jnp.uint32),
             prefix_inc[:, :-1]], axis=1)                    # exclusive
        safe = jnp.where(order < n, order, 0)
        nbr_rows = jnp.take(sketch.data, safe, axis=0)       # [S, k, words]
        # inclusion–exclusion (the paper's OR estimator): both set sizes are
        # *known exactly* here — |N(v_j)| = d(v_j) and |S_{j-1}| = j — so only
        # the union size needs estimating. Unlike the AND form this stays
        # accurate while the prefix filter fills up: it saturates with the
        # union's fill fraction, which core.bounds.sweep_cut_rmse models.
        from ...engine import setexpr
        u_row, v_row = setexpr.rows(2)
        ce = setexpr.compile_expr(u_row & v_row, block_w=plan.block_w,
                                  use_kernel=plan.use_kernel)
        ones_and = ce.ones_rows(
            nbr_rows.reshape(-1, words),
            prefix.reshape(-1, words)).reshape(s_batch, k)
        ones_nbr = jnp.sum(jax.lax.population_count(nbr_rows), axis=-1)
        ones_pre = jnp.sum(jax.lax.population_count(prefix), axis=-1)
        ones_or = ones_nbr + ones_pre - ones_and
        union_est = bf_intersection_and_from_ones(ones_or, total_bits,
                                                  sketch.num_hashes)
        d_j = jnp.take(deg, safe).astype(jnp.float32)
        psize = jnp.arange(k, dtype=jnp.float32)[None, :]    # |S_{j-1}| = j
        est = d_j + psize - union_est
        # an intersection is bounded by the smaller of the two true sets
        return jnp.clip(est, 0.0, jnp.minimum(d_j, psize))

    # exact fallback: rank-compare through the padded adjacency
    rank = jnp.full((s_batch, n + 1), k, jnp.int32)
    rank = rank.at[jnp.arange(s_batch)[:, None],
                   jnp.minimum(order, n)].set(
        jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (s_batch, k)))
    rank = rank.at[:, n].set(k)                    # adjacency pad sentinel
    nbrs = jnp.take(adj, jnp.where(order < n, order, 0),
                    axis=0)                                  # [S, k, cap]
    nbr_rank = jnp.take_along_axis(
        rank, nbrs.reshape(s_batch, -1), axis=1).reshape(nbrs.shape)
    before = nbr_rank < jnp.arange(k, dtype=jnp.int32)[None, :, None]
    valid = nbrs < n
    return jnp.sum(before & valid, axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n", "plan"))
def _sweep_cut_impl(deg: jax.Array, adj: jax.Array, ppr: jax.Array,
                    vol_total: jax.Array, sketch: Optional[SketchSet],
                    plan: eng.EnginePlan, *, n: int):
    """Jitted sweep body over raw arrays; ``vol_total`` (= 2m) arrives as a
    traced scalar so a streaming delta's changed edge count does not retrace
    (the Graph pytree's static ``n_edges`` would)."""
    deg = deg.astype(jnp.float32)
    score = ppr / jnp.maximum(deg[None, :], 1.0)
    k = max(1, min(int(plan.sweep_cap), n))
    top_score, order = jax.lax.top_k(score, k)
    in_sweep = top_score > 0.0                               # [S, k]
    support = jnp.sum(in_sweep, axis=1).astype(jnp.int32)
    order = jnp.where(in_sweep, order, n).astype(jnp.int32)  # pad -> sentinel

    d_j = jnp.where(in_sweep, jnp.take(deg, jnp.minimum(order, n - 1)), 0.0)
    inter = jnp.where(
        in_sweep,
        _prefix_intersections(deg, adj, n, order, sketch, plan), 0.0)
    vol = jnp.cumsum(d_j, axis=1)
    cut = jnp.cumsum(d_j - 2.0 * inter, axis=1)
    cut = jnp.maximum(cut, 0.0)                # sketch noise can dip below 0
    vol_rest = vol_total - vol
    denom = jnp.minimum(vol, vol_rest)
    ok = in_sweep & (denom > 0.0)
    conductance = jnp.where(ok, cut / jnp.maximum(denom, 1.0), jnp.inf)
    return order, conductance, support


def sweep_cut(graph: Graph, ppr: jax.Array, sketch: Optional[SketchSet] = None,
              plan: Optional[eng.EnginePlan] = None):
    """Batched sweep-cut conductance scan over degree-normalized PPR mass.

    Args:
      graph:  the graph the PPR vectors live on.
      ppr:    float32[S, n] PPR estimates (from :func:`ppr_push`).
      sketch: optional SketchSet; a Bloom sketch routes the cut increments
              through prefix-filter AND+popcounts, anything else (or None)
              uses the exact rank-compare fallback.
      plan:   EnginePlan; ``plan.sweep_cap`` bounds the swept prefix length
              and ``plan.use_kernel`` routes Bloom popcounts through the
              Pallas pair kernel.

    Returns:
      ``(order, conductance, support)`` — int32[S, k] sweep order,
      float32[S, k] per-prefix conductance (inf at invalid prefixes), and
      int32[S] number of positive-mass vertices swept.
    """
    plan = plan if plan is not None else eng.plan_for(graph, sketch)
    return _sweep_cut_impl(graph.deg, graph.adj, ppr,
                           jnp.float32(2.0 * graph.m), sketch, plan,
                           n=graph.n)


def local_cluster(graph: Graph, seeds, alpha: float = 0.15, eps: float = 1e-4,
                  sketch: Optional[SketchSet] = None,
                  plan: Optional[eng.EnginePlan] = None,
                  max_iters: int = 200, **kw) -> LocalClusterResult:
    """Seed-centric local clustering: PPR push then a sweep-cut scan.

    Args:
      graph:  frozen Graph or a streaming ``DynamicGraph.view()``.
      seeds:  int32[S] (or scalar) seed vertex ids.
      alpha:  PPR teleport probability.
      eps:    push tolerance (smaller = larger support, better clusters).
      sketch: optional SketchSet for sketch-gated cut increments ("bf" kind
              engages the prefix-filter path; others fall back to exact).
      plan:   EnginePlan or legacy kwargs (``sweep_cap=``, ``use_kernel=``).
      max_iters: push round cap.

    Returns:
      A :class:`LocalClusterResult` with per-seed sweep order, conductance
      profile, and the best (minimum-conductance) prefix.
    """
    plan = eng.resolve_plan(plan, graph, sketch, kw)
    seeds = jnp.asarray(seeds, jnp.int32).reshape(-1)
    p, r, iters = ppr_push(graph, seeds, alpha, eps, max_iters)
    order, conductance, support = sweep_cut(graph, p, sketch, plan)
    best_idx = jnp.argmin(conductance, axis=1).astype(jnp.int32)
    best_phi = jnp.take_along_axis(conductance, best_idx[:, None],
                                   axis=1)[:, 0]
    # an all-inf profile (isolated seed, no valid prefix) has no cluster:
    # report size 0 rather than a bogus 1-element prefix of sentinel ids
    best_size = jnp.where(jnp.isfinite(best_phi), best_idx + 1, 0)
    return LocalClusterResult(
        order=order, conductance=conductance, best_idx=best_idx,
        best_conductance=best_phi,
        best_size=best_size, support=support, ppr=p, residual=r,
        iterations=iters)
