from .tc import triangle_count
from .cliques import five_clique_count, four_clique_count
from .clustering import jarvis_patrick
from .localcluster import LocalClusterResult, local_cluster, ppr_push, sweep_cut
from .similarity import pair_similarity
from .linkpred import link_prediction_effectiveness

__all__ = [
    "triangle_count",
    "five_clique_count",
    "four_clique_count",
    "jarvis_patrick",
    "LocalClusterResult",
    "local_cluster",
    "ppr_push",
    "sweep_cut",
    "pair_similarity",
    "link_prediction_effectiveness",
]
