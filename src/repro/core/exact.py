"""Tuned exact set-intersection baselines (paper Fig. 1, panel 2).

The paper's exact baselines are "merge" (two-pointer over sorted lists) and
"galloping" (binary search of the smaller list into the larger). Two-pointer
merges are inherently sequential; on a vector machine the right exact kernel
is *batched galloping*: `vmap(searchsorted)` over padded neighbor rows —
O(d_u · log d_v) work per pair, fully lane-parallel, which is also the
work-depth-optimal entry in paper Table IV.

These serve double duty: (1) tuned exact baseline for speedup numbers,
(2) accuracy oracle for every estimator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .graph import Graph


def _row_intersect_gallop(row_a: jax.Array, row_b: jax.Array, sentinel: int) -> jax.Array:
    """|set(a) ∩ set(b)| for sorted sentinel-padded rows via binary search."""
    pos = jnp.searchsorted(row_b, row_a)
    pos = jnp.clip(pos, 0, row_b.shape[0] - 1)
    hit = (row_b[pos] == row_a) & (row_a < sentinel)
    return jnp.sum(hit).astype(jnp.int32)


def exact_pair_cardinalities(graph: Graph, pairs: jax.Array) -> jax.Array:
    """|N_u ∩ N_v| for a batch of vertex pairs [P, 2] (exact, galloping)."""
    rows_u = jnp.take(graph.adj, pairs[:, 0], axis=0)
    rows_v = jnp.take(graph.adj, pairs[:, 1], axis=0)
    return jax.vmap(_row_intersect_gallop, in_axes=(0, 0, None))(rows_u, rows_v, graph.n)


def exact_pair_intersection_elements(graph: Graph, pairs: jax.Array) -> jax.Array:
    """The intersection *elements* (padded with n) for each pair — needed by
    Adamic-Adar / Resource-Allocation and by 4-clique enumeration."""
    rows_u = jnp.take(graph.adj, pairs[:, 0], axis=0)
    rows_v = jnp.take(graph.adj, pairs[:, 1], axis=0)

    def one(a, b):
        pos = jnp.clip(jnp.searchsorted(b, a), 0, b.shape[0] - 1)
        hit = (b[pos] == a) & (a < graph.n)
        return jnp.where(hit, a, graph.n)

    return jax.vmap(one)(rows_u, rows_v)


def exact_triangle_count(graph: Graph, edge_chunk: int = 65536) -> jax.Array:
    """TC = (1/3)·Σ_{(u,v)∈E} |N_u ∩ N_v| over canonical edges (u<v).

    Over canonical (u<v) edges each triangle {a<b<c} is counted once per edge
    = 3 times, hence /3 (Listing 1 formulation).
    """
    edges = graph.edges

    def chunk_fn(pairs):
        return jnp.sum(exact_pair_cardinalities(graph, pairs).astype(jnp.int32))

    total = _fold_edges(graph, edges, chunk_fn, edge_chunk)
    return total // 3


def _fold_edges(graph: Graph, edges: jax.Array, chunk_fn, edge_chunk: int):
    m = edges.shape[0]
    if m == 0:
        return jnp.int32(0)
    if m <= edge_chunk:
        return chunk_fn(edges)
    pad = (-m) % edge_chunk
    # pad with a self-pair of vertex 0's padded row? use (0,0): N_0∩N_0 = d_0
    # instead pad with an out-of-range pair that intersects to 0: (n-1, n-1) is
    # wrong too; use dedicated masking:
    edges_p = jnp.concatenate(
        [edges, jnp.zeros((pad, 2), edges.dtype)], axis=0)
    mask = jnp.concatenate([jnp.ones(m, bool), jnp.zeros(pad, bool)])

    def body(c, xs):
        pairs, msk = xs
        vals = exact_pair_cardinalities(graph, pairs).astype(jnp.int32)
        return c + jnp.sum(jnp.where(msk, vals, 0)), None

    total, _ = jax.lax.scan(
        body, jnp.int32(0),
        (edges_p.reshape(-1, edge_chunk, 2), mask.reshape(-1, edge_chunk)))
    return total
