"""ProbGraph estimators of |X|, |X∩Y| and Jaccard (paper §IV, §IX, App. E/G).

Every function is a pure, batched jnp op over *rows* of sketch matrices, so it
vmaps/shards trivially: inputs are `[..., words]` (BF), `[..., k]` (MH/KMV).
Heavy BF paths can be routed through the Pallas kernels (see repro.kernels.ops);
these jnp forms are the reference semantics used by tests.

Notation maps to the paper:  B = total bits, b = #hash functions,
ones = B_{X∩Y,1}, k = sketch size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sketches import PAD_HASH, KMV_PAD


def _popcount_words(w: jax.Array) -> jax.Array:
    return jnp.sum(jax.lax.population_count(w), axis=-1).astype(jnp.int32)


# ----------------------------------------------------------------------------
# Bloom filters
# ----------------------------------------------------------------------------

def bf_size_swamidass(row: jax.Array, num_hashes: int) -> jax.Array:
    """|X|_S (Eq. 1), with the divergence fix of App. C-3 (ones==B -> ones-1)."""
    total_bits = row.shape[-1] * 32
    ones = _popcount_words(row).astype(jnp.float32)
    ones = jnp.where(ones >= total_bits, total_bits - 1, ones)
    return -(total_bits / num_hashes) * jnp.log1p(-ones / total_bits)


def bf_intersection_and(row_x: jax.Array, row_y: jax.Array, num_hashes: int) -> jax.Array:
    """|X∩Y|_AND (Eq. 2): Swamidass estimator on Bx AND By."""
    return bf_size_swamidass(row_x & row_y, num_hashes)


def bf_intersection_and_from_ones(ones: jax.Array, total_bits: int, num_hashes: int) -> jax.Array:
    """Eq. 2 given a precomputed popcount (e.g. from the Pallas kernel)."""
    ones = jnp.minimum(ones.astype(jnp.float32), total_bits - 1)
    return -(total_bits / num_hashes) * jnp.log1p(-ones / total_bits)


def bf_intersection_limit(row_x: jax.Array, row_y: jax.Array, num_hashes: int) -> jax.Array:
    """|X∩Y|_L (Eq. 4): ones(AND)/b — the B→∞ limit of the AND estimator."""
    return _popcount_words(row_x & row_y).astype(jnp.float32) / num_hashes


def bf_intersection_or(row_x: jax.Array, row_y: jax.Array, num_hashes: int,
                       size_x: jax.Array, size_y: jax.Array) -> jax.Array:
    """|X∩Y|_OR (Eq. 29, Swamidass prior work): |X|+|Y| - |X∪Y|_S via OR."""
    union_est = bf_size_swamidass(row_x | row_y, num_hashes)
    return size_x.astype(jnp.float32) + size_y.astype(jnp.float32) - union_est


def bf_false_positive_rate(row: jax.Array, num_hashes: int) -> jax.Array:
    """p_f = (ones/B)^b — per-sketch false-positive probability."""
    total_bits = row.shape[-1] * 32
    frac = _popcount_words(row).astype(jnp.float32) / total_bits
    return frac ** num_hashes


# ----------------------------------------------------------------------------
# k-Hash MinHash (Eq. 5)
# ----------------------------------------------------------------------------

def khash_jaccard(mx: jax.Array, my: jax.Array, n: int) -> jax.Array:
    """Ĵ_kH = |M_X ∩ M_Y| / k with multiset (per-hash-function) alignment."""
    k = mx.shape[-1]
    both_valid = (mx < n) & (my < n)
    matches = jnp.sum((mx == my) & both_valid, axis=-1)
    return matches.astype(jnp.float32) / k


def minhash_intersection(j_hat: jax.Array, size_x: jax.Array, size_y: jax.Array) -> jax.Array:
    """|X∩Y| = Ĵ/(1+Ĵ) · (|X|+|Y|)  (Eq. 5 and the 1-Hash analogue)."""
    s = size_x.astype(jnp.float32) + size_y.astype(jnp.float32)
    return j_hat / (1.0 + j_hat) * s


def khash_intersection(mx: jax.Array, my: jax.Array, size_x, size_y, n: int) -> jax.Array:
    return minhash_intersection(khash_jaccard(mx, my, n), size_x, size_y)


# ----------------------------------------------------------------------------
# 1-Hash MinHash (paper §IV-D)
# ----------------------------------------------------------------------------

def _sorted_intersect_count(a: jax.Array, b: jax.Array, sentinel: int) -> jax.Array:
    """|set(a) ∩ set(b)| for sentinel-padded, duplicate-free rows.

    O(k²) dense compare — the TPU-friendly form of a sorted merge (DESIGN §2).
    """
    eq = a[..., :, None] == b[..., None, :]
    valid = (a[..., :, None] < sentinel) & (b[..., None, :] < sentinel)
    return jnp.sum(eq & valid, axis=(-2, -1)).astype(jnp.int32)


def onehash_jaccard_naive(mx: jax.Array, my: jax.Array, n: int) -> jax.Array:
    """Paper's literal Ĵ_1H = |M¹_X ∩ M¹_Y| / k."""
    k = mx.shape[-1]
    return _sorted_intersect_count(mx, my, n).astype(jnp.float32) / k


def onehash_jaccard_union(mx: jax.Array, my: jax.Array, hx: jax.Array, hy: jax.Array,
                          n: int) -> jax.Array:
    """Union-k-min Ĵ_1H: among the k smallest hashes of X∪Y (merged from the two
    sketches), the fraction present in both sketches.

    This matches the Hyper(|X∪Y|, |X∩Y|, k) sampling model assumed by
    Prop IV.3 (sampling w/o replacement from the union), and is the default.
    mx/my are 1-Hash sketches sorted by hash; hx/hy their uint32 hash values.
    """
    k = mx.shape[-1]
    # merge the two sorted-k lists, dedupe by element id, take k smallest
    elems = jnp.concatenate([mx, my], axis=-1)
    hsh = jnp.concatenate([hx, hy], axis=-1)
    # mark duplicates (same element in both sketches): keep one copy
    dup = _pairwise_dup_mask(mx, my, n)
    hsh = jnp.where(jnp.concatenate([jnp.zeros_like(mx, bool), dup], axis=-1), PAD_HASH, hsh)
    order = jnp.argsort(hsh, axis=-1)
    top_h = jnp.take_along_axis(hsh, order, axis=-1)[..., :k]
    top_e = jnp.take_along_axis(elems, order, axis=-1)[..., :k]
    top_e = jnp.where(top_h == PAD_HASH, n, top_e)
    in_x = _membership(top_e, mx, n)
    in_y = _membership(top_e, my, n)
    denom = jnp.maximum(jnp.sum(top_e < n, axis=-1), 1)
    return jnp.sum(in_x & in_y, axis=-1).astype(jnp.float32) / denom.astype(jnp.float32)


def _pairwise_dup_mask(mx: jax.Array, my: jax.Array, n: int) -> jax.Array:
    """For each element of my, is it also present in mx?"""
    eq = my[..., :, None] == mx[..., None, :]
    valid = (my[..., :, None] < n) & (mx[..., None, :] < n)
    return jnp.any(eq & valid, axis=-1)


def _membership(queries: jax.Array, table: jax.Array, n: int) -> jax.Array:
    eq = queries[..., :, None] == table[..., None, :]
    valid = (queries[..., :, None] < n) & (table[..., None, :] < n)
    return jnp.any(eq & valid, axis=-1)


def onehash_intersection(mx, my, hx, hy, size_x, size_y, n: int,
                         variant: str = "union") -> jax.Array:
    if variant == "naive":
        j = onehash_jaccard_naive(mx, my, n)
    else:
        j = onehash_jaccard_union(mx, my, hx, hy, n)
    return minhash_intersection(j, size_x, size_y)


# ----------------------------------------------------------------------------
# KMV (paper §IX, App. G)
# ----------------------------------------------------------------------------

def kmv_size(kmv_row: jax.Array) -> jax.Array:
    """|X|_K = (k-1)/max(K_X) (Eq. 39); handles partially-filled sketches."""
    filled = jnp.sum(kmv_row < KMV_PAD, axis=-1)
    kmax = jnp.max(jnp.where(kmv_row < KMV_PAD, kmv_row, 0.0), axis=-1)
    est = (filled.astype(jnp.float32) - 1.0) / jnp.maximum(kmax, 1e-20)
    # if the sketch isn't full, it IS the whole set: |X| = filled
    full = filled >= kmv_row.shape[-1]
    return jnp.where(full, est, filled.astype(jnp.float32))


def kmv_union_size(kx: jax.Array, ky: jax.Array) -> jax.Array:
    """|X∪Y|_K from the k smallest of K_X ∪ K_Y (dedup by hash value)."""
    k = kx.shape[-1]
    merged = jnp.concatenate([kx, ky], axis=-1)
    merged = jnp.sort(merged, axis=-1)
    # dedupe equal adjacent values (same element hashed in both sets)
    dup = jnp.concatenate(
        [jnp.zeros_like(merged[..., :1], bool), merged[..., 1:] == merged[..., :-1]],
        axis=-1) & (merged < KMV_PAD)
    merged = jnp.where(dup, KMV_PAD, merged)
    merged = jnp.sort(merged, axis=-1)[..., :k]
    return kmv_size(merged)


def kmv_intersection(kx: jax.Array, ky: jax.Array, size_x, size_y) -> jax.Array:
    """|X∩Y|_K = |X| + |Y| - |X∪Y|_K (Eq. 41, exact degrees known)."""
    union = kmv_union_size(kx, ky)
    est = size_x.astype(jnp.float32) + size_y.astype(jnp.float32) - union
    return jnp.maximum(est, 0.0)


# ----------------------------------------------------------------------------
# Uniform pair-estimator dispatch (used by algorithms & benchmarks)
# ----------------------------------------------------------------------------

def pair_estimator(kind: str):
    """Returns fn(sketch_rows_u, sketch_rows_v, deg_u, deg_v, ctx) -> float32[...]."""
    def bf_and(ru, rv, du, dv, ctx):
        return bf_intersection_and(ru, rv, ctx["num_hashes"])

    def bf_l(ru, rv, du, dv, ctx):
        return bf_intersection_limit(ru, rv, ctx["num_hashes"])

    def bf_or(ru, rv, du, dv, ctx):
        return bf_intersection_or(ru, rv, ctx["num_hashes"], du, dv)

    def kh(ru, rv, du, dv, ctx):
        return khash_intersection(ru, rv, du, dv, ctx["n"])

    def oneh(ru, rv, du, dv, ctx):
        hx = ctx["hash_of"](ru)
        hy = ctx["hash_of"](rv)
        return onehash_intersection(ru, rv, hx, hy, du, dv, ctx["n"], ctx.get("variant", "union"))

    def kmv(ru, rv, du, dv, ctx):
        return kmv_intersection(ru, rv, du, dv)

    table = {"bf": bf_and, "bf_and": bf_and, "bf_l": bf_l, "bf_or": bf_or,
             "kh": kh, "1h": oneh, "kmv": kmv}
    return table[kind]
