"""Degree-adaptive Bloom filters (beyond-paper accuracy optimization).

The paper's fixed-size BFs saturate on hub vertices of skewed graphs: with
B bits and b·d_v ≫ B the filter fills up and |X∩Y|_AND explodes (our Fig.-3
benchmark shows median errors ≥0.5 on kron/ba graphs, and the paper itself
reports BF-AND degrading on dense inputs).

Fix: give each vertex a filter of 2^κ(v) bits ∝ its degree, under the SAME
global storage budget. The key identity making cross-size intersections
exact is *folding*: if bit positions are `h mod 2^a`, then OR-folding the
vector in half k times yields exactly the filter that `h mod 2^(a−k)` would
have built:

    (h mod 2^a) mod 2^(a−k) == h mod 2^(a−k)

so |X∩Y| between different-size filters = AND+popcount after folding the
larger one down — no re-hashing, pure reshape+OR (VPU-friendly). Load
factor b·d_v/B_v becomes ~uniform across vertices: the hub-saturation mode
disappears while total storage is unchanged.

Trade-off vs the paper: per-pair work varies with min(B_u, B_v) — the
perfect static load balance of fixed-size sketches relaxes to bucketed
balance (sort pairs by size class on TPU). Accuracy gain measured in
benchmarks/adaptive_bloom.py.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import Graph
from .hashing import np_hash_u32
from . import estimators as est


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AdaptiveBloom:
    data: jax.Array          # uint32[n, words_max] (row v uses words[v] words)
    words: jax.Array         # int32[n] power-of-two word counts
    num_hashes: int = dataclasses.field(metadata=dict(static=True))
    seed: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    words_max: int = dataclasses.field(metadata=dict(static=True))


def _pow2_words(deg: np.ndarray, bits_per_elem: float, min_words: int,
                max_words: int) -> np.ndarray:
    want_bits = np.maximum(deg, 1) * bits_per_elem
    words = np.maximum(np.ceil(want_bits / 32.0), min_words)
    pow2 = 2 ** np.ceil(np.log2(words)).astype(np.int64)
    return np.clip(pow2, min_words, max_words).astype(np.int64)


def size_for_budget(graph: Graph, storage_budget: float, min_words: int = 2,
                    max_words: int = 4096) -> np.ndarray:
    """Per-vertex pow2 word counts with Σ words·32 ≈ budget × CSR bits."""
    deg = np.asarray(graph.deg)
    target_words = storage_budget * (2 * graph.m + graph.n + 1)
    lo, hi = 1e-3, 1e4
    for _ in range(48):  # bisection on bits-per-element
        mid = (lo + hi) / 2
        total = _pow2_words(deg, mid, min_words, max_words).sum()
        if total > target_words:
            hi = mid
        else:
            lo = mid
    return _pow2_words(deg, lo, min_words, max_words)


def build_adaptive_bloom(graph: Graph, storage_budget: float = 0.25,
                         num_hashes: int = 1, seed: int = 0,
                         min_words: int = 2, max_words: int = 4096
                         ) -> AdaptiveBloom:
    """Host-side construction (np.bitwise_or.at), per-vertex moduli."""
    n = graph.n
    words = size_for_budget(graph, storage_budget, min_words, max_words)
    words_max = int(words.max())
    indptr = np.asarray(graph.indptr)
    indices = np.asarray(graph.indices)
    deg = np.diff(indptr)
    rows = np.repeat(np.arange(n), deg)
    row_bits = (words * 32)[rows]
    out = np.zeros((n, words_max), dtype=np.uint32)
    golden = 0x9E3779B9
    for i in range(num_hashes):
        s = np.uint32((i + seed * golden) & 0xFFFFFFFF)
        pos = np_hash_u32(indices, int(s)) % row_bits  # per-row modulus
        np.bitwise_or.at(out, (rows, pos >> 5), np.uint32(1) << (pos & 31))
    return AdaptiveBloom(data=jnp.asarray(out),
                         words=jnp.asarray(words.astype(np.int32)),
                         num_hashes=num_hashes, seed=seed, n=n,
                         words_max=words_max)


def _fold_to(row: jax.Array, cur_words: jax.Array, target_words: jax.Array,
             words_max: int) -> jax.Array:
    """OR-fold a pow2-sized filter down to target_words (both traced)."""
    steps = int(np.log2(words_max)) + 1
    idx = jnp.arange(words_max)

    def step(_, carry):
        row, cur = carry
        half = cur // 2
        partner = jnp.take(row, jnp.minimum(idx + half, words_max - 1))
        folded = jnp.where(idx < half, row | partner,
                           jnp.where(idx < cur, jnp.uint32(0), row))
        apply = cur > target_words
        return (jnp.where(apply, folded, row),
                jnp.where(apply, half, cur))

    row, _ = jax.lax.fori_loop(0, steps, step, (row, cur_words))
    return row


def adaptive_pair_cardinalities(sk: AdaptiveBloom, pairs: jax.Array) -> jax.Array:
    """|N_u ∩ N_v|_AND across (possibly different-size) adaptive filters."""
    ru = jnp.take(sk.data, pairs[:, 0], axis=0)
    rv = jnp.take(sk.data, pairs[:, 1], axis=0)
    wu = jnp.take(sk.words, pairs[:, 0])
    wv = jnp.take(sk.words, pairs[:, 1])
    wt = jnp.minimum(wu, wv)

    def one(ru, rv, wu, wv, wt):
        fu = _fold_to(ru, wu, wt, sk.words_max)
        fv = _fold_to(rv, wv, wt, sk.words_max)
        valid = jnp.arange(sk.words_max) < wt
        ones = jnp.sum(jnp.where(valid, jax.lax.population_count(fu & fv), 0))
        total_bits = (wt * 32).astype(jnp.float32)
        ones = jnp.minimum(ones.astype(jnp.float32), total_bits - 1.0)
        return -(total_bits / sk.num_hashes) * jnp.log1p(-ones / total_bits)

    return jax.vmap(one)(ru, rv, wu, wv, wt)


def adaptive_triangle_count(graph: Graph, sk: AdaptiveBloom) -> jax.Array:
    vals = adaptive_pair_cardinalities(sk, graph.edges)
    return jnp.sum(vals) / 3.0
