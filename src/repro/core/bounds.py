"""Concentration / MSE bounds from the paper (host-side, numpy floats).

These make the accuracy knob *quantitative*: given sketch parameters, they
bound P(|estimate - truth| >= t). Used by tests (empirical deviations must sit
inside the bounds) and by the auto-tuner that picks sketch sizes for a target
accuracy (data-pipeline dedup uses Prop IV.2 to size k).
"""
from __future__ import annotations

import numpy as np


def _bf_and_mse(inter_size, total_bits: int, num_hashes: int) -> np.ndarray:
    """Prop IV.1 MSE expression, vectorized over the intersection size —
    the single home of the formula (scalar bound and streaming RMSE scale
    both derive from it, so a correction lands in both)."""
    B, b = float(total_bits), float(num_hashes)
    c = np.asarray(inter_size, dtype=np.float64)
    return np.exp(c * b / (B - 1.0)) * B / b**2 - B / b**2 - c / b


def bf_and_mse_bound(inter_size: float, total_bits: int, num_hashes: int) -> float:
    """Prop IV.1: MSE upper bound for |X∩Y|_AND (up to the (1+o(1)) factor).

    Valid when b = o(sqrt(B)) and b·|X∩Y| <= 0.499·B·log(B).
    """
    return float(_bf_and_mse(inter_size, total_bits, num_hashes))


def bf_kway_and_mse_bound(inter_size: float, total_bits: int,
                          num_hashes: int, k: int = 2) -> float:
    """MSE bound for the *direct* k-way AND estimator |X_1∩…∩X_k|_AND.

    The Swamidass map applied to popcount(B_1 AND … AND B_k) sees exactly
    one derived Bloom row whose true-bit process is governed by the k-way
    intersection size, so Prop IV.1's MSE expression carries over with
    ``inter_size`` the k-way intersection (the AND row's ones are
    stochastically *closer* to the true-bits-only row as k grows — each
    extra AND strips false-positive bits that survive the pairwise case —
    so this is conservative for k > 2). Validity mirrors the pairwise
    bound: b = o(sqrt(B)) and b·|∩| <= 0.499·B·log(B).

    This is why ``repro.engine.setexpr`` lowers k-way queries (e.g. the
    5-clique 4-way AND) to a *single* fused AND expression instead of
    inclusion–exclusion over the 2^k − 1 pairwise/union terms: the direct
    estimator needs one popcount with one MSE of this form, while the
    inclusion–exclusion expansion sums 2^k − 1 estimates whose errors add
    (in the best, independent case) and whose alternating signs lose the
    intersection's monotonicity — the kH 3-way path in
    ``core.algorithms.cliques`` shows the degradation in practice.
    """
    if k < 2:
        raise ValueError(f"k-way AND needs k >= 2, got {k}")
    return float(_bf_and_mse(inter_size, total_bits, num_hashes))


def bf_and_deviation_bound(inter_size: float, total_bits: int, num_hashes: int,
                           t: float) -> float:
    """Eq. 3: Chebyshev-on-MSE tail bound P(|est−truth| ≥ t)."""
    if t <= 0:
        return 1.0
    return min(1.0, bf_and_mse_bound(inter_size, total_bits, num_hashes) / t**2)


def bf_linear_mse_bound(set_size: float, total_bits: int, num_hashes: int,
                        delta: float | None = None) -> float:
    """Prop A.2: exact (assumption-free) MSE bound for linear estimators
    δ·B_{X,1}; δ defaults to 1/b (the |X|_L / |X∩Y|_L estimator)."""
    B, b, c = float(total_bits), float(num_hashes), float(set_size)
    d = (1.0 / b) if delta is None else float(delta)
    lam = c * b / B
    bias2 = (c - d * B * (1.0 - np.exp(-lam))) ** 2
    var = d**2 * B * (np.exp(-lam) - (1.0 + lam) * np.exp(-2.0 * lam))
    return float(bias2 + var)


def bf_linear_deviation_bound(set_size: float, total_bits: int, num_hashes: int,
                              t: float, delta: float | None = None) -> float:
    if t <= 0:
        return 1.0
    return min(1.0, bf_linear_mse_bound(set_size, total_bits, num_hashes, delta) / t**2)


def minhash_deviation_bound(size_x: float, size_y: float, k: int, t: float) -> float:
    """Prop IV.2 / IV.3 (identical form): exponential tail for kH and 1H,
    P(| |X∩Y|_MH − |X∩Y| | ≥ t) ≤ 2·exp(−2kt² / (|X|+|Y|)²)."""
    if t <= 0:
        return 1.0
    s = float(size_x) + float(size_y)
    if s == 0:
        return 0.0
    return min(1.0, 2.0 * np.exp(-2.0 * k * t**2 / s**2))


def bf_and_rmse(inter_size, total_bits: int, num_hashes: int) -> np.ndarray:
    """Vectorized RMSE form of Prop IV.1 (clamped to 0 outside validity).

    Streaming maintenance uses this as the BF sketch's intrinsic error scale:
    staleness from deferred deletions that stays below it is statistically
    invisible, so rebuilds can wait (the error-budget policy).
    """
    return np.sqrt(np.maximum(
        _bf_and_mse(inter_size, total_bits, num_hashes), 0.0))


def minhash_error_scale(set_size, k: int, delta: float = 0.05) -> np.ndarray:
    """Invert Prop IV.2 at fixed k: smallest t whose deviation probability is
    ≤ delta for a pair of sets of the given size (vectorized over sizes).

    t = (|X|+|Y|)·sqrt(ln(2/δ) / 2k); with |X| = |Y| = set_size this is the
    MinHash/KMV analogue of :func:`bf_and_rmse` for the streaming
    error-budget policy.
    """
    s = 2.0 * np.asarray(set_size, dtype=np.float64)
    return s * np.sqrt(np.log(2.0 / float(delta)) / (2.0 * max(int(k), 1)))


def minhash_k_for_accuracy(size_x: float, size_y: float, t: float, delta: float) -> int:
    """Invert Prop IV.2: smallest k with deviation ≥t having prob ≤ delta."""
    s = float(size_x) + float(size_y)
    if t <= 0 or s == 0:
        return 1
    return int(np.ceil(s**2 * np.log(2.0 / delta) / (2.0 * t**2)))


# ---------------------------------------------------------------------------
# Triangle-count bounds (Theorem VII.1)
# ---------------------------------------------------------------------------

def tc_bf_deviation_bound(m: int, max_degree: int, total_bits: int,
                          num_hashes: int, t: float) -> float:
    """Thm VII.1, BF case. Valid when b·Δ ≤ 0.499·B·log(B)."""
    if t <= 0:
        return 1.0
    mse = float(_bf_and_mse(max_degree, total_bits, num_hashes))
    return min(1.0, 2.0 * m**2 * mse / (9.0 * t**2))


def tc_minhash_deviation_bound(degrees: np.ndarray, k: int, t: float) -> float:
    """Thm VII.1, MinHash case: 2·exp(−18kt² / (Σ d(v)²)²)."""
    if t <= 0:
        return 1.0
    s2 = float(np.sum(np.asarray(degrees, dtype=np.float64) ** 2))
    if s2 == 0:
        return 0.0
    return min(1.0, 2.0 * np.exp(-18.0 * k * t**2 / s2**2))


def tc_minhash_deviation_bound_bounded_degree(degrees: np.ndarray, k: int, t: float) -> float:
    """Thm VII.1, tighter MinHash bound via Vizing grouping:
    2·exp(−9kt² / (4(Δ+1)·Σ d(v)³))."""
    if t <= 0:
        return 1.0
    d = np.asarray(degrees, dtype=np.float64)
    s3 = float(np.sum(d**3))
    if s3 == 0:
        return 0.0
    delta = float(d.max())
    return min(1.0, 2.0 * np.exp(-9.0 * k * t**2 / (4.0 * (delta + 1.0) * s3)))


# ---------------------------------------------------------------------------
# Sweep-cut conductance bounds (local clustering; Prop IV.1 accumulated)
# ---------------------------------------------------------------------------

def sweep_cut_rmse(prefix_degrees: np.ndarray, total_bits: int,
                   num_hashes: int) -> np.ndarray:
    """Cumulative RMSE of the sketch-gated sweep *cut* after each step.

    Step j of a sweep estimates ``|N(v_j) ∩ S_{j-1}|`` by inclusion–exclusion
    (both set sizes are known exactly, only ``|N(v_j) ∪ S_{j-1}|`` is
    estimated from the OR of the two filters), so the step error is the
    Swamidass size-estimator error at the *union* size ``d(v_j) + j`` — the
    Prop IV.1 MSE expression evaluated there, which correctly explodes as
    the prefix filter saturates. Each estimate enters the running cut with
    weight 2, and consecutive steps share the growing prefix filter, so
    their errors *correlate* —
    the right accumulation is the sum of per-step RMSEs (worst case under
    arbitrary correlation), not the independent-errors square root:

        err_scale(cut_j) = 2 · Σ_{i≤j} RMSE(d(v_i) + i)

    (empirically the observed drift tracks this sum; the sqrt-of-variances
    form underestimates it by >5× on Kronecker sweeps). ``prefix_degrees``
    is the degree sequence in sweep order; returns the vector of cumulative
    cut error scales (one per prefix). Divide by
    ``min(vol(S_j), vol(V∖S_j))`` for the conductance error scale.
    """
    degs = np.asarray(prefix_degrees, dtype=np.float64)
    union = degs + np.arange(degs.size, dtype=np.float64)
    mse = np.maximum(_bf_and_mse(union, total_bits, num_hashes), 0.0)
    return 2.0 * np.cumsum(np.sqrt(mse))


def sweep_conductance_interval(prefix_degrees: np.ndarray, volumes: np.ndarray,
                               total_bits: int, num_hashes: int,
                               delta: float = 0.05) -> np.ndarray:
    """Half-width of a (1−δ) Chebyshev interval on each prefix's conductance.

    ``|φ_est(S_j) − φ(S_j)| ≤ RMSE(cut_j) / (sqrt(δ)·denom_j)`` with
    probability ≥ 1−δ, where ``denom_j = min(vol(S_j), 2m − vol(S_j))``
    passed in as ``volumes``. Vectorized over prefixes.
    """
    rmse = sweep_cut_rmse(prefix_degrees, total_bits, num_hashes)
    denom = np.maximum(np.asarray(volumes, dtype=np.float64), 1.0)
    return rmse / (np.sqrt(float(delta)) * denom)


def bloom_words_for_conductance(target_err: float, typical_degree: float,
                                sweep_len: int, volume: float,
                                num_hashes: int = 2, delta: float = 0.05,
                                max_words: int = 1 << 16) -> int:
    """Smallest Bloom words/vertex whose sweep conductance error ≤ target.

    Inverts :func:`sweep_conductance_interval` at a homogeneous model sweep
    (``sweep_len`` steps, every step at ``typical_degree``, denominator
    ``volume``) by doubling the word count until the (1−δ) interval half-width
    at the *last* prefix — the worst one, errors only accumulate — drops
    under ``target_err``. The streaming/serving path uses this to size the
    sketch from a conductance-error budget instead of a storage budget.

    Raises ``ValueError`` when even ``max_words`` cannot meet the target
    (rather than silently returning an undersized sketch) — shorten the
    sweep, raise δ, or relax the target.
    """
    degs = np.full(max(int(sweep_len), 1), float(typical_degree))
    words = 2
    while True:
        half = sweep_conductance_interval(
            degs, np.full_like(degs, float(volume)), words * 32, num_hashes,
            delta)[-1]
        if half <= target_err:
            return int(words)
        if words >= max_words:
            raise ValueError(
                f"target conductance error {target_err} unreachable at "
                f"max_words={max_words} (half-width {half:.3g}); shorten "
                "the sweep, raise delta, or relax the target")
        words *= 2


# ---------------------------------------------------------------------------
# KMV bounds (Prop A.7 / A.9) — regularized incomplete beta via series
# ---------------------------------------------------------------------------

def _reg_inc_beta_int(x: float, k: int, n: int) -> float:
    """I_x(k, n-k+1) = P(Bin(n, x) >= k), exact binomial-sum form."""
    if x <= 0:
        return 0.0
    if x >= 1:
        return 1.0
    # sum_{i=k}^{n} C(n,i) x^i (1-x)^{n-i}, computed in log space
    from math import lgamma as _lg
    lx, l1x = np.log(x), np.log1p(-x)
    total = 0.0
    for i in range(k, n + 1):
        logp = _lg(n + 1) - _lg(i + 1) - _lg(n - i + 1) + i * lx + (n - i) * l1x
        total += np.exp(logp)
    return float(min(1.0, total))


def kmv_size_containment_prob(set_size: int, k: int, t: float) -> float:
    """Prop A.7: P(| |X|_K − |X| | ≤ t) for a full KMV sketch."""
    n = int(set_size)
    if n <= k:
        return 1.0  # sketch holds the whole set: exact
    u = min(1.0, (k - 1) / max(n - t, 1e-12))
    l = (k - 1) / (n + t)
    return max(0.0, _reg_inc_beta_int(u, k, n) - _reg_inc_beta_int(l, k, n))


def kmv_intersection_deviation_bound(union_size: int, k: int, t: float) -> float:
    """Prop A.9 (exact-degree variant, Eq. 41): deviation prob of |X∩Y|_K
    equals that of |X∪Y|_K at distance t."""
    return max(0.0, 1.0 - kmv_size_containment_prob(union_size, k, t))
