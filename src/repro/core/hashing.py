"""Hash functions for probabilistic set representations.

The paper uses MurmurHash3 for its speed/simplicity; we use the murmur3
``fmix32`` finalizer (the avalanche core of MurmurHash3) on uint32 keys,
parameterized by a per-function seed. Pure jnp on uint32 so it is jit-able,
vmap-able, and bit-exact across hosts (important for distributed sketch
construction: every shard must agree on h_i(x)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)  # seed spacing (Weyl constant)


def fmix32(x: jax.Array) -> jax.Array:
    """Murmur3 32-bit finalizer. x: uint32 array -> uint32 array."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _C1
    x = x ^ (x >> 13)
    x = x * _C2
    x = x ^ (x >> 16)
    return x


def hash_u32(x: jax.Array, seed) -> jax.Array:
    """Seeded 32-bit hash of integer keys. Accepts any int dtype."""
    x = x.astype(jnp.uint32)
    seed = jnp.asarray(seed, dtype=jnp.uint32)
    return fmix32(x ^ fmix32(seed * _GOLDEN + jnp.uint32(1)))


def hash_family(x: jax.Array, num_fns: int, seed) -> jax.Array:
    """Evaluate ``num_fns`` independent hash functions on x.

    Returns uint32 array of shape ``x.shape + (num_fns,)``.
    """
    seeds = jnp.arange(num_fns, dtype=jnp.uint32) + jnp.asarray(seed, jnp.uint32) * _GOLDEN
    # broadcast: x[..., None] ^ per-fn tweak
    return hash_u32(x[..., None] * jnp.uint32(1) + jnp.uint32(0), seeds)


def hash_unit_interval(x: jax.Array, seed) -> jax.Array:
    """Hash keys to (0, 1] as float32 (for KMV sketches)."""
    h = hash_u32(x, seed)
    # (h + 1) / 2^32 in (0, 1]; do it in float64-free fashion
    return (h.astype(jnp.float32) + 1.0) * jnp.float32(2.0 ** -32)


# numpy twin (bit-identical) for fast host-side construction ---------------

def np_fmix32(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint32(16))
        x = (x * _C1).astype(np.uint32)
        x = x ^ (x >> np.uint32(13))
        x = (x * _C2).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
    return x


def np_hash_u32(x: np.ndarray, seed: int) -> np.ndarray:
    x = np.asarray(x, dtype=np.uint32)
    seed = np.uint32(seed)
    with np.errstate(over="ignore"):
        inner = np_fmix32(np.asarray(seed * _GOLDEN + np.uint32(1), dtype=np.uint32))
    return np_fmix32(x ^ inner)
