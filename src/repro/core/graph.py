"""Graph model: CSR + padded adjacency, generators, and host utilities.

The paper stores G in CSR (indptr + sorted neighbor arrays). For TPU/JAX we
additionally keep a *padded adjacency* matrix ``adj[n, d_max]`` (rows sorted,
padded with the sentinel ``n``) so that per-edge neighborhood gathers are a
single `jnp.take`, and vmapped set algebra (merge / galloping) is regular.

Degree skew makes the padded form wasteful for power-law graphs — exactly the
load-imbalance pathology the paper's fixed-size sketches remove — but it is
the right *exact-baseline* representation on a vector machine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .hashing import np_hash_u32


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected graph in CSR + padded-adjacency form (device arrays).

    Attributes:
      indptr:  int32[n+1]   CSR row pointers.
      indices: int32[2m]    concatenated sorted neighbor lists.
      adj:     int32[n, d_max] padded adjacency (pad value == n).
      deg:     int32[n]     vertex degrees.
      edges:   int32[m, 2]  unique undirected edges with u < v.
      n_vertices / n_edges / d_max: static ints (aux data).
    """

    indptr: jax.Array
    indices: jax.Array
    adj: jax.Array
    deg: jax.Array
    edges: jax.Array
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    d_max: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n(self) -> int:
        return self.n_vertices

    @property
    def m(self) -> int:
        return self.n_edges


def canonical_edge_keys(n: int, edges) -> np.ndarray:
    """Sorted unique canonical keys ``lo·n + hi`` (u < v) of a raw edge array.

    Self loops and out-of-range endpoints are dropped; ``n == 0`` yields an
    empty key set (the key would otherwise divide by n on the way back out).
    Shared by :func:`from_edge_array` and the streaming ``DynamicGraph`` so
    both agree on edge identity.
    """
    if edges is None:
        return np.zeros(0, dtype=np.int64)
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if e.size == 0 or n == 0:
        return np.zeros(0, dtype=np.int64)
    u, v = e[:, 0], e[:, 1]
    keep = (u != v) & (u >= 0) & (v >= 0) & (u < n) & (v < n)
    u, v = u[keep], v[keep]
    lo, hi = np.minimum(u, v), np.maximum(u, v)
    return np.unique(lo * n + hi)


def from_edge_array(n: int, edges: np.ndarray, pad_to_max_degree: Optional[int] = None) -> Graph:
    """Build a Graph from an (possibly duplicated / both-direction) edge array."""
    key = canonical_edge_keys(n, edges)
    if n > 0:
        lo, hi = key // n, key % n
    else:
        lo = hi = np.zeros(0, dtype=np.int64)
    m = lo.shape[0]

    # symmetric CSR
    src = np.concatenate([lo, hi])
    dst = np.concatenate([hi, lo])
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    deg = np.bincount(src, minlength=n).astype(np.int32)
    indptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(deg, out=indptr[1:])
    d_max = int(deg.max()) if n else 0
    if pad_to_max_degree is not None:
        d_max = max(d_max, pad_to_max_degree)
    d_max = max(d_max, 1)

    # padded adjacency, pad sentinel = n (sorts after every valid id)
    adj = np.full((n, d_max), n, dtype=np.int32)
    col = np.arange(len(src)) - indptr[src]
    adj[src, col] = dst

    return Graph(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(dst.astype(np.int32)),
        adj=jnp.asarray(adj),
        deg=jnp.asarray(deg),
        edges=jnp.asarray(np.stack([lo, hi], axis=1).astype(np.int32)),
        n_vertices=int(n),
        n_edges=int(m),
        d_max=int(d_max),
    )


def graph_view(n: int, m: int, deg: jax.Array, adj: jax.Array,
               edges: jax.Array) -> Graph:
    """``Graph`` over live device buffers — zero host → device traffic.

    The streaming hot path hands in its persistent device arrays (``deg``
    int32[n], ``adj`` int32[n, cap] sorted rows padded with n, ``edges``
    int32[m, 2] in canonical key order) and gets the engine's graph type
    without any host materialization: the CSR fields are *derived on device*
    — indptr is a cumsum of deg, and indices come from lexsorting both
    directions of the edge list by (src, dst), exactly how
    ``from_edge_array`` builds them, so the cost is O(m log m) (not O(n·cap)
    like a dense adjacency scan) with the sort shape pow2-bucketed to keep
    one compiled variant per size class across deltas. The only difference
    from ``from_edge_array`` is the adjacency width — ``cap`` headroom
    columns instead of a tight d_max — and the padding sentinel makes the
    extra columns invisible to every consumer.

    The CSR derivation is eager even though the streaming tc/lcc/similarity
    hot path reads only adj/deg/edges: ``Graph`` is a frozen pytree whose
    fields must be arrays (a lazy thunk would break flattening), and a view
    missing its CSR would fail *silently* in host-side consumers
    (``neighbors_np``, ``build_bloom_np``). The cost is device-only compute
    — zero host traffic, the resource this path actually bounds.
    """
    indptr = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(deg, dtype=jnp.int32)])
    cap = int(adj.shape[1]) if n else 1
    m_b = 1 << (max(2 * m, 1) - 1).bit_length()
    pad = jnp.full(m_b - 2 * m, n, dtype=jnp.int32)    # sorts after real ids
    src = jnp.concatenate([edges[:, 0], edges[:, 1], pad])
    dst = jnp.concatenate([edges[:, 1], edges[:, 0], pad])
    order = jnp.lexsort((dst, src))[: 2 * m]
    indices = jnp.take(dst, order).astype(jnp.int32)
    return Graph(indptr=indptr, indices=indices, adj=adj, deg=deg,
                 edges=edges, n_vertices=int(n), n_edges=int(m),
                 d_max=max(cap, 1))


# ----------------------------------------------------------------------------
# Generators (paper: Kronecker power-law synthetics + real-world sets)
# ----------------------------------------------------------------------------

def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    max_pairs = n * (n - 1) // 2
    if max_pairs == 0 or p <= 0.0:
        return from_edge_array(n, np.zeros((0, 2), dtype=np.int64))
    if max_pairs <= 4_000_000:
        iu = np.triu_indices(n, k=1)
        mask = rng.random(iu[0].shape[0]) < p
        edges = np.stack([iu[0][mask], iu[1][mask]], axis=1)
    else:
        # geometric skipping over the linearized upper triangle: each slot is
        # kept independently with prob p by jumping Geometric(p) positions at
        # a time — the exact Bernoulli process, so no duplicate pairs, no
        # self loops, and E[m] = p·max_pairs, without n² memory on big n.
        sel = []
        pos = np.int64(-1)
        batch = int(1.2 * p * max_pairs) + 1024
        while pos < max_pairs:
            gaps = rng.geometric(p, size=batch).astype(np.int64)
            steps = np.cumsum(gaps) + pos
            sel.append(steps[steps < max_pairs])
            pos = steps[-1]
        t = np.concatenate(sel)
        edges = np.stack(_triu_unrank(t, n), axis=1)
    return from_edge_array(n, edges)


def _triu_unrank(t: np.ndarray, n: int):
    """Linear index t in the row-major strict upper triangle -> (u, v), u < v.

    Row u starts at S(u) = u·(2n-1-u)/2; invert via the float quadratic root,
    then correct the rare off-by-one from sqrt rounding.
    """
    u = np.floor((2.0 * n - 1.0 - np.sqrt((2.0 * n - 1.0) ** 2 - 8.0 * t)) / 2.0
                 ).astype(np.int64)
    for _ in range(2):
        start = u * (2 * n - 1 - u) // 2
        u = np.where(start > t, u - 1, u)
        end = (u + 1) * (2 * n - 2 - u) // 2
        u = np.where(end <= t, u + 1, u)
    v = t - u * (2 * n - 1 - u) // 2 + u + 1
    return u, v


def kronecker(scale: int, edge_factor: int = 16, seed: int = 0,
              a: float = 0.57, b: float = 0.19, c: float = 0.19) -> Graph:
    """Graph500-style stochastic Kronecker (power-law degree distribution)."""
    n = 1 << scale
    m = edge_factor * n
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r1 = rng.random(m)
        r2 = rng.random(m)
        src_bit = r1 > ab
        thresh = np.where(src_bit, c / (1.0 - ab), a / ab)
        dst_bit = r2 > thresh
        src += src_bit.astype(np.int64) << bit
        dst += dst_bit.astype(np.int64) << bit
    # permute vertex ids to destroy locality (standard practice)
    perm = rng.permutation(n)
    return from_edge_array(n, np.stack([perm[src], perm[dst]], axis=1))


def barabasi_albert(n: int, m_attach: int = 4, seed: int = 0) -> Graph:
    """Preferential-attachment power-law graph (cheap host construction)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = []
    edges = []
    for v in range(m_attach, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m_attach)
        idx = rng.integers(0, len(repeated), size=m_attach)
        targets = [repeated[i] for i in idx]
    return from_edge_array(n, np.asarray(edges, dtype=np.int64))


def random_bipartite_community(n: int, communities: int, p_in: float, p_out: float,
                               seed: int = 0) -> Graph:
    """Planted-partition graph: dense communities, sparse cross edges."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, communities, size=n)
    u = rng.integers(0, n, size=int(6 * n * max(p_in, 1e-6) * n / communities) + 4 * n)
    v = rng.integers(0, n, size=u.shape[0])
    same = labels[u] == labels[v]
    keep = np.where(same, rng.random(u.shape[0]) < p_in, rng.random(u.shape[0]) < p_out)
    return from_edge_array(n, np.stack([u[keep], v[keep]], axis=1))


# ----------------------------------------------------------------------------
# Host helpers
# ----------------------------------------------------------------------------

def neighbors_np(g: Graph, v: int) -> np.ndarray:
    indptr = np.asarray(g.indptr)
    indices = np.asarray(g.indices)
    return indices[indptr[v]:indptr[v + 1]]


def triangle_count_dense(g: Graph) -> int:
    """Exact TC oracle via dense A^3 trace (small graphs only)."""
    n = g.n
    a = np.zeros((n, n), dtype=np.int64)
    e = np.asarray(g.edges)
    a[e[:, 0], e[:, 1]] = 1
    a[e[:, 1], e[:, 0]] = 1
    return int(np.trace(a @ a @ a) // 6)


def four_clique_count_bruteforce(g: Graph) -> int:
    """Exact 4-clique oracle (tiny graphs only): O(m * d^2)."""
    n = g.n
    adj_sets = [set(neighbors_np(g, v).tolist()) for v in range(n)]
    count = 0
    e = np.asarray(g.edges)
    for u, v in e:
        common = sorted(adj_sets[u] & adj_sets[v])
        for i in range(len(common)):
            wi = common[i]
            for j in range(i + 1, len(common)):
                wj = common[j]
                if wj in adj_sets[wi]:
                    count += 1
    return count // 6  # each 4-clique counted once per each of its 6 edges
