"""Incremental sketch maintenance for streaming graphs.

ProbGraph's representations are cheap to *maintain*, not just to intersect:

  * Bloom inserts are monotone — scatter-OR only the new elements' bit
    positions into the touched rows.
  * k-Hash inserts are lexicographic (hash, element) min-merges per hash fn.
  * 1-Hash inserts are sorted merges of (hash, element) pairs, keep-k.
  * KMV inserts are sorted merges of unit-interval hash values, keep-k.

All four incremental updates are **bit-identical** to a from-scratch rebuild
on the post-insert adjacency (the builders' tie-breaking — stable argsort /
first-argmin over id-sorted rows — equals the (hash, element) lexicographic
order used here), which the property tests assert per kind.

Deletions are not monotone: a deleted element may be the very minimum a row
stores. Deletion therefore marks rows *dirty* and defers work: each dirty
row tracks how many deleted-but-still-sketched (phantom) elements it holds,
and an :class:`ErrorBudgetPolicy` — driven by the paper's own accuracy
bounds in ``core.bounds`` — decides when the accumulated staleness exceeds
the sketch's intrinsic error scale and the row must be selectively rebuilt
through the existing chunked builders (only dirty rows, never the full
O(b·Σd_v) pass).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import bounds
from ..core.hashing import hash_u32, hash_unit_interval
from ..core.sketches import (KMV_PAD, PAD_HASH, SketchSet, _map_vertex_chunks,
                             _positions, bloom_rows, bloom_words_for_budget,
                             khash_rows, kmv_rows, minhash_k_for_budget,
                             onehash_rows, onehash_values, pack_bits)
from ..engine.api import pow2_bucket
from ..obs import trace
from .dynamic_graph import DeltaResult, DynamicGraph


# ----------------------------------------------------------------------------
# error-budget policy (core.bounds-driven deferral of deletion rebuilds)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ErrorBudgetPolicy:
    """When must a dirty (deletion-pending) sketch row be rebuilt?

    Every phantom element (deleted from the graph, still in the sketch)
    perturbs any |N_u ∩ N_v| estimate through that row by at most 1, so a
    row's stale count is an additive error bound on its answers. The policy
    tolerates staleness up to ``rel_tolerance`` × the sketch's own
    statistical error scale at the row's degree (Prop IV.1 RMSE for Bloom,
    inverted Prop IV.2 for MinHash/KMV): deferred deletions hide below the
    estimator's intrinsic noise floor.

    ``rel_tolerance=0`` (the default) rebuilds every dirty row immediately —
    strict mode, streaming answers stay bit-identical to a from-scratch
    build. ``max_stale`` is an absolute cap independent of degree.
    """

    rel_tolerance: float = 0.0
    confidence: float = 0.05
    max_stale: int = 1 << 30

    def allowed_stale(self, sketch: SketchSet, degrees: np.ndarray) -> np.ndarray:
        """Per-row stale-count budget at the given degrees (0 = strict)."""
        if self.rel_tolerance <= 0.0:
            return np.zeros(np.shape(degrees), dtype=np.float64)
        if sketch.kind == "bf":
            scale = bounds.bf_and_rmse(degrees, sketch.total_bits,
                                       sketch.num_hashes)
        else:
            scale = bounds.minhash_error_scale(degrees, sketch.k,
                                               self.confidence)
        return np.minimum(self.rel_tolerance * scale, float(self.max_stale))


#: rebuild-immediately policy: streaming ≡ from-scratch, bit for bit
STRICT_POLICY = ErrorBudgetPolicy(rel_tolerance=0.0)


# ----------------------------------------------------------------------------
# batched device update kernels (one per sketch kind)
# ----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "num_hashes", "seed",
                                             "total_bits"))
def _bloom_insert(data, rows, new_elems, *, n, num_hashes, seed, total_bits):
    """Scatter-OR only the new elements' bit positions into the given rows."""
    pos, valid = _positions(new_elems, n, num_hashes, total_bits, seed)
    t = rows.shape[0]
    row_idx = jnp.broadcast_to(jnp.arange(t)[:, None, None], pos.shape)
    vmask = jnp.broadcast_to(valid[..., None], pos.shape)
    bits = jnp.zeros((t, total_bits), dtype=jnp.bool_)
    bits = bits.at[row_idx.reshape(-1),
                   jnp.where(vmask, pos, 0).reshape(-1)].max(vmask.reshape(-1))
    cur = jnp.take(data, rows, axis=0)
    # padded entries carry row index n (out of range) and are dropped
    return data.at[rows].set(cur | pack_bits(bits), mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "seed"))
def _khash_insert(data, rows, new_elems, *, n, seed):
    """Per-hash-fn lexicographic (hash, element) min-merge of new elements."""
    k = data.shape[1]
    cur = jnp.take(data, rows, axis=0)                       # [T, k]
    seeds = (jnp.arange(k, dtype=jnp.uint32)
             + jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
    cur_valid = cur < n
    cur_h = jnp.where(cur_valid,
                      hash_u32(jnp.where(cur_valid, cur, 0), seeds), PAD_HASH)
    nvalid = new_elems < n
    safe = jnp.where(nvalid, new_elems, 0)
    h = hash_u32(safe[..., None], seeds)                     # [T, L, k]
    h = jnp.where(nvalid[..., None], h, PAD_HASH)
    # first-argmin over id-sorted new elements == lexicographic (h, elem) min
    arg = jnp.argmin(h, axis=1)                              # [T, k]
    e_new = jnp.take_along_axis(new_elems, arg, axis=1)
    h_new = jnp.take_along_axis(h, arg[:, None, :], axis=1)[:, 0, :]
    better = (h_new < cur_h) | ((h_new == cur_h) & (e_new < cur))
    return data.at[rows].set(jnp.where(better, e_new, cur).astype(jnp.int32),
                             mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "seed"))
def _onehash_insert(data, rows, new_elems, *, n, seed):
    """Sorted (hash, element) merge of current k-set with new elements."""
    k = data.shape[1]
    cur = jnp.take(data, rows, axis=0)
    cur_h = onehash_values(cur, n, seed)
    nvalid = new_elems < n
    new_h = jnp.where(nvalid,
                      hash_u32(jnp.where(nvalid, new_elems, 0),
                               jnp.uint32(seed)), PAD_HASH)
    elems = jnp.concatenate([cur, jnp.where(nvalid, new_elems, n)], axis=1)
    hs = jnp.concatenate([cur_h, new_h], axis=1)
    order = jnp.lexsort((elems, hs), axis=-1)[:, :k]
    sel_e = jnp.take_along_axis(elems, order, axis=1)
    sel_h = jnp.take_along_axis(hs, order, axis=1)
    return data.at[rows].set(
        jnp.where(sel_h == PAD_HASH, n, sel_e).astype(jnp.int32), mode="drop")


@functools.partial(jax.jit, static_argnames=("n", "seed"))
def _kmv_insert(data, rows, new_elems, *, n, seed):
    """Sorted merge of current k smallest hash values with new ones."""
    k = data.shape[1]
    cur = jnp.take(data, rows, axis=0)
    nvalid = new_elems < n
    nh = jnp.where(nvalid,
                   hash_unit_interval(jnp.where(nvalid, new_elems, 0),
                                      jnp.uint32(seed)), KMV_PAD)
    merged = jnp.sort(jnp.concatenate([cur, nh], axis=1), axis=1)[:, :k]
    return data.at[rows].set(merged, mode="drop")


# ----------------------------------------------------------------------------
# maintainer
# ----------------------------------------------------------------------------

class SketchMaintainer:
    """Owns one sketch of a :class:`DynamicGraph` and keeps it current.

    Inserts are absorbed incrementally (per-kind device merges above);
    deletions mark rows dirty and are repaired by selective rebuild of only
    the dirty rows through the chunked batch builders, when the
    :class:`ErrorBudgetPolicy` says their staleness is no longer affordable.
    """

    def __init__(self, dyn: DynamicGraph, kind: str = "bf",
                 storage_budget: float = 0.25, num_hashes: int = 2,
                 seed: int = 0, words: Optional[int] = None,
                 k: Optional[int] = None,
                 policy: Optional[ErrorBudgetPolicy] = None,
                 chunk: int = 4096, data: Optional[jnp.ndarray] = None):
        if kind not in ("bf", "kh", "1h", "kmv"):
            raise ValueError(f"unknown sketch kind: {kind}")
        self.dyn = dyn
        self.kind = kind
        self.num_hashes = int(num_hashes)
        self.seed = int(seed)
        self.policy = policy if policy is not None else STRICT_POLICY
        self.chunk = int(chunk)
        n, m = dyn.n, dyn.m
        if kind == "bf":
            self.words = int(words) if words is not None else \
                bloom_words_for_budget(n, m, storage_budget)
            self.k = 0
        else:
            self.words = 0
            self.k = int(k) if k is not None else \
                minhash_k_for_budget(n, m, storage_budget)
        self.dirty = np.zeros(n, dtype=bool)
        self.stale = np.zeros(n, dtype=np.int64)
        self.rows_rebuilt = 0
        self.rows_incremental = 0
        self.deltas_applied = 0
        if data is None:
            # build from the device mirror when it exists (StreamSession
            # creates it first — no second adjacency upload); otherwise the
            # meter copies before upload: jnp.asarray of a host buffer can
            # be zero-copy on CPU, and dyn.adj is mutated in place by
            # subsequent deltas while this build may still be executing
            # asynchronously
            adj_dev = (dyn._device.adj if dyn._device is not None
                       else dyn.traffic.put(dyn.adj, init=True))
            data = self._build_rows(adj_dev)
        self.sketch = SketchSet(
            data=data, kind=kind,
            num_hashes=self.num_hashes if kind == "bf" else 0,
            k=self.k, seed=self.seed, n=n)

    # -- full/selective construction through the chunked builders ----------

    def _row_fn(self):
        n = self.dyn.n
        if self.kind == "bf":
            return functools.partial(bloom_rows, n=n, words=self.words,
                                     num_hashes=self.num_hashes,
                                     seed=self.seed)
        fn = {"kh": khash_rows, "1h": onehash_rows, "kmv": kmv_rows}[self.kind]
        return functools.partial(fn, n=n, k=self.k, seed=self.seed)

    def _build_rows(self, adj_rows: jnp.ndarray) -> jnp.ndarray:
        if self.kind != "bf" and adj_rows.shape[1] < self.k:
            # keep-k row builders need at least k columns to slice
            adj_rows = jnp.pad(adj_rows,
                               ((0, 0), (0, self.k - adj_rows.shape[1])),
                               constant_values=self.dyn.n)
        tail = (self.words,) if self.kind == "bf" else (self.k,)
        dtype = {"bf": jnp.uint32, "kmv": jnp.float32}.get(self.kind, jnp.int32)
        return _map_vertex_chunks(self._row_fn(), adj_rows, self.chunk,
                                  tail, dtype)

    # -- delta application -------------------------------------------------

    def apply(self, delta: DeltaResult) -> np.ndarray:
        """Absorb one delta; returns the vertex ids rebuilt *now* (per the
        error-budget policy — empty when all deletions stayed affordable)."""
        self.deltas_applied += 1
        verts, new_nbrs = delta.insert_rows(self.dyn.n)
        if verts.size:
            with trace.span("sketch.insert", kind=self.kind,
                            rows=int(verts.size)) as sp:
                self._insert(verts, new_nbrs)
                sp.fence(self.sketch.data)
            self.rows_incremental += int(verts.size)
        if delta.deleted.size:
            ends = delta.deleted.ravel()
            self.dirty[delta.dirty] = True
            self.stale += np.bincount(ends, minlength=self.dyn.n)
        dirty_ids = np.nonzero(self.dirty)[0]
        if dirty_ids.size == 0:
            return dirty_ids
        allowed = self.policy.allowed_stale(self.sketch,
                                            self.dyn.deg[dirty_ids])
        rebuild = dirty_ids[self.stale[dirty_ids] > allowed]
        self.rebuild_rows(rebuild)
        return rebuild

    def _insert(self, verts: np.ndarray, new_nbrs: np.ndarray):
        # pad both axes to powers of two so jit recompiles stay bounded;
        # padded entries carry the out-of-range row index n and are dropped
        # by the scatter (a colliding in-range pad index could clobber a
        # real row's update)
        t, width = new_nbrs.shape
        t_p, l_p = pow2_bucket(t), pow2_bucket(width)
        rows = np.full(t_p, self.dyn.n, dtype=np.int32)
        rows[:t] = verts
        padded = np.full((t_p, l_p), self.dyn.n, dtype=np.int32)
        padded[:t, :width] = new_nbrs
        rows_j = self.dyn.traffic.put(rows)
        new_j = self.dyn.traffic.put(padded)
        if self.kind == "bf":
            data = _bloom_insert(self.sketch.data, rows_j, new_j,
                                 n=self.dyn.n, num_hashes=self.num_hashes,
                                 seed=self.seed,
                                 total_bits=self.sketch.total_bits)
        elif self.kind == "kh":
            data = _khash_insert(self.sketch.data, rows_j, new_j,
                                 n=self.dyn.n, seed=self.seed)
        elif self.kind == "1h":
            data = _onehash_insert(self.sketch.data, rows_j, new_j,
                                   n=self.dyn.n, seed=self.seed)
        else:
            data = _kmv_insert(self.sketch.data, rows_j, new_j,
                               n=self.dyn.n, seed=self.seed)
        self.sketch = dataclasses.replace(self.sketch, data=data)

    def rebuild_rows(self, verts: np.ndarray):
        """Selectively rebuild the given rows from the current adjacency
        through the chunked batch builders (never the full O(b·Σd_v) pass)."""
        verts = np.asarray(verts, dtype=np.int64)
        if verts.size == 0:
            return
        with trace.span("sketch.rebuild", kind=self.kind,
                        rows=int(verts.size)) as sp:
            self._rebuild_rows(verts)
            sp.fence(self.sketch.data)

    def _rebuild_rows(self, verts: np.ndarray):
        # bucket the row count to a power of two so deltas of varying size
        # reuse one compiled builder per (bucket, adjacency-width) pair;
        # padded entries carry row index n and are dropped by the scatter
        n, t = self.dyn.n, int(verts.size)
        bucket = pow2_bucket(t)
        rows_idx = np.full(bucket, n, dtype=np.int32)
        rows_idx[:t] = verts
        dev = self.dyn._device
        if dev is not None:
            # device-resident graph: gather the rebuild inputs from the live
            # device adjacency — only the row *indices* cross the host
            # boundary (pad index n clips to a real row, whose result the
            # scatter then drops)
            idx_j = self.dyn.traffic.put(rows_idx)
            adj_rows = jnp.take(dev.adj, jnp.clip(idx_j, 0, max(n - 1, 0)),
                                axis=0)
        else:
            idx_j = jnp.asarray(rows_idx)
            adj_rows_np = np.full((bucket, self.dyn.capacity), n,
                                  dtype=np.int32)
            adj_rows_np[:t] = self.dyn.adj[verts]
            adj_rows = jnp.asarray(adj_rows_np)
        rows = self._build_rows(adj_rows)
        data = self.sketch.data.at[idx_j].set(rows, mode="drop")
        self.sketch = dataclasses.replace(self.sketch, data=data)
        self.dirty[verts] = False
        self.stale[verts] = 0
        self.rows_rebuilt += int(verts.size)

    def flush(self) -> np.ndarray:
        """Force-rebuild every dirty row (e.g. before a checkpoint); returns
        the rebuilt vertex ids."""
        dirty_ids = np.nonzero(self.dirty)[0]
        self.rebuild_rows(dirty_ids)
        return dirty_ids

    def stats(self) -> dict:
        """Maintenance counters: incremental rows, rebuilds, staleness."""
        return {
            "kind": self.kind,
            "rows_incremental": self.rows_incremental,
            "rows_rebuilt": self.rows_rebuilt,
            "rows_dirty": int(self.dirty.sum()),
            "stale_total": int(self.stale.sum()),
            "deltas_applied": self.deltas_applied,
        }
