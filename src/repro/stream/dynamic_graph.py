"""Mutable graph store for the streaming subsystem.

``DynamicGraph`` owns the same CSR + padded-adjacency representation as the
frozen :class:`repro.core.graph.Graph`, but host-side (numpy) and mutable:
adjacency rows carry *headroom* slots so a batched ``apply_delta`` usually
edits rows in place instead of reallocating, and ``snapshot()`` materializes
a device ``Graph`` that is bit-identical to ``from_edge_array`` on the same
edge set — so every batch-mode algorithm, sketch builder, and engine plan
runs unchanged on the evolving graph.

The vertex set [0, n) is fixed; edges arrive and depart in batches. Edge
identity is the canonical key ``lo·n + hi`` (u < v), kept as one sorted
int64 array so delta application and carry-index computation are pure
vectorized set algebra (SISA's framing: updates are set operations too).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph, canonical_edge_keys


@dataclasses.dataclass(frozen=True)
class DeltaResult:
    """What one ``apply_delta`` actually changed (post-canonicalization).

    Attributes:
      inserted: int64[I, 2]  newly present edges (u < v).
      deleted:  int64[D, 2]  removed edges (u < v).
      touched:  int64[T]     sorted unique vertices with any adjacency change.
      dirty:    int64[Dv]    sorted unique vertices that *lost* a neighbor
                             (their sketches cannot be updated monotonically).
      version:  graph version after this delta.
    """

    inserted: np.ndarray
    deleted: np.ndarray
    touched: np.ndarray
    dirty: np.ndarray
    version: int

    @property
    def is_noop(self) -> bool:
        return self.inserted.size == 0 and self.deleted.size == 0

    def insert_rows(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Per-vertex new-neighbor lists, padded for batched device updates.

        Returns ``(verts int32[T], new int32[T, L])`` where row i holds the
        neighbors vertex ``verts[i]`` gained, sorted ascending, padded with
        the sentinel ``n`` — the shape incremental sketch maintenance eats.
        """
        if self.inserted.size == 0:
            return (np.zeros(0, dtype=np.int32),
                    np.zeros((0, 1), dtype=np.int32))
        src = np.concatenate([self.inserted[:, 0], self.inserted[:, 1]])
        dst = np.concatenate([self.inserted[:, 1], self.inserted[:, 0]])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        verts, start = np.unique(src, return_index=True)
        counts = np.diff(np.append(start, src.size))
        padded = np.full((verts.size, int(counts.max())), n, dtype=np.int32)
        for i, (s, c) in enumerate(zip(start, counts)):
            padded[i, :c] = dst[s:s + c]
        return verts.astype(np.int32), padded


class DynamicGraph:
    """Mutable undirected graph on a fixed vertex set with batched deltas."""

    def __init__(self, n: int, edge_keys: np.ndarray, deg: np.ndarray,
                 adj: np.ndarray, headroom: float = 1.5, version: int = 0):
        self.n = int(n)
        self.edge_keys = edge_keys        # sorted int64[m], key = lo*n + hi
        self.deg = deg                    # int32[n]
        self.adj = adj                    # int32[n, cap]; rows sorted, pad = n
        self.headroom = float(headroom)
        self.version = int(version)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_edges(cls, n: int, edges, headroom: float = 1.5,
                   min_width: int = 4) -> "DynamicGraph":
        keys = canonical_edge_keys(n, edges)
        deg, adj = _build_adjacency(n, keys, headroom, min_width)
        return cls(n, keys, deg, adj, headroom)

    @classmethod
    def from_graph(cls, graph: Graph, headroom: float = 1.5) -> "DynamicGraph":
        return cls.from_edges(graph.n, np.asarray(graph.edges),
                              headroom=headroom)

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    @property
    def m(self) -> int:
        return int(self.edge_keys.shape[0])

    @property
    def capacity(self) -> int:
        return int(self.adj.shape[1])

    def neighbors(self, v: int) -> np.ndarray:
        return self.adj[v, :self.deg[v]]

    def edge_array(self) -> np.ndarray:
        """int64[m, 2] canonical (u < v) edges in key order."""
        return _decode_keys(self.n, self.edge_keys)

    def snapshot(self) -> Graph:
        """Device ``Graph`` of the current state — bit-identical (arrays and
        static fields) to ``from_edge_array(n, self.edge_array())``.

        Every numpy buffer handed to jax is a fresh copy: ``jnp.asarray`` of
        a host array can be zero-copy on CPU, and ``self.adj``/``self.deg``
        are mutated in place by later deltas — an aliased device view would
        change under any still-in-flight async computation.
        """
        n = self.n
        d_max = max(int(self.deg.max()) if n else 0, 1)
        mask = np.arange(self.capacity)[None, :] < self.deg[:, None]
        indices = self.adj[mask].astype(np.int32)      # row-major == CSR order
        indptr = np.zeros(n + 1, dtype=np.int32)
        np.cumsum(self.deg, out=indptr[1:])
        adj = self.adj[:, :d_max] if self.capacity >= d_max else np.pad(
            self.adj, ((0, 0), (0, d_max - self.capacity)), constant_values=n)
        return Graph(
            indptr=jnp.asarray(indptr),
            indices=jnp.asarray(indices),
            adj=jnp.asarray(np.array(adj, copy=True)),
            deg=jnp.asarray(self.deg.copy()),
            edges=jnp.asarray(self.edge_array().astype(np.int32)),
            n_vertices=n, n_edges=self.m, d_max=d_max)

    # ------------------------------------------------------------------
    # mutation
    # ------------------------------------------------------------------

    def apply_delta(self, inserts=None, deletes=None) -> DeltaResult:
        """Apply one batch of edge insertions and deletions.

        Both arguments are (possibly duplicated / both-direction / already
        present or absent) edge arrays; the applied delta is canonicalized:
        deletes that miss and inserts that already exist are dropped.
        Deletes are applied before inserts, so an edge listed in both ends
        up present (and both endpoints count as dirty).
        """
        n = self.n
        cur = self.edge_keys
        del_req = canonical_edge_keys(n, deletes)
        del_applied = del_req[np.isin(del_req, cur, assume_unique=True)]
        kept = (cur[~np.isin(cur, del_applied, assume_unique=True)]
                if del_applied.size else cur)
        ins_req = canonical_edge_keys(n, inserts)
        ins_applied = (ins_req[~np.isin(ins_req, kept, assume_unique=True)]
                       if ins_req.size else ins_req)

        ins_uv = _decode_keys(n, ins_applied)
        del_uv = _decode_keys(n, del_applied)
        self.version += 1
        if ins_applied.size == 0 and del_applied.size == 0:
            return DeltaResult(ins_uv, del_uv, np.zeros(0, np.int64),
                               np.zeros(0, np.int64), self.version)

        self.edge_keys = np.union1d(kept, ins_applied)
        touched = np.unique(np.concatenate([ins_uv.ravel(), del_uv.ravel()]))
        dirty = np.unique(del_uv.ravel())

        new_deg = self.deg.astype(np.int64)
        if ins_uv.size:
            new_deg += np.bincount(ins_uv.ravel(), minlength=n)
        if del_uv.size:
            new_deg -= np.bincount(del_uv.ravel(), minlength=n)
        need = int(new_deg.max())
        if need > self.capacity:
            # grow with headroom so a run of inserts amortizes reallocation
            cap = max(need, int(math.ceil(need * self.headroom)))
            grown = np.full((n, cap), n, dtype=np.int32)
            grown[:, :self.capacity] = self.adj
            self.adj = grown

        add = _partner_lists(ins_uv)
        drop = _partner_lists(del_uv)
        for v in touched:
            nbrs = self.adj[v, :self.deg[v]]
            if v in drop:
                nbrs = nbrs[~np.isin(nbrs, drop[v])]
            if v in add:
                nbrs = np.concatenate([nbrs, add[v]])
            nbrs = np.sort(nbrs)
            self.adj[v, :nbrs.size] = nbrs
            self.adj[v, nbrs.size:] = n
        self.deg = new_deg.astype(np.int32)
        return DeltaResult(ins_uv, del_uv, touched, dirty, self.version)

    def carry_index(self, old_keys: np.ndarray,
                    invalid_vertices: np.ndarray) -> Optional[np.ndarray]:
        """Map current edges to their row in a previous edge order.

        Returns int64[m] where entry j is the position of edge j in
        ``old_keys`` (a previous sorted ``edge_keys``) when neither endpoint
        is in ``invalid_vertices``, else -1 — exactly the
        ``MiningSession.refresh`` carry contract.
        """
        new_keys = self.edge_keys
        if self.n == 0 or new_keys.size == 0:
            return np.zeros(0, dtype=np.int64)
        if old_keys.size == 0:
            return np.full(new_keys.shape[0], -1, dtype=np.int64)
        pos = np.searchsorted(old_keys, new_keys)
        pos_c = np.minimum(pos, old_keys.size - 1)
        found = old_keys[pos_c] == new_keys
        bad = np.zeros(self.n, dtype=bool)
        bad[np.asarray(invalid_vertices, dtype=np.int64)] = True
        lo, hi = new_keys // self.n, new_keys % self.n
        return np.where(found & ~bad[lo] & ~bad[hi], pos_c, -1).astype(np.int64)


# ----------------------------------------------------------------------------
# host helpers
# ----------------------------------------------------------------------------



def _decode_keys(n: int, keys: np.ndarray) -> np.ndarray:
    if keys.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    return np.stack([keys // n, keys % n], axis=1)


def _partner_lists(uv: np.ndarray) -> dict:
    out: dict = {}
    for u, v in uv:
        out.setdefault(int(u), []).append(int(v))
        out.setdefault(int(v), []).append(int(u))
    return {v: np.asarray(ps, dtype=np.int32) for v, ps in out.items()}


def _build_adjacency(n: int, keys: np.ndarray, headroom: float,
                     min_width: int) -> Tuple[np.ndarray, np.ndarray]:
    uv = _decode_keys(n, keys)
    src = np.concatenate([uv[:, 0], uv[:, 1]])
    dst = np.concatenate([uv[:, 1], uv[:, 0]])
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    deg = np.bincount(src, minlength=n).astype(np.int32)
    d_max = int(deg.max()) if n else 0
    cap = max(min_width, int(math.ceil(max(d_max, 1) * headroom)))
    adj = np.full((n, cap), n, dtype=np.int32)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    col = np.arange(src.size) - indptr[src]
    adj[src.astype(np.int64), col] = dst.astype(np.int32)
    return deg, adj
