"""Mutable graph store for the streaming subsystem.

``DynamicGraph`` owns the same CSR + padded-adjacency representation as the
frozen :class:`repro.core.graph.Graph`, but host-side (numpy) and mutable:
adjacency rows carry *headroom* slots so a batched ``apply_delta`` usually
edits rows in place instead of reallocating. The host arrays stay the source
of truth; the serving hot path never re-uploads them. Instead a
:class:`DeviceGraphState` keeps ``deg``/``adj``/``edges`` resident on device
and ``apply_delta`` pushes only the touched rows — a jitted (donated off
CPU) scatter-update plus an edge-list splice sized by the delta — so host →
device traffic per delta is proportional to the delta, not to O(n·d_max+m).
``view()`` wraps the live device buffers in a lightweight ``Graph`` for the
engine; ``snapshot()`` is the *explicit* full host materialization, needed
only by ``save()`` / ``--verify`` style consumers, and is bit-identical to
``from_edge_array`` on the same edge set.

The vertex set [0, n) is fixed; edges arrive and depart in batches. Edge
identity is the canonical key ``lo·n + hi`` (u < v), kept as one sorted
int64 array so delta application and carry-index computation are pure
vectorized set algebra (SISA's framing: updates are set operations too).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import threading
import weakref
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph, canonical_edge_keys, graph_view
from ..engine.api import pow2_bucket
from ..obs import trace
from ..obs.metrics import MetricsRegistry


@dataclasses.dataclass(frozen=True)
class DeltaResult:
    """What one ``apply_delta`` actually changed (post-canonicalization).

    Attributes:
      inserted: int64[I, 2]  newly present edges (u < v).
      deleted:  int64[D, 2]  removed edges (u < v).
      touched:  int64[T]     sorted unique vertices with any adjacency change.
      dirty:    int64[Dv]    sorted unique vertices that *lost* a neighbor
                             (their sketches cannot be updated monotonically).
      version:  graph version after this delta.
    """

    inserted: np.ndarray
    deleted: np.ndarray
    touched: np.ndarray
    dirty: np.ndarray
    version: int

    @property
    def is_noop(self) -> bool:
        """True when the delta changed nothing (all edges already as asked)."""
        return self.inserted.size == 0 and self.deleted.size == 0

    def insert_rows(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Per-vertex new-neighbor lists, padded for batched device updates.

        Returns ``(verts int32[T], new int32[T, L])`` where row i holds the
        neighbors vertex ``verts[i]`` gained, sorted ascending, padded with
        the sentinel ``n`` — the shape incremental sketch maintenance eats.
        """
        if self.inserted.size == 0:
            return (np.zeros(0, dtype=np.int32),
                    np.zeros((0, 1), dtype=np.int32))
        src = np.concatenate([self.inserted[:, 0], self.inserted[:, 1]])
        dst = np.concatenate([self.inserted[:, 1], self.inserted[:, 0]])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        verts, start = np.unique(src, return_index=True)
        counts = np.diff(np.append(start, src.size))
        # offset scatter: within-group column = global rank - group start
        row = np.repeat(np.arange(verts.size), counts)
        col = np.arange(src.size) - np.repeat(start, counts)
        padded = np.full((verts.size, int(counts.max())), n, dtype=np.int32)
        padded[row, col] = dst
        return verts.astype(np.int32), padded


class TrafficMeter:
    """Host → device upload accounting for the streaming delta path.

    ``put()`` is the single doorway every streaming upload goes through, so
    ``bytes_delta`` (reset by ``begin_delta``) is an *exact* measure of host
    traffic per delta — the quantity the device-resident design bounds by
    the delta size. Init-time puts copy the host buffer first: ``jnp.asarray``
    can be zero-copy on CPU and the session-open uploads pass ``dyn.deg`` /
    ``dyn.adj``, which later deltas mutate in place; delta-path callers all
    pass freshly built padded buffers, so they skip the copy.

    The numbers live in a :class:`~repro.obs.metrics.MetricsRegistry`
    (``traffic_bytes{path=init|delta}``, ``traffic_bytes_last_delta``,
    ``traffic_steps``); the historical attribute names (``bytes_init`` etc.)
    and the ``stats()`` dict are views over those instruments, shape- and
    value-identical to the pre-registry meter.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = MetricsRegistry() if registry is None else registry
        self._init = self.registry.counter("traffic_bytes", path="init")
        self._total = self.registry.counter("traffic_bytes", path="delta")
        self._last = self.registry.gauge("traffic_bytes_last_delta")
        self._steps = self.registry.counter("traffic_steps")

    @property
    def bytes_init(self) -> int:
        """One-time device residency bytes (session open)."""
        return self._init.value

    @property
    def bytes_total(self) -> int:
        """Cumulative delta-path upload bytes."""
        return self._total.value

    @property
    def bytes_delta(self) -> int:
        """Upload bytes since the last ``begin_delta()``."""
        return int(self._last.value)

    @property
    def steps(self) -> int:
        """Committed delta/flush traffic steps."""
        return self._steps.value

    def begin_delta(self):
        """Reset the per-delta byte counter (called at each delta's start)."""
        self._last.set(0)

    def commit_step(self):
        """Count one real delta/flush step (no-op steps stay unmetered so
        ``bytes_per_delta_mean`` reflects deltas that did work)."""
        self._steps.inc()

    def put(self, arr: np.ndarray, init: bool = False) -> jax.Array:
        """Upload a host buffer, metering its bytes (init vs delta path)."""
        host = np.array(arr, copy=True) if init else np.ascontiguousarray(arr)
        if init:
            self._init.inc(host.nbytes)
        else:
            self._last.add(host.nbytes)
            self._total.inc(host.nbytes)
        return jnp.asarray(host)

    def stats(self) -> dict:
        """Upload accounting: init/total/last-delta bytes and step count."""
        return {
            "bytes_init": self.bytes_init,
            "bytes_total": self.bytes_total,
            "bytes_last_delta": self.bytes_delta,
            "bytes_per_delta_mean": self.bytes_total / max(self.steps, 1),
            "steps": self.steps,
        }


def _scatter_rows_impl(adj, verts, rows):
    """adj[verts] <- rows over the leading row columns; pad verts == n drop."""
    cols = jnp.arange(rows.shape[1], dtype=jnp.int32)
    return adj.at[verts[:, None], cols[None, :]].set(rows, mode="drop")


def _scatter_vals_impl(vec, verts, vals):
    return vec.at[verts].set(vals, mode="drop")


def _splice_edges_impl(edges, del_pos, ins_pos, ins_uv, m_old, n):
    """Delta-sized splice of the canonical-order device edge list.

    ``edges`` is int32[e_cap, 2]: valid edges in (lo, hi)-lex == key order at
    [0, m_old), sentinel rows (n, n) after. Deleted positions are sentineled,
    inserts land in the free slots [m_old, m_old+I), and one on-device
    lexsort restores canonical order (sentinels sort last) — zero host
    traffic beyond the delta-sized index/edge uploads. Also returns the
    position carry: ``carry[j]`` is new edge j's position in the *old* order
    (or -1 for an insert), the device-resident replacement for uploading an
    O(m) carry index into the session's cardinality-cache refresh.
    """
    e_cap = edges.shape[0]
    pos = jnp.arange(e_cap, dtype=jnp.int32)
    deleted = jnp.zeros(e_cap, jnp.bool_).at[del_pos].set(True, mode="drop")
    edges = jnp.where(deleted[:, None], jnp.int32(n), edges)
    edges = edges.at[ins_pos].set(ins_uv, mode="drop")
    order = jnp.lexsort((edges[:, 1], edges[:, 0])).astype(jnp.int32)
    new_edges = jnp.take(edges, order, axis=0)
    old_flag = (pos < m_old) & ~deleted
    carry = jnp.where(jnp.take(old_flag, order), order, jnp.int32(-1))
    return new_edges, carry


@functools.lru_cache(maxsize=None)
def _update_fns(donate: bool = True):
    """The jitted device-update kernels, donation decided at first *use*.

    Donating the old buffer gives true in-place device updates; CPU has no
    donation support and would warn on every compile. The backend query must
    not run at import time — it would initialize JAX as an import side
    effect and freeze the decision before the program configures platforms
    (same call-time pattern as ``repro.kernels.ops``).

    ``donate=False`` selects non-donating variants even off-CPU: when an
    in-flight flush still reads the published buffers, donating them to
    build the next version would invalidate arrays under it.
    :meth:`DynamicGraph.donate_ok` makes the per-delta call — a session's
    lease-aware policy when one is installed (donation re-engages whenever
    no stale view is in flight and no read lease is out), else the
    conservative any-live-snapshot veto.
    """
    argnums = (0,) if donate and jax.default_backend() != "cpu" else ()
    return tuple(jax.jit(fn, donate_argnums=argnums) for fn in
                 (_scatter_rows_impl, _scatter_vals_impl, _splice_edges_impl))


class _DeviceBuffers(NamedTuple):
    # one immutable generation of the device mirror: swapped wholesale at
    # the end of every delta so concurrent readers never see a half-applied
    # generation (deg from version N+1, edges still at N)
    deg: jax.Array
    adj: jax.Array
    edges: jax.Array
    e_cap: int
    m: int


class DeviceGraphState:
    """Persistent device mirrors of a DynamicGraph's deg/adj/edges.

    Created once per session (one full upload, metered as ``bytes_init``);
    afterwards every delta is absorbed by delta-sized scatter-updates with
    pow2-bucketed shapes, so a handful of compiled variants serve any delta
    and per-delta host traffic scales with the delta, never with n·d_max.
    Capacity growth (adjacency headroom exhausted, edge buffer full) happens
    *on device* via sentinel padding — still zero full-graph upload; the
    grown rows themselves arrive through the ordinary touched-row scatter.

    The mirror is **double-buffered**: ``deg``/``adj``/``edges`` read one
    immutable published generation, and :meth:`apply_delta` builds the next
    generation into shadow locals (jax arrays are persistent, so the shadow
    shares all unchanged device memory) before publishing it with a single
    atomic attribute swap. A reader that captured the published arrays —
    a ``view()`` graph pinned by an in-flight flush — keeps a consistent
    version-N world no matter how many deltas land meanwhile.
    """

    def __init__(self, dyn: "DynamicGraph", meter: TrafficMeter):
        self.n = dyn.n
        self.meter = meter
        e_cap = pow2_bucket(max(dyn.m, 1))
        edges = np.full((e_cap, 2), dyn.n, dtype=np.int32)
        edges[:dyn.m] = dyn.edge_array()
        self._buf = _DeviceBuffers(meter.put(dyn.deg, init=True),
                                   meter.put(dyn.adj, init=True),
                                   meter.put(edges, init=True), e_cap, dyn.m)
        self.last_carry: Optional[jax.Array] = None
        self._identity: Optional[jax.Array] = None

    @property
    def deg(self) -> jax.Array:
        """Published device degree vector int32[n]."""
        return self._buf.deg

    @property
    def adj(self) -> jax.Array:
        """Published device padded adjacency int32[n, cap]."""
        return self._buf.adj

    @property
    def edges(self) -> jax.Array:
        """Published device edge list int32[e_cap, 2] (sentinel-padded)."""
        return self._buf.edges

    @property
    def e_cap(self) -> int:
        """Published edge-buffer capacity."""
        return self._buf.e_cap

    @property
    def m(self) -> int:
        """Edge count of the published generation."""
        return self._buf.m

    def identity_carry(self) -> jax.Array:
        """Position carry of a no-splice step (flush-triggered rebuilds)."""
        if self._identity is None or self._identity.shape[0] != self.e_cap:
            self._identity = jnp.arange(self.e_cap, dtype=jnp.int32)
        return self._identity

    def apply_delta(self, dyn: "DynamicGraph", delta: "DeltaResult",
                    del_pos: np.ndarray, old_deg_touched: np.ndarray,
                    m_old: int) -> None:
        """Mirror one already-applied host delta with delta-sized uploads."""
        with trace.span("graph.device_delta", touched=int(delta.touched.size),
                        inserted=int(delta.inserted.shape[0]),
                        deleted=int(delta.deleted.shape[0])) as dsp:
            self._apply_delta(dyn, delta, del_pos, old_deg_touched, m_old)
            dsp.fence((self.adj, self.deg, self.edges))

    def _apply_delta(self, dyn: "DynamicGraph", delta: "DeltaResult",
                     del_pos: np.ndarray, old_deg_touched: np.ndarray,
                     m_old: int) -> None:
        """The untraced body of :meth:`apply_delta` — shadow build + swap."""
        # donation consumes the input buffer, which is exactly the published
        # generation an in-flight reader may still be using: only donate
        # when the graph's donation policy proves nothing does (CPU never
        # donates)
        _scatter_rows, _scatter_vals, _splice_edges = \
            _update_fns(dyn.donate_ok())
        n = self.n
        deg, adj, edges, e_cap = (self._buf.deg, self._buf.adj,
                                  self._buf.edges, self._buf.e_cap)
        cap = dyn.capacity
        if adj.shape[1] < cap:               # headroom growth, device-side
            adj = jnp.pad(adj, ((0, 0), (0, cap - adj.shape[1])),
                          constant_values=n)
        touched = delta.touched
        if touched.size:
            # per-row width covers the row before AND after the delta so
            # untouched columns are sentinel on both sides of the scatter;
            # rows are partitioned by pow2 width bucket so one hub does not
            # inflate every row's upload to its width (≤ log(cap) scatters,
            # each a reused compiled variant)
            with trace.span("graph.scatter_rows", rows=int(touched.size)):
                wv = np.maximum(np.maximum(old_deg_touched,
                                           dyn.deg[touched]), 1)
                wb = np.minimum(2 ** np.ceil(np.log2(wv)).astype(np.int64)
                                .clip(min=0), cap)
                for width in np.unique(wb):
                    grp = touched[wb == width]
                    w_b = int(width)
                    t_b = pow2_bucket(grp.size)
                    verts = np.full(t_b, n, dtype=np.int32)
                    verts[:grp.size] = grp
                    rows = np.full((t_b, w_b), n, dtype=np.int32)
                    rows[:grp.size] = dyn.adj[grp, :w_b]
                    adj = _scatter_rows(adj, self.meter.put(verts),
                                        self.meter.put(rows))
                # degrees are width-independent: one scatter over all touched
                t_b = pow2_bucket(touched.size)
                verts = np.full(t_b, n, dtype=np.int32)
                verts[:touched.size] = touched
                degs = np.zeros(t_b, dtype=np.int32)
                degs[:touched.size] = dyn.deg[touched]
                deg = _scatter_vals(deg, self.meter.put(verts),
                                    self.meter.put(degs))

        n_ins = int(delta.inserted.shape[0])
        with trace.span("graph.splice_edges", inserts=n_ins,
                        deletes=int(del_pos.size)):
            if e_cap < m_old + n_ins:        # edge buffer growth, device-side
                new_cap = pow2_bucket(m_old + n_ins)
                edges = jnp.pad(edges, ((0, new_cap - e_cap), (0, 0)),
                                constant_values=n)
                e_cap = new_cap
            i_b, d_b = pow2_bucket(n_ins), pow2_bucket(del_pos.size)
            dpos = np.full(d_b, e_cap, dtype=np.int32)       # sentinel: drop
            dpos[:del_pos.size] = del_pos
            ipos = np.full(i_b, e_cap, dtype=np.int32)
            ipos[:n_ins] = m_old + np.arange(n_ins)
            iuv = np.full((i_b, 2), n, dtype=np.int32)
            iuv[:n_ins] = delta.inserted
            edges, self.last_carry = _splice_edges(
                edges, self.meter.put(dpos), self.meter.put(ipos),
                self.meter.put(iuv), m_old, n)
        # publication: one atomic swap — no reader ever observes a mix of
        # generations
        self._buf = _DeviceBuffers(deg, adj, edges, e_cap, dyn.m)


class HostGraphSnapshot:
    """Frozen host-side view of a :class:`DynamicGraph` at one version.

    ``deg``/``edge_keys`` are captured by reference — deltas rebind those
    arrays on the graph, so the captured ones never change again. The padded
    adjacency *is* mutated in place (that is the point of the headroom), so
    the snapshot keeps a copy-on-write row overlay: just before a delta
    overwrites a row the graph pushes the pre-delta bytes into every live
    snapshot's overlay (:meth:`DynamicGraph._shield_snapshots`), a cost
    sized by the delta and the number of live snapshots, never by n. On
    capacity growth the adjacency is rebound instead, which freezes the old
    array for free — the identity check in :meth:`_save_rows_locked`
    notices.

    Snapshots are read concurrently with delta application (that is the
    whole point), so shield+overwrite on the delta thread and the
    overlay-miss → live-row read in :meth:`neighbors` synchronize on the
    graph's shared ``_row_lock``; see :meth:`neighbors` for the protocol.
    """

    # machine-checked lock discipline (tools/pgcheck PG001): the overlay is
    # shared between the delta thread (shield) and snapshot readers (miss
    # path) — both sides hold the graph's row lock. The one intentional
    # unlocked probe in `neighbors` carries its own suppression.
    _GUARDED_BY = {
        "_overlay": "_lock",
    }

    __slots__ = ("n", "m", "version", "deg", "edge_keys", "_adj", "_overlay",
                 "_lock", "__weakref__")

    def __init__(self, dyn: "DynamicGraph"):
        self.n = dyn.n
        self.m = dyn.m
        self.version = dyn.version
        self.deg = dyn.deg
        self.edge_keys = dyn.edge_keys
        self._adj = dyn.adj
        self._overlay = {}
        self._lock = dyn._row_lock

    def _save_rows_locked(self, adj: np.ndarray,
                          touched: np.ndarray) -> None:
        # first save wins: the overlay must hold the row as of snapshot
        # creation, and a vertex touched twice was already saved pre-first-
        # mutation (rows untouched since creation are read live — identical)
        # caller (_shield_snapshots) holds the shared row lock
        if self._adj is not adj:
            return                        # adjacency was rebound: frozen
        overlay = self._overlay
        for v in touched:
            iv = int(v)
            if iv not in overlay:
                overlay[iv] = np.array(adj[iv], copy=True)

    def neighbors(self, v: int) -> np.ndarray:
        """Sorted neighbor ids of ``v`` at the snapshot's version.

        Safe against a delta landing concurrently: the delta thread holds
        the graph's row lock across "save pre-delta rows into overlays,
        then overwrite" (:meth:`DynamicGraph._apply_delta`), so under the
        same lock either the overlay already has the pre-delta row or the
        live row still *is* the pre-delta row — and the live-row path
        returns a copy taken inside the lock, so the result cannot change
        between return and consumption. Overlay rows are private frozen
        copies; slicing them needs no copy. The unlocked first probe is
        sound: a hit is immutable, and a miss is re-checked under the lock.
        """
        iv = int(v)
        # double-checked locking: a hit is an immutable private row, and a
        # miss is re-probed under the lock just below
        row = self._overlay.get(iv)  # pgcheck: disable=PG001
        if row is None:
            with self._lock:
                row = self._overlay.get(iv)
                if row is None:
                    return self._adj[iv, :self.deg[iv]].copy()
        return row[:self.deg[iv]]


class DynamicGraph:
    """Mutable undirected graph on a fixed vertex set with batched deltas."""

    # machine-checked lock discipline (tools/pgcheck PG001): the delta
    # thread's shield-then-overwrite of `adj`/`deg` must be one critical
    # section with snapshot row reads (`write:` — host reads are the common
    # case and synchronize through snapshot capture, not the lock).
    _GUARDED_BY = {
        "adj": "write:_row_lock",
        "deg": "write:_row_lock",
    }

    def __init__(self, n: int, edge_keys: np.ndarray, deg: np.ndarray,
                 adj: np.ndarray, headroom: float = 1.5, version: int = 0):
        self.n = int(n)
        self.edge_keys = edge_keys        # sorted int64[m], key = lo*n + hi
        self.deg = deg                    # int32[n]
        self.adj = adj                    # int32[n, cap]; rows sorted, pad = n
        self.headroom = float(headroom)
        self.version = int(version)
        self.traffic = TrafficMeter()
        self._device: Optional[DeviceGraphState] = None
        self._snapshots: "weakref.WeakSet[HostGraphSnapshot]" = \
            weakref.WeakSet()
        # shared with every HostGraphSnapshot: serializes the delta thread's
        # shield-then-overwrite against concurrent snapshot row reads
        self._row_lock = threading.Lock()
        # a StreamSession installs its lease-aware donation policy here;
        # a bare DynamicGraph falls back to "any live snapshot vetoes"
        self._donation_guard = None

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_edges(cls, n: int, edges, headroom: float = 1.5,
                   min_width: int = 4) -> "DynamicGraph":
        """Build from a raw edge array (duplicates/self-loops dropped)."""
        keys = canonical_edge_keys(n, edges)
        deg, adj = _build_adjacency(n, keys, headroom, min_width)
        return cls(n, keys, deg, adj, headroom)

    @classmethod
    def from_graph(cls, graph: Graph, headroom: float = 1.5) -> "DynamicGraph":
        """Build from a frozen :class:`~repro.core.graph.Graph`."""
        return cls.from_edges(graph.n, np.asarray(graph.edges),
                              headroom=headroom)

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    @property
    def m(self) -> int:
        """Current number of (canonical, undirected) edges."""
        return int(self.edge_keys.shape[0])

    @property
    def capacity(self) -> int:
        """Adjacency row width (degree headroom included)."""
        return int(self.adj.shape[1])

    def neighbors(self, v: int) -> np.ndarray:
        """Sorted neighbor ids of ``v`` (host view, no padding)."""
        return self.adj[v, :self.deg[v]]

    def edge_array(self) -> np.ndarray:
        """int64[m, 2] canonical (u < v) edges in key order."""
        return _decode_keys(self.n, self.edge_keys)

    @property
    def pinned(self) -> bool:
        """True while any live :class:`HostGraphSnapshot` pins published
        state (device buffer donation must then be off — see
        ``_update_fns``)."""
        return len(self._snapshots) > 0

    def snapshots(self) -> Tuple[HostGraphSnapshot, ...]:
        """The currently live (weakly tracked) host snapshots."""
        return tuple(self._snapshots)

    def donate_ok(self) -> bool:
        """May the next device update donate the published buffers?

        A :class:`~repro.stream.session.StreamSession` installs a guard
        that tracks serving read-leases and stale views, so donation
        re-engages whenever only the session's own published view is
        alive and nobody is reading it. Without a guard, any live host
        snapshot vetoes donation (the conservative standalone default).
        """
        if self._donation_guard is not None:
            return bool(self._donation_guard())
        return not self.pinned

    def host_snapshot(self) -> HostGraphSnapshot:
        """Capture a frozen host view of the current version.

        The snapshot stays valid (and delta-sized cheap) across any number
        of later deltas; it is tracked by weak reference, so dropping it
        releases its overlay and its donation pin automatically.
        """
        snap = HostGraphSnapshot(self)
        self._snapshots.add(snap)
        return snap

    def _shield_snapshots(self, touched: np.ndarray) -> None:
        """Copy the about-to-be-overwritten adjacency rows into every live
        snapshot's overlay (called by ``_apply_delta`` pre-mutation)."""
        if self._snapshots:
            for snap in tuple(self._snapshots):
                snap._save_rows_locked(self.adj, touched)

    @property
    def device(self) -> DeviceGraphState:
        """The device-resident mirror, created (one full upload) on first use
        and kept current by every subsequent ``apply_delta``."""
        if self._device is None:
            self._device = DeviceGraphState(self, self.traffic)
        return self._device

    def view(self) -> Graph:
        """Lightweight ``Graph`` over the live device buffers — the streaming
        hot path's graph, built with zero host → device traffic.

        Value-identical to ``snapshot()`` everywhere an algorithm reads it
        (same deg/edges/CSR contents; the padded adjacency only carries extra
        sentinel columns, which every consumer ignores); the next
        ``apply_delta`` supersedes it, so sessions must repoint at a fresh
        view per delta (``StreamSession`` does).
        """
        buf = self.device._buf             # one read: a concurrent publish
        return graph_view(self.n, buf.m, buf.deg, buf.adj,
                          buf.edges[:buf.m])   # must not mix generations

    def snapshot(self) -> Graph:
        """Explicit full host materialization: a device ``Graph`` that is
        bit-identical (arrays and static fields) to
        ``from_edge_array(n, self.edge_array())``. The streaming hot path
        never calls this — only ``save()``/``--verify``-style consumers do;
        serving reads ``view()`` instead.

        Every numpy buffer handed to jax is a fresh copy: ``jnp.asarray`` of
        a host array can be zero-copy on CPU, and ``self.adj``/``self.deg``
        are mutated in place by later deltas — an aliased device view would
        change under any still-in-flight async computation.
        """
        n = self.n
        d_max = max(int(self.deg.max()) if n else 0, 1)
        mask = np.arange(self.capacity)[None, :] < self.deg[:, None]
        indices = self.adj[mask].astype(np.int32)      # row-major == CSR order
        indptr = np.zeros(n + 1, dtype=np.int32)
        np.cumsum(self.deg, out=indptr[1:])
        adj = self.adj[:, :d_max] if self.capacity >= d_max else np.pad(
            self.adj, ((0, 0), (0, d_max - self.capacity)), constant_values=n)
        return Graph(
            indptr=jnp.asarray(indptr),
            indices=jnp.asarray(indices),
            adj=jnp.asarray(np.array(adj, copy=True)),
            deg=jnp.asarray(self.deg.copy()),
            edges=jnp.asarray(self.edge_array().astype(np.int32)),
            n_vertices=n, n_edges=self.m, d_max=d_max)

    # ------------------------------------------------------------------
    # mutation
    # ------------------------------------------------------------------

    def apply_delta(self, inserts=None, deletes=None) -> DeltaResult:
        """Apply one batch of edge insertions and deletions.

        Both arguments are (possibly duplicated / both-direction / already
        present or absent) edge arrays; the applied delta is canonicalized:
        deletes that miss and inserts that already exist are dropped.
        Deletes are applied before inserts, so an edge listed in both ends
        up present (and both endpoints count as dirty).
        """
        with trace.span("graph.apply_delta") as sp:
            delta = self._apply_delta(inserts, deletes)
            sp.set(inserted=int(delta.inserted.shape[0]),
                   deleted=int(delta.deleted.shape[0]),
                   touched=int(delta.touched.size), version=delta.version)
            return delta

    def _apply_delta(self, inserts, deletes) -> DeltaResult:
        """The untraced body of :meth:`apply_delta`."""
        n = self.n
        cur = self.edge_keys
        del_req = canonical_edge_keys(n, deletes)
        del_applied = del_req[np.isin(del_req, cur, assume_unique=True)]
        kept = (cur[~np.isin(cur, del_applied, assume_unique=True)]
                if del_applied.size else cur)
        ins_req = canonical_edge_keys(n, inserts)
        ins_applied = (ins_req[~np.isin(ins_req, kept, assume_unique=True)]
                       if ins_req.size else ins_req)

        ins_uv = _decode_keys(n, ins_applied)
        del_uv = _decode_keys(n, del_applied)
        self.version += 1
        if ins_applied.size == 0 and del_applied.size == 0:
            return DeltaResult(ins_uv, del_uv, np.zeros(0, np.int64),
                               np.zeros(0, np.int64), self.version)

        # positions of the deleted edges in the *old* canonical order — the
        # device edge-splice scatters these before the host order changes
        del_pos = np.searchsorted(cur, del_applied).astype(np.int64)
        m_old = int(cur.shape[0])
        self.edge_keys = np.union1d(kept, ins_applied)
        touched = np.unique(np.concatenate([ins_uv.ravel(), del_uv.ravel()]))
        dirty = np.unique(del_uv.ravel())
        old_deg_touched = self.deg[touched].copy()

        new_deg = self.deg.astype(np.int64)
        if ins_uv.size:
            new_deg += np.bincount(ins_uv.ravel(), minlength=n)
        if del_uv.size:
            new_deg -= np.bincount(del_uv.ravel(), minlength=n)
        need = int(new_deg.max())
        grown = None
        if need > self.capacity:
            # grow with headroom so a run of inserts amortizes reallocation;
            # built here, but rebound onto self.adj only inside the row
            # lock below — the rebind is a write to published state
            cap = max(need, int(math.ceil(need * self.headroom)))
            grown = np.full((n, cap), n, dtype=np.int32)
            grown[:, :self.capacity] = self.adj
        new_cap = grown.shape[1] if grown is not None else self.capacity

        # vectorized touched-row rewrite (np.unique/offset-scatter, the
        # DeltaResult.insert_rows technique — no per-vertex Python loop):
        # collect the touched rows' surviving half-edges plus the inserted
        # ones, lexsort by (src, dst), and scatter each group back into its
        # row at within-group rank. Bit-identical to the old per-row
        # delete/concat/sort because both produce ascending neighbor lists
        # padded with the sentinel n.
        old_counts = old_deg_touched.astype(np.int64)
        mask = np.arange(self.capacity)[None, :] < old_counts[:, None]
        src = np.repeat(touched, old_counts)
        dst = self.adj[touched][mask].astype(np.int64)
        if del_uv.size:
            del_keys = np.concatenate([del_uv[:, 0] * n + del_uv[:, 1],
                                       del_uv[:, 1] * n + del_uv[:, 0]])
            keep = ~np.isin(src * n + dst, del_keys)
            src, dst = src[keep], dst[keep]
        if ins_uv.size:
            src = np.concatenate([src, ins_uv[:, 0], ins_uv[:, 1]])
            dst = np.concatenate([dst, ins_uv[:, 1], ins_uv[:, 0]])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        rows_new = np.full((touched.size, new_cap), n, dtype=np.int32)
        if src.size:
            verts, start = np.unique(src, return_index=True)
            counts = np.diff(np.append(start, src.size))
            row = np.repeat(np.searchsorted(touched, verts), counts)
            col = np.arange(src.size) - np.repeat(start, counts)
            rows_new[row, col] = dst
        # rebind + shield + overwrite are one critical section: a snapshot
        # reader that misses the overlay and falls through to the live row
        # must never observe the row post-overwrite
        # (HostGraphSnapshot.neighbors takes the same lock). The rebind
        # happens first so shielding sees the new array and skips copies —
        # the old array is frozen by the rebind, exactly what snapshots
        # captured (`_save_rows_locked`'s identity check).
        with self._row_lock:
            if grown is not None:
                self.adj = grown
            self._shield_snapshots(touched)
            self.adj[touched] = rows_new
            self.deg = new_deg.astype(np.int32)
        delta = DeltaResult(ins_uv, del_uv, touched, dirty, self.version)
        if self._device is not None:
            self._device.apply_delta(self, delta, del_pos, old_deg_touched,
                                     m_old)
        return delta

    def carry_index(self, old_keys: np.ndarray,
                    invalid_vertices: np.ndarray) -> Optional[np.ndarray]:
        """Map current edges to their row in a previous edge order.

        Returns int64[m] where entry j is the position of edge j in
        ``old_keys`` (a previous sorted ``edge_keys``) when neither endpoint
        is in ``invalid_vertices``, else -1 — exactly the
        ``MiningSession.refresh`` carry contract.
        """
        new_keys = self.edge_keys
        if self.n == 0 or new_keys.size == 0:
            return np.zeros(0, dtype=np.int64)
        if old_keys.size == 0:
            return np.full(new_keys.shape[0], -1, dtype=np.int64)
        pos = np.searchsorted(old_keys, new_keys)
        pos_c = np.minimum(pos, old_keys.size - 1)
        found = old_keys[pos_c] == new_keys
        bad = np.zeros(self.n, dtype=bool)
        bad[np.asarray(invalid_vertices, dtype=np.int64)] = True
        lo, hi = new_keys // self.n, new_keys % self.n
        return np.where(found & ~bad[lo] & ~bad[hi], pos_c, -1).astype(np.int64)


# ----------------------------------------------------------------------------
# host helpers
# ----------------------------------------------------------------------------



def _decode_keys(n: int, keys: np.ndarray) -> np.ndarray:
    if keys.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    return np.stack([keys // n, keys % n], axis=1)


def _build_adjacency(n: int, keys: np.ndarray, headroom: float,
                     min_width: int) -> Tuple[np.ndarray, np.ndarray]:
    uv = _decode_keys(n, keys)
    src = np.concatenate([uv[:, 0], uv[:, 1]])
    dst = np.concatenate([uv[:, 1], uv[:, 0]])
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    deg = np.bincount(src, minlength=n).astype(np.int32)
    d_max = int(deg.max()) if n else 0
    cap = max(min_width, int(math.ceil(max(d_max, 1) * headroom)))
    adj = np.full((n, cap), n, dtype=np.int32)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(deg, out=indptr[1:])
    col = np.arange(src.size) - indptr[src]
    adj[src.astype(np.int64), col] = dst.astype(np.int32)
    return deg, adj
