"""Versioned serving-tier result cache with delta-precise invalidation.

Real serving traffic is heavily repeated and skewed, so the biggest win
after device-resident deltas is not recomputing answers whose inputs did
not change. ProbGraph's fixed-size sketch rows make that *precise*: every
answer carries an :class:`repro.engine.Footprint` — the exact vertex set
whose adjacency/degree/sketch rows it was computed from — and
``StreamSession.apply_delta`` publishes each delta's ``touched ∪ rebuilt``
vertex set, so the cache evicts exactly the entries whose footprint
intersects the delta. Everything else is served straight from cache,
bit-identical (under the strict error-budget policy) to a recomputation on
the live graph.

Two provenance guards keep entries honest beyond the footprint:

* **whole-graph answers** (triangle counts fold every edge) are evicted on
  *any* real delta or maintenance rebuild;
* **local-cluster answers** additionally depend on the total volume
  ``2m`` through the sweep's ``min(vol, vol_total − vol)`` denominator.
  Entries record the largest swept prefix volume; a hit is served only
  while ``min`` provably resolved to the prefix volume at both cache and
  serve time (``max2vol ≤ min(vol_total_then, vol_total_now)``, with a
  small slack against float32 cumsum rounding). Oversized clusters —
  more than half the graph's volume — are simply not cached.

The cache is LRU-bounded; all counters are exposed by :meth:`stats` so
benchmarks and tests can assert that invalidation evicts only
footprint-intersecting entries.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Dict, Optional, Set, Tuple

import numpy as np

from ..engine.api import Footprint
from ..obs import trace

# how many recent invalidations the stale-put guard remembers; a put whose
# epoch predates the oldest remembered invalidation cannot be proven fresh
# and is conservatively rejected — 256 publications of slack is far beyond
# any real flush-vs-delta race window
_INVAL_LOG_LEN = 256

# slack (in volume units = 2·edges) for the local-cluster volume guard: the
# sweep's cumsum runs in float32, so a prefix within one edge of half the
# total volume cannot be proven to resolve min(vol, rest) identically
_VOL_GUARD_SLACK = 4.0


@dataclasses.dataclass
class CacheEntry:
    """One cached answer plus the provenance that keeps it honest.

    Attributes:
      key:       the canonical ``(kind, args…)`` request key.
      value:     the answer exactly as the server would have computed it
                 (arrays are frozen read-only before insertion).
      footprint: the vertex dependency set (``Footprint.whole_graph()`` for
                 answers no delta can survive).
      version:   graph version the answer was computed at (observability
                 only — validity is maintained eagerly by eviction).
      max2vol:   local-cluster only: twice the largest swept prefix volume.
      vol_total: local-cluster only: the total volume ``2m`` at cache time.
    """

    key: Tuple
    value: object
    footprint: Footprint
    version: int
    max2vol: Optional[float] = None
    vol_total: Optional[float] = None

    def vol_safe(self, vol_total_now: Optional[float]) -> bool:
        """Is the entry's volume guard satisfied at serve time?"""
        if self.max2vol is None:
            return True
        if vol_total_now is None or self.vol_total is None:
            return False
        return (self.max2vol + _VOL_GUARD_SLACK
                <= min(self.vol_total, vol_total_now))


class ResultCache:
    """LRU result cache keyed by canonical request, evicted by footprint.

    ``get``/``put`` are the serving hot path; ``invalidate`` is the delta
    listener fed by ``StreamSession`` with each delta's ``touched ∪
    rebuilt`` vertex set. An inverted vertex → keys index makes
    invalidation cost proportional to the delta and the entries it actually
    kills, never to the cache size.

    With async serving, flushes and the delta thread hit the cache
    concurrently, so every operation holds one re-entrant lock, and
    ``put`` carries a **stale-put guard**: a flush snapshot-isolated at
    epoch E may finish computing *after* a later delta already invalidated
    the vertices its answer depends on — inserting then would resurrect a
    dead entry. ``invalidate`` logs ``(epoch, vertices)`` for the last
    :data:`_INVAL_LOG_LEN` publications; ``put(..., epoch=E)`` is rejected
    (counted in ``rejected_stale``) when any logged invalidation newer than
    E intersects the entry's footprint, when the entry is whole-graph with
    any newer invalidation at all, or when E predates the log. *Hits* need
    no such guard: an entry that survived every invalidation up to the
    reader's snapshot epoch was, by the eviction invariant, valid at that
    epoch.
    """

    # machine-checked lock discipline (tools/pgcheck PG001): every piece of
    # cache state — entry map, inverted index, whole-graph set, stale-put
    # log — moves only under the one re-entrant lock. Internals that rely
    # on the caller's lock carry the `_locked` suffix instead.
    _GUARDED_BY = {
        "_entries": "_lock",
        "_by_vertex": "_lock",
        "_whole": "_lock",
        "_inval_log": "_lock",
        "_inval_floor": "_lock",
    }

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._lock = threading.RLock()
        self._entries: "collections.OrderedDict[Tuple, CacheEntry]" = \
            collections.OrderedDict()
        self._by_vertex: Dict[int, Set[Tuple]] = {}
        self._whole: Set[Tuple] = set()
        # stale-put guard state: recent (epoch, vertex-set) invalidations
        # plus the epoch floor below which the log no longer proves anything
        self._inval_log: "collections.deque[Tuple[int, Set[int]]]" = \
            collections.deque()
        self._inval_floor: Optional[int] = None
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.evicted_footprint = 0      # precise: footprint ∩ delta ≠ ∅
        self.evicted_whole = 0          # whole-graph entries, any real delta
        self.evicted_capacity = 0       # LRU pressure
        self.evicted_guard = 0          # local-cluster volume guard failed
        self.rejected_stale = 0         # put raced a newer invalidation

    def __len__(self) -> int:
        """Number of live entries."""
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: Tuple) -> bool:
        """Is ``key`` currently cached? (No hit/miss accounting.)"""
        with self._lock:
            return key in self._entries

    # ------------------------------------------------------------------
    # hot path
    # ------------------------------------------------------------------

    def get(self, key: Tuple, vol_total_now: Optional[float] = None
            ) -> Optional[CacheEntry]:
        """Look up ``key``; returns the entry on a provable hit, else None.

        ``vol_total_now`` (the live graph's ``2m``) must be passed for
        local-cluster keys so the volume guard can be checked; a guard
        failure drops the entry (it cannot be proven fresh).
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and not entry.vol_safe(vol_total_now):
                self._remove_locked(key)
                self.evicted_guard += 1
                entry = None
            if entry is None:
                self.misses += 1
                return None
            self.hits += 1
            self._entries.move_to_end(key)
            return entry

    @staticmethod
    def cacheable(max2vol: float, vol_total: float) -> bool:
        """Can a local-cluster answer with this swept volume be cached at
        all? The admission twin of :meth:`CacheEntry.vol_safe` — both sides
        of the volume guard live here so they cannot drift apart."""
        return max2vol + _VOL_GUARD_SLACK <= vol_total

    def _put_is_stale_locked(self, footprint: Footprint,
                      epoch: Optional[int]) -> bool:
        """Did any invalidation newer than the put's epoch kill this entry
        before it could be inserted? (Caller holds the lock.)"""
        if epoch is None or not self._inval_log:
            return False                 # no provenance / nothing newer
        if self._inval_floor is not None and epoch < self._inval_floor:
            return True                  # predates the log: unprovable
        for ep, verts in self._inval_log:
            if ep <= epoch:
                continue
            if footprint.is_whole_graph:
                return True              # any real change kills whole-graph
            if footprint.intersects(np.fromiter(verts, np.int64,
                                                count=len(verts))):
                return True
        return False

    def put(self, key: Tuple, value: object, footprint: Footprint,
            version: int, max2vol: Optional[float] = None,
            vol_total: Optional[float] = None,
            epoch: Optional[int] = None) -> None:
        """Insert (or replace) an entry and index its footprint.

        ``epoch`` is the publication epoch of the serving view the answer
        was computed from; the stale-put guard drops the insert when a
        newer logged invalidation already covered it (see the class
        docstring). ``epoch=None`` skips the guard (single-threaded
        callers).
        """
        with self._lock:
            if self._put_is_stale_locked(footprint, epoch):
                self.rejected_stale += 1
                return
            if key in self._entries:
                self._remove_locked(key)
            while len(self._entries) >= self.capacity:
                # unindex BEFORE dropping the entry: _unindex_locked reads
                # entry's footprint, so popitem-first would leak the dead
                # key in every _by_vertex bucket (over-eviction + inflated
                # counters)
                self._remove_locked(next(iter(self._entries)))
                self.evicted_capacity += 1
            entry = CacheEntry(key, value, footprint, version,
                               max2vol=max2vol, vol_total=vol_total)
            self._entries[key] = entry
            if footprint.is_whole_graph:
                self._whole.add(key)
            else:
                for v in footprint.vertices:
                    self._by_vertex.setdefault(int(v), set()).add(key)
            self.inserts += 1

    # ------------------------------------------------------------------
    # invalidation feed
    # ------------------------------------------------------------------

    def invalidate(self, vertices, epoch: Optional[int] = None) -> int:
        """Evict exactly the entries invalidated by a delta/rebuild.

        ``vertices`` is the delta's ``touched ∪ rebuilt`` vertex set; every
        entry whose footprint intersects it is evicted, plus every
        whole-graph entry. ``epoch`` (the change's publication epoch) feeds
        the stale-put guard log. Returns the number of evictions.
        """
        vertices = np.asarray(vertices).reshape(-1)
        if vertices.size == 0:
            return 0
        with trace.span("cache.invalidate",
                        vertices=int(vertices.size)) as sp, self._lock:
            if epoch is not None:
                self._inval_log.append(
                    (int(epoch), set(int(v) for v in vertices)))
                while len(self._inval_log) > _INVAL_LOG_LEN:
                    self._inval_floor = self._inval_log.popleft()[0]
            doomed: Set[Tuple] = set()
            for v in vertices:
                doomed |= self._by_vertex.get(int(v), set())
            n_fp = len(doomed)
            whole = set(self._whole)
            for key in doomed:
                self._remove_locked(key)
            for key in whole:
                self._remove_locked(key)
            self.evicted_footprint += n_fp
            self.evicted_whole += len(whole)
            sp.set(evicted_footprint=n_fp, evicted_whole=len(whole))
        return n_fp + len(whole)

    def clear(self) -> None:
        """Drop every entry (counters are kept)."""
        with self._lock:
            self._entries.clear()
            self._by_vertex.clear()
            self._whole.clear()

    # ------------------------------------------------------------------
    # internals / stats
    # ------------------------------------------------------------------

    def _unindex_locked(self, key: Tuple) -> None:
        entry = self._entries.get(key)
        self._whole.discard(key)
        if entry is None or entry.footprint.vertices is None:
            return
        for v in entry.footprint.vertices:
            bucket = self._by_vertex.get(int(v))
            if bucket is not None:
                bucket.discard(key)
                if not bucket:
                    del self._by_vertex[int(v)]

    def _remove_locked(self, key: Tuple) -> None:
        self._unindex_locked(key)
        self._entries.pop(key, None)

    def stats(self) -> dict:
        """Counters: hit rate, entries, and the eviction breakdown."""
        with self._lock:
            lookups = self.hits + self.misses
            return {
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
                "hit_rate": self.hits / lookups if lookups else 0.0,
                "inserts": self.inserts,
                "evicted_footprint": self.evicted_footprint,
                "evicted_whole": self.evicted_whole,
                "evicted_capacity": self.evicted_capacity,
                "evicted_guard": self.evicted_guard,
                "rejected_stale": self.rejected_stale,
            }
