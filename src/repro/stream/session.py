"""Streaming mining session: DynamicGraph + sketch maintenance + engine.

A :class:`StreamSession` is the long-lived counterpart of the batch
``engine.MiningSession``: it owns a mutable :class:`DynamicGraph`, keeps one
sketch current through :class:`SketchMaintainer`, and holds a MiningSession
whose per-edge cardinality cache is *delta-aware* — after ``apply_delta``
only cardinalities of edges incident to touched (or policy-rebuilt) vertices
are recomputed; everything else is carried over by index. Under the strict
(default) error-budget policy every answer is bit-identical to a
from-scratch ``engine.session`` on the equivalent static graph.

The delta path is *device-resident*: the session serves queries from
``DynamicGraph.view()`` (a Graph over persistent device buffers) and every
per-delta upload — touched adjacency rows, edge-list splice, sketch-row
merges, recompute positions — is sized by the delta, never by the graph
(``stats()["traffic"]`` reports the exact bytes). A full host
materialization (``snapshot()``) happens only on ``save()`` or explicit
verification.

Snapshot/restore goes through ``repro.checkpoint.store`` (atomic publish,
bounded retention), so a serving process can resume mid-stream.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import store
from ..core.sketches import SketchSet, bloom_membership
from ..engine.api import (DeviceCarry, EnginePlan, MiningSession,
                          pow2_bucket, resolve_plan)
from ..obs import accuracy, trace
from .dynamic_graph import DynamicGraph, HostGraphSnapshot
from .maintenance import ErrorBudgetPolicy, SketchMaintainer


@dataclasses.dataclass(frozen=True)
class ServingView:
    """One published, snapshot-isolated serving generation.

    Everything a flush needs to answer queries at a single consistent
    version: the engine session (graph view + sketch + per-edge cardinality
    cache, all rebound-only state), the sketch, and a host graph snapshot
    for the few host-side reads (link-prediction candidates, local-cluster
    volume accounting). ``apply_delta`` builds the *next* view off to the
    side and publishes it with one atomic attribute swap, so an in-flight
    flush that captured this view keeps serving version N bit-identically
    while version N+1 lands.

    ``epoch`` is the publication sequence number — unlike ``version`` it
    also advances on maintenance rebuilds (which change sketch rows without
    an edge delta), which is what the result cache's stale-put guard keys
    on.
    """

    version: int
    epoch: int
    session: MiningSession
    sketch: Optional[SketchSet]
    host: HostGraphSnapshot

    def membership(self, u: int, candidates) -> jax.Array:
        """Membership tests at this view's version (BF answers from the
        captured sketch row; other kinds answer exactly from the host
        snapshot) — the snapshot twin of ``StreamSession.membership``."""
        sk = self.sketch
        cand = jnp.asarray(np.asarray(candidates, dtype=np.int32))
        if sk is not None and sk.kind == "bf":
            return bloom_membership(sk.data[u], cand, self.host.n,
                                    sk.num_hashes, sk.total_bits, sk.seed)
        return jnp.asarray(np.isin(np.asarray(candidates),
                                   self.host.neighbors(u)))


class StreamSession:
    """Interleaved mutation + query serving over one maintained sketch."""

    # machine-checked lock discipline (tools/pgcheck PG001). `write:` specs
    # are the snapshot-isolation contract: `_serving`, `session` and
    # `version` are atomic published references — readers never lock, and
    # only mutators (all of which hold `_mutate_lock`) may swap them. The
    # lease/donation pair lives entirely under `_view_cond`.
    _GUARDED_BY = {
        "_serving": "write:_mutate_lock",
        "session": "write:_mutate_lock",
        "version": "write:_mutate_lock",
        "_delta_listeners": "_mutate_lock",
        "_read_leases": "_view_cond",
        "_donating": "_view_cond",
    }

    def __init__(self, dyn: DynamicGraph, kind: Optional[str] = "bf",
                 storage_budget: float = 0.25, num_hashes: int = 2,
                 seed: int = 0, words: Optional[int] = None,
                 k: Optional[int] = None,
                 policy: Optional[ErrorBudgetPolicy] = None,
                 plan: Optional[EnginePlan] = None,
                 sketch_data=None, **plan_kw):
        self.dyn = dyn
        graph = dyn.view()                 # device-resident; no host snapshot
        # the mirror exists now, so the initial sketch build reads the
        # device adjacency directly instead of uploading it a second time
        self.maintainer = None if kind is None else SketchMaintainer(
            dyn, kind, storage_budget=storage_budget, num_hashes=num_hashes,
            seed=seed, words=words, k=k, policy=policy, data=sketch_data)
        sketch = self.maintainer.sketch if self.maintainer else None
        self.session = MiningSession(
            graph, sketch, resolve_plan(plan, graph, sketch, plan_kw))
        self.version = 0
        self.cards_recomputed = 0
        self.cards_carried = 0
        self.extra = {}            # restore() fills this from the checkpoint
        self._delta_listeners = []  # serving-tier invalidation subscribers
        # the session's metric home: the traffic meter's registry, so one
        # snapshot carries upload accounting plus anything recorded here
        self.metrics = dyn.traffic.registry
        # snapshot-isolated serving: mutations serialize on this lock and
        # end by atomically publishing a fresh ServingView; readers never
        # block and never see a half-applied delta
        self._mutate_lock = threading.RLock()
        self._serving = ServingView(0, 0, self.session, sketch,
                                    dyn.host_snapshot())
        # donation gating: device-buffer donation is safe only when provably
        # nobody reads the published generation. Flushes take a read lease
        # (acquire_serving_view/release_serving_view); a delta that did
        # engage donation sets _donating, blocking new leases until the
        # next view publishes. The guard lives on the graph so the device
        # update consults it at the moment it picks its kernels.
        self._view_cond = threading.Condition(threading.Lock())
        self._read_leases = 0
        self._donating = False
        dyn._donation_guard = self._device_donate_ok

    # ------------------------------------------------------------------
    # mutation
    # ------------------------------------------------------------------

    @property
    def graph(self):
        """The Graph the engine session currently serves (a device view)."""
        return self.session.graph

    @property
    def sketch(self) -> Optional[SketchSet]:
        """The maintained sketch, or None in exact mode."""
        return self.maintainer.sketch if self.maintainer else None

    def serving_view(self) -> ServingView:
        """The currently published :class:`ServingView` (atomic read).

        Flushes capture this once and serve everything from it — a delta
        landing mid-flush builds and publishes the *next* view without
        disturbing the captured one. Concurrent readers that touch device
        state should prefer :meth:`acquire_serving_view`, whose lease also
        keeps buffer donation off while they read.
        """
        return self._serving

    def acquire_serving_view(self) -> ServingView:
        """Capture the published view under a read lease.

        The lease is what makes device-buffer donation safe to keep
        enabled: while any lease is out, ``apply_delta`` builds the next
        device generation *without* donating the published one; and when a
        delta did engage donation (no lease was out), acquisition blocks
        until the next view publishes — the old generation's buffers are
        already condemned. Always pair with :meth:`release_serving_view`.
        """
        with self._view_cond:
            while self._donating:
                self._view_cond.wait()
            self._read_leases += 1
            return self._serving

    def release_serving_view(self, view: Optional[ServingView] = None) -> None:
        """Release one :meth:`acquire_serving_view` lease (``view`` is
        accepted for call-site symmetry; leases are a plain count)."""
        with self._view_cond:
            self._read_leases = max(self._read_leases - 1, 0)

    def _device_donate_ok(self) -> bool:
        """Donation policy installed on ``dyn`` (see ``donate_ok``).

        Donating the published device buffers invalidates them for every
        holder of the current (or an earlier) ServingView, so it is
        allowed only when provably nobody reads one: no serving lease is
        out, and no host snapshot other than the published view's own is
        alive (a stale view still in flight keeps its snapshot alive,
        which is exactly the veto we want). Engaging donation sets
        ``_donating``, which blocks new leases until the delta publishes.
        """
        with self._view_cond:
            if self._read_leases:
                return False
            published = self._serving.host
            if any(s is not published for s in self.dyn.snapshots()):
                return False
            self._donating = True
            return True

    def _end_donation(self) -> None:
        """Re-admit serving-view leases after a donating delta publishes
        (also the exception-path unblocker — see ``apply_delta``)."""
        with self._view_cond:
            if self._donating:
                self._donating = False
                self._view_cond.notify_all()

    def add_delta_listener(self, fn) -> None:
        """Subscribe ``fn(vertices, epoch)`` to the invalidation feed.

        After every delta (and every maintenance :meth:`flush` that rebuilt
        rows) each listener is called with the sorted int64 vertex set whose
        adjacency, degree, or sketch row changed — ``touched ∪ rebuilt`` —
        and the publication epoch of the change. This is exactly the set a
        serving-tier result cache must evict footprint-intersecting entries
        for; nothing else can have changed any answer. Listeners fire
        *before* the new :class:`ServingView` publishes, so by the time any
        flush can read the new version its cache is already clean.
        """
        with self._mutate_lock:
            self._delta_listeners.append(fn)

    def remove_delta_listener(self, fn) -> None:
        """Unsubscribe a listener previously added (no-op if absent)."""
        with self._mutate_lock:
            if fn in self._delta_listeners:
                self._delta_listeners.remove(fn)

    def _publish_invalid_locked(self, vertices: np.ndarray,
                                epoch: int) -> None:
        """Push one delta's changed-vertex set to every listener (a copy of
        the list: a listener may unsubscribe itself mid-publish). Callers
        hold ``_mutate_lock``."""
        if vertices.size:
            for fn in list(self._delta_listeners):
                fn(vertices, epoch)

    def _publish_view_locked(self) -> None:
        """Atomically publish the post-mutation state as the serving view
        (callers hold ``_mutate_lock`` and have already fired the
        invalidation feed). Publication also ends any donation window the
        delta opened: the new view's buffers are valid, so blocked
        :meth:`acquire_serving_view` callers may proceed."""
        self._serving = ServingView(
            self.version, self._serving.epoch + 1, self.session,
            self.maintainer.sketch if self.maintainer else None,
            self.dyn.host_snapshot())
        self._end_donation()

    def _device_carry(self, carry_host: Optional[np.ndarray],
                      identity: bool) -> Optional[DeviceCarry]:
        """Assemble the device-resident refresh carry: the splice permutation
        already lives on device; only the delta-sized recompute positions
        (where the host-computed carry is invalid) are uploaded."""
        if carry_host is None:
            return None
        dev = self.dyn.device
        base = dev.identity_carry() if identity else dev.last_carry
        if base is None:
            return None
        recompute = np.nonzero(carry_host < 0)[0]
        r = int(recompute.size)
        pos = np.full(pow2_bucket(r), self.dyn.m, dtype=np.int32)
        pos[:r] = recompute
        return DeviceCarry(base, self.dyn.traffic.put(pos), r, dev.edges)

    def apply_delta(self, inserts=None, deletes=None) -> dict:
        """Apply one edge-delta batch: mutate the graph, maintain the sketch
        incrementally, and refresh only the invalidated session caches.

        Device-resident: no full-graph host copy or upload happens here —
        the returned ``bytes_uploaded`` (also in ``stats()["traffic"]``) is
        the exact host → device traffic, proportional to the delta size.
        """
        with trace.span("stream.apply_delta") as sp, self._mutate_lock:
            try:
                return self._apply_delta_locked(inserts, deletes, sp)
            finally:
                # normally a no-op (publication ended the donation window);
                # on an exception after the device update donated, this is
                # what unblocks lease acquirers waiting on the window
                self._end_donation()

    def _apply_delta_locked(self, inserts, deletes, sp) -> dict:
        """The body of :meth:`apply_delta` (mutation lock held)."""
        old_keys = self.dyn.edge_keys
        self.dyn.traffic.begin_delta()
        delta = self.dyn.apply_delta(inserts, deletes)
        rebuilt = (self.maintainer.apply(delta)
                   if self.maintainer else np.zeros(0, np.int64))
        self.version += 1
        rec = car = 0
        if not (delta.is_noop and rebuilt.size == 0):
            self.dyn.traffic.commit_step()  # noop deltas stay unmetered
            graph = self.dyn.view()
            # a row rebuilt this delta may have gone dirty at an
            # *earlier* delta (policy deferral), so invalidation covers
            # touched ∪ rebuilt
            invalid = np.union1d(delta.touched, rebuilt)
            carry = self._device_carry(
                self.dyn.carry_index(old_keys, invalid),
                identity=delta.is_noop)  # noop delta ran no edge splice
            # fork-refresh-publish: the live session keeps serving the
            # previous version while the fork absorbs the delta; the
            # swap below is the version-N+1 publication point
            new_session = self.session.fork()
            recomputed = new_session.refresh(
                graph,
                self.maintainer.sketch if self.maintainer else None,
                carry)
            # refresh returns None when it dropped the cache (nothing
            # carried; the full pass happens lazily) — no savings counted
            rec = 0 if recomputed is None else recomputed
            car = 0 if recomputed is None else max(graph.m - recomputed, 0)
            self.cards_recomputed += rec
            self.cards_carried += car
            # invalidation completes BEFORE publication: once a flush
            # can capture the new view, every stale cache entry is gone
            self._publish_invalid_locked(invalid, self._serving.epoch + 1)
            self.session = new_session
        self._publish_view_locked()
        if self.maintainer is not None:
            accuracy.record_maintenance(self.maintainer.stats(),
                                        self.metrics)
        info = {
            "version": self.version,
            "inserted": int(delta.inserted.shape[0]),
            "deleted": int(delta.deleted.shape[0]),
            "touched": int(delta.touched.shape[0]),
            "rows_rebuilt_now": int(rebuilt.size),
            "cards_recomputed": rec,
            "cards_carried": car,
            "bytes_uploaded": self.dyn.traffic.bytes_delta,
        }
        sp.set(**info)
        return info

    def flush(self) -> int:
        """Force-rebuild all dirty sketch rows and refresh their edges —
        makes subsequent answers exact w.r.t. the current graph even under a
        lazy error-budget policy."""
        if self.maintainer is None or not self.maintainer.dirty.any():
            return 0       # nothing to rebuild: not a metered traffic step
        with trace.span("stream.flush") as sp, self._mutate_lock:
            self.dyn.traffic.begin_delta()
            self.dyn.traffic.commit_step()
            rebuilt = self.maintainer.flush()
            if rebuilt.size:
                carry = self._device_carry(
                    self.dyn.carry_index(self.dyn.edge_keys, rebuilt),
                    identity=True)           # edge set unchanged by a flush
                new_session = self.session.fork()
                new_session.refresh(self.dyn.view(), self.maintainer.sketch,
                                    carry)
                # a rebuild replaces stale sketch rows: cached answers
                # reading those rows are now wrong, exactly like a delta
                # touching them
                # rebuilt is host data (np.nonzero output) — .astype is a
                # pure host cast, not a device copy needing a span fence
                self._publish_invalid_locked(rebuilt.astype(np.int64),
                                             self._serving.epoch + 1)
                self.session = new_session
                self._publish_view_locked()
            sp.set(rows_rebuilt=int(rebuilt.size))
        return int(rebuilt.size)

    # ------------------------------------------------------------------
    # queries (the batch engine's surface, served on the live graph)
    # ------------------------------------------------------------------

    def triangle_count(self) -> jax.Array:
        """Scalar TC estimate over the live graph (shared engine pass)."""
        return self.session.triangle_count()

    def local_clustering(self) -> jax.Array:
        """Per-vertex clustering coefficients float32[n] (live graph)."""
        return self.session.local_clustering()

    def four_clique_count(self) -> jax.Array:
        """Scalar 4-clique count estimate over the live graph."""
        return self.session.four_clique_count()

    def five_clique_count(self) -> jax.Array:
        """Scalar 5-clique count estimate over the live graph (compiled
        4-way AND set expression — see ``repro.engine.setexpr``)."""
        return self.session.five_clique_count()

    def similarity(self, pairs, measure: str = "jaccard") -> jax.Array:
        """Similarity scores float32[P] for vertex pairs on the live graph."""
        return self.session.similarity(jnp.asarray(pairs), measure)

    def local_cluster(self, seeds, alpha: float = 0.15, eps: float = 1e-4,
                      **kw):
        """Seed-centric local clustering on the live graph.

        Serves over ``DynamicGraph.view()`` (device-resident) through the
        engine session, so answers reflect every applied delta; under the
        strict error-budget policy they are bit-identical to a fresh static
        session on the equivalent graph — including on the sparse-frontier
        push path (``frontier_mode=``/``frontier_cap=`` plan overrides
        forward through ``**kw``), whose capped ``[S, cap]`` buffers keep
        high-QPS seed expansion affordable between deltas. See
        :meth:`repro.engine.engine.MiningSession.local_cluster`.
        """
        return self.session.local_cluster(seeds, alpha, eps, **kw)

    def membership(self, u: int, candidates) -> jax.Array:
        """Is each candidate a neighbor of u? BF answers from the sketch row
        (the paper's membership primitive); other kinds answer exactly."""
        sk = self.sketch
        cand = jnp.asarray(np.asarray(candidates, dtype=np.int32))
        if sk is not None and sk.kind == "bf":
            return bloom_membership(sk.data[u], cand, self.dyn.n,
                                    sk.num_hashes, sk.total_bits, sk.seed)
        return jnp.asarray(np.isin(np.asarray(candidates),
                                   self.dyn.neighbors(u)))

    def stats(self) -> dict:
        """Session counters: sizes, cache savings, traffic, maintenance."""
        out = {
            "version": self.version,
            "n": self.dyn.n, "m": self.dyn.m,
            "cards_recomputed": self.cards_recomputed,
            "cards_carried": self.cards_carried,
            # host → device bytes: init is the one-time residency upload;
            # bytes_per_delta_mean is the per-delta traffic the
            # device-resident design bounds by the delta size
            "traffic": self.dyn.traffic.stats(),
        }
        if self.maintainer is not None:
            out["maintenance"] = self.maintainer.stats()
            # accuracy telemetry: sketch saturation is the leading indicator
            # of estimate inflation; recorded here (stats-time, not hot path
            # — the Bloom fill scan is O(n·bits))
            accuracy.record_fill(self.maintainer.sketch, self.metrics)
        return out

    # ------------------------------------------------------------------
    # snapshot / restore through checkpoint.store
    # ------------------------------------------------------------------

    def _config(self, extra: Optional[dict] = None) -> dict:
        cfg = {"kind": None, "headroom": self.dyn.headroom,
               "extra": extra or {}}
        if self.maintainer is not None:
            mt = self.maintainer
            cfg.update(kind=mt.kind, num_hashes=mt.num_hashes, seed=mt.seed,
                       words=mt.words, k=mt.k,
                       policy={"rel_tolerance": mt.policy.rel_tolerance,
                               "confidence": mt.policy.confidence,
                               "max_stale": mt.policy.max_stale})
        return cfg

    def save(self, directory: str, step: Optional[int] = None,
             keep: int = 3, extra: Optional[dict] = None) -> str:
        """Atomic snapshot of the full dynamic state (graph + sketch +
        dirty/stale bookkeeping) via checkpoint.store. ``extra`` is an
        arbitrary JSON-able dict the caller can validate at restore time
        (e.g. the replay driver's stream parameters)."""
        step = self.version if step is None else int(step)
        # hold the mutation lock: a delta landing mid-save must not tear the
        # checkpoint across versions (adj from N+1, edge_keys from N)
        with self._mutate_lock:
            return self._save_locked(directory, step, keep, extra)

    def _save_locked(self, directory: str, step: int, keep: int,
                     extra: Optional[dict]) -> str:
        tree = {
            "config": np.frombuffer(
                json.dumps(self._config(extra)).encode(),
                dtype=np.uint8).copy(),
            "n": np.int64(self.dyn.n),
            "version": np.int64(self.version),
            "edge_keys": self.dyn.edge_keys,
            "deg": self.dyn.deg,
            "adj": self.dyn.adj,
        }
        if self.maintainer is not None:
            mt = self.maintainer
            tree.update(sketch=np.asarray(mt.sketch.data), dirty=mt.dirty,
                        stale=mt.stale,
                        counters=np.asarray([mt.rows_incremental,
                                             mt.rows_rebuilt,
                                             mt.deltas_applied], np.int64))
        return store.save_checkpoint(directory, step, tree, keep=keep)

    @classmethod
    def restore(cls, directory: str, step: Optional[int] = None,
                plan: Optional[EnginePlan] = None, **plan_kw) -> "StreamSession":
        """Resume a session from a :meth:`save` checkpoint (latest step by
        default); the stored config re-creates graph, sketch and policy."""
        if step is None:
            step = store.latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {directory}")
        meta = store.load_meta(directory, step)
        target = {key: jax.ShapeDtypeStruct(tuple(leaf["shape"]),
                                            np.dtype(leaf["dtype"]))
                  for key, leaf in meta["leaves"].items()}
        tree = {key: np.asarray(val)
                for key, val in store.restore_checkpoint(
                    directory, step, target).items()}
        cfg = json.loads(bytes(tree["config"]).decode())
        dyn = DynamicGraph(int(tree["n"]), tree["edge_keys"].astype(np.int64),
                           tree["deg"].astype(np.int32),
                           tree["adj"].astype(np.int32),
                           headroom=cfg["headroom"])
        policy = (ErrorBudgetPolicy(**cfg["policy"])
                  if cfg.get("policy") else None)
        self = cls(dyn, kind=cfg["kind"], num_hashes=cfg.get("num_hashes", 2),
                   seed=cfg.get("seed", 0), words=cfg.get("words") or None,
                   k=cfg.get("k") or None, policy=policy, plan=plan,
                   sketch_data=(jnp.asarray(tree["sketch"])
                                if cfg["kind"] else None), **plan_kw)
        # the restored session is not shared yet, but version/view swaps
        # are mutations all the same — hold the lock like every mutator
        with self._mutate_lock:
            self.version = int(tree["version"])
            self.extra = cfg.get("extra") or {}
            if self.maintainer is not None:
                mt = self.maintainer
                mt.dirty = tree["dirty"].astype(bool)
                mt.stale = tree["stale"].astype(np.int64)
                mt.rows_incremental, mt.rows_rebuilt, mt.deltas_applied = (
                    int(x) for x in tree["counters"])
            # __init__ published a view stamped version 0; re-publish so
            # the serving view carries the restored version
            self._publish_view_locked()
        return self


def stream_session(graph_or_dyn, kind: Optional[str] = "bf",
                   **kwargs) -> StreamSession:
    """Open a streaming session over a Graph or DynamicGraph (the streaming
    twin of ``engine.session``)."""
    dyn = (graph_or_dyn if isinstance(graph_or_dyn, DynamicGraph)
           else DynamicGraph.from_graph(graph_or_dyn))
    return StreamSession(dyn, kind=kind, **kwargs)
