"""Batched query serving over a live StreamSession.

Requests (similarity / link-prediction / membership / triangle-count /
local clustering) accumulate in a queue; ``flush()`` groups them, pads each
group to fixed
batch shapes (powers of two, so XLA recompiles stay bounded under arbitrary
traffic), and answers everything through the engine seam — one
``pair_cardinality_fn`` evaluation serves *all* pair-scored requests in a
flush, whatever similarity measure each asked for, because every measure
derives from |N_u ∩ N_v| + degrees (``similarity_from_cardinalities``).

Each response carries per-query latency (submit → answer wall time) and
staleness (graph deltas applied between submit and answer) so a serving tier
above this can reason about freshness.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from ..core.algorithms.similarity import similarity_from_cardinalities
from ..engine import engine as eng
from ..engine.plan import pow2_bucket
from .session import StreamSession


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One answered request: value plus latency/staleness provenance."""

    request_id: int
    kind: str
    value: object
    submitted_version: int
    answered_version: int
    latency_s: float

    @property
    def staleness(self) -> int:
        """Graph deltas applied between submit and answer (0 == fresh)."""
        return self.answered_version - self.submitted_version


@dataclasses.dataclass
class _Pending:
    request_id: int
    kind: str          # similarity | linkpred | membership | tc | localcluster
    measure: str
    pairs: Optional[np.ndarray]     # [P, 2] for pair-scored kinds
    payload: dict
    submitted_version: int
    t_submit: float


class BatchedQueryServer:
    """Accumulate-and-flush query server over one StreamSession."""

    def __init__(self, stream: StreamSession, min_batch: int = 64,
                 stats_window: int = 65536):
        self.stream = stream
        self.min_batch = int(min_batch)
        self._queue: List[_Pending] = []
        self._next_id = 0
        self._served = 0
        self._flushes = 0
        # bounded windows: a long-lived server must not grow per-query state
        self._latencies = collections.deque(maxlen=stats_window)
        self._staleness = collections.deque(maxlen=stats_window)
        self._padded_rows = 0
        self._real_rows = 0

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def _submit(self, kind: str, measure: str = "",
                pairs: Optional[np.ndarray] = None, **payload) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, kind, measure, pairs, payload,
                                    self.stream.version, time.perf_counter()))
        return rid

    def submit_similarity(self, pairs, measure: str = "jaccard") -> int:
        """Score vertex pairs [P, 2] under any cardinality-derived measure."""
        return self._submit("similarity", measure,
                            np.asarray(pairs, dtype=np.int32).reshape(-1, 2))

    def submit_link_prediction(self, u: int, top_k: int = 8,
                               measure: str = "common") -> int:
        """Top-k predicted partners for u among its distance-2 non-neighbors
        of the *live* graph (Listing-5 candidates, served online)."""
        dyn = self.stream.dyn
        nbrs = dyn.neighbors(int(u))
        cand = np.unique(np.concatenate(
            [dyn.neighbors(int(x)) for x in nbrs]
            or [np.zeros(0, np.int32)]))
        cand = cand[(cand != u) & ~np.isin(cand, nbrs)]
        pairs = np.stack([np.full(cand.shape[0], u, np.int32),
                          cand.astype(np.int32)], axis=1)
        return self._submit("linkpred", measure, pairs,
                            u=int(u), top_k=int(top_k), candidates=cand)

    def submit_membership(self, u: int, candidates) -> int:
        """x ∈ N_u membership tests (BF answers straight from the sketch)."""
        return self._submit("membership", "",
                            u=int(u),
                            candidates=np.asarray(candidates, dtype=np.int32))

    def submit_triangle_count(self) -> int:
        """Triangle-count query over the live graph (shared engine pass)."""
        return self._submit("tc")

    def submit_local_cluster(self, seed: int, alpha: float = 0.15,
                             eps: float = 1e-4) -> int:
        """Seed-centric local cluster query (``localcluster(seed, α, ε)``).

        All localcluster requests sharing ``(alpha, eps)`` in one flush run
        as a single pow2-padded seed batch through the vmapped PPR push +
        sweep — the local-clustering analogue of the shared cardinality
        pass. The answer value is a dict with ``members`` (int32[size]
        vertex ids of the best cluster), ``conductance``, ``size`` and
        ``support``.
        """
        return self._submit("localcluster", "", seed=int(seed),
                            alpha=float(alpha), eps=float(eps))

    def pending_count(self) -> int:
        """Number of submitted-but-unflushed requests."""
        return len(self._queue)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def flush(self) -> Dict[int, QueryResult]:
        """Answer every pending request in one padded batch per shape."""
        if not self._queue:
            return {}
        queue, self._queue = self._queue, []
        self._flushes += 1
        sess = self.stream.session

        # one shared cardinality pass for ALL pair-scored requests
        pair_reqs = [p for p in queue if p.pairs is not None]
        scores: Dict[int, np.ndarray] = {}
        if pair_reqs:
            pairs = np.concatenate([p.pairs for p in pair_reqs], axis=0)
            total = pairs.shape[0]
            padded = np.zeros((pow2_bucket(total, self.min_batch), 2), np.int32)
            padded[:total] = pairs
            self._real_rows += total
            self._padded_rows += padded.shape[0]
            fn = eng.pair_cardinality_fn(sess.graph, sess.sketch, sess.plan)
            pairs_j = jnp.asarray(padded)
            cards_j = eng.map_edges(pairs_j, fn, sess.plan)
            # degrees gathered on device at the queried pairs only — a full
            # np.asarray(graph.deg) here would move O(n) bytes per flush,
            # against the streaming path's delta-sized-transfer contract
            du_j = jnp.take(sess.graph.deg, pairs_j[:, 0]).astype(jnp.float32)
            dv_j = jnp.take(sess.graph.deg, pairs_j[:, 1]).astype(jnp.float32)
            cards = np.asarray(cards_j)
            du_all, dv_all = np.asarray(du_j), np.asarray(dv_j)
            off = 0
            for p in pair_reqs:
                k = p.pairs.shape[0]
                scores[p.request_id] = np.asarray(similarity_from_cardinalities(
                    jnp.asarray(cards[off:off + k]),
                    jnp.asarray(du_all[off:off + k]),
                    jnp.asarray(dv_all[off:off + k]), p.measure))
                off += k

        # one batched push + sweep per (alpha, eps) localcluster group
        lc_reqs = [p for p in queue if p.kind == "localcluster"]
        lc_answers: Dict[int, dict] = {}
        for key in sorted({(p.payload["alpha"], p.payload["eps"])
                           for p in lc_reqs}):
            group = [p for p in lc_reqs
                     if (p.payload["alpha"], p.payload["eps"]) == key]
            seeds = np.array([p.payload["seed"] for p in group], np.int32)
            # pad with a repeat of the first seed (dropped below); the pow2
            # bucket keeps one compiled push/sweep per batch size class
            padded = np.full(pow2_bucket(seeds.size), seeds[0], np.int32)
            padded[:seeds.size] = seeds
            self._real_rows += seeds.size
            self._padded_rows += padded.shape[0]
            res = self.stream.local_cluster(padded, alpha=key[0], eps=key[1])
            sizes = np.asarray(res.best_size)
            phis = np.asarray(res.best_conductance)
            sup = np.asarray(res.support)
            order = np.asarray(res.order)
            for i, p in enumerate(group):
                lc_answers[p.request_id] = {
                    "members": order[i, :sizes[i]],
                    "conductance": float(phis[i]),
                    "size": int(sizes[i]),
                    "support": int(sup[i]),
                }

        out: Dict[int, QueryResult] = {}
        for p in queue:
            if p.kind == "similarity":
                value = scores[p.request_id]
            elif p.kind == "linkpred":
                s = scores[p.request_id]
                top = np.argsort(-s, kind="stable")[:p.payload["top_k"]]
                value = {"candidates": p.payload["candidates"][top],
                         "scores": s[top]}
            elif p.kind == "membership":
                cand = p.payload["candidates"]
                padded = np.full(pow2_bucket(cand.shape[0], self.min_batch),
                                 self.stream.dyn.n, np.int32)
                padded[:cand.shape[0]] = cand
                self._real_rows += cand.shape[0]
                self._padded_rows += padded.shape[0]
                value = np.asarray(self.stream.membership(
                    p.payload["u"], padded))[:cand.shape[0]]
            elif p.kind == "tc":
                value = float(sess.triangle_count())
            elif p.kind == "localcluster":
                value = lc_answers[p.request_id]
            else:  # pragma: no cover - guarded at submit time
                raise ValueError(p.kind)
            lat = time.perf_counter() - p.t_submit
            res = QueryResult(p.request_id, p.kind, value,
                              p.submitted_version, self.stream.version, lat)
            self._latencies.append(lat)
            self._staleness.append(res.staleness)
            self._served += 1
            out[p.request_id] = res
        return out

    def stats(self) -> dict:
        """Serving counters: latency percentiles, staleness, pad overhead."""
        lat = np.asarray(self._latencies or [0.0])
        return {
            "served": self._served,
            "flushes": self._flushes,
            "latency_mean_s": float(lat.mean()),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "staleness_mean": float(np.mean(self._staleness or [0])),
            "pad_overhead": (self._padded_rows / self._real_rows - 1.0
                             if self._real_rows else 0.0),
        }
