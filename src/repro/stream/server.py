"""Batched query serving over a live StreamSession, with a result cache.

Requests (similarity / link-prediction / membership / triangle-count /
local clustering) accumulate in a queue; ``flush()`` groups them, pads each
group to fixed batch shapes (powers of two, so XLA recompiles stay bounded
under arbitrary traffic), and answers everything through the engine seam —
one ``pair_cardinality_fn`` evaluation serves *all* pair-scored requests in
a flush, whatever similarity measure each asked for, because every measure
derives from |N_u ∩ N_v| + degrees (``similarity_from_cardinalities``).

Three serving-tier layers ride on top of the batching:

* **Result cache** (:class:`repro.stream.cache.ResultCache`, on by
  default): answers are keyed by ``(kind, canonical args)`` and carry the
  exact vertex :class:`~repro.engine.Footprint` they were computed from;
  the session's delta feed (``touched ∪ rebuilt``) evicts precisely the
  intersecting entries, so a hit is — under the strict error-budget
  policy — bit-identical to recomputing on the live graph.
* **Coalescing**: identical pending requests in one flush compute once and
  fan out to every request id; duplicate local-cluster seeds in one
  ``(alpha, eps)`` group collapse the same way (the canonical key *is* the
  dedup unit).
* **Admission policy**: optional ``max_batch`` (auto-flush when the queue
  fills) and ``max_wait_s`` (``poll()`` flushes once the oldest pending
  request has waited long enough), so callers submit-and-drain instead of
  hand-rolling flush loops.

Each response carries per-query latency (submit → answer wall time) and
staleness (graph deltas applied between submit and answer) so a serving tier
above this can reason about freshness.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import weakref
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.algorithms.similarity import similarity_from_cardinalities
from ..engine import api as eng
from ..engine.api import Footprint, pow2_bucket
from ..obs import accuracy, trace
from ..obs.metrics import MetricsRegistry
from .cache import ResultCache
from .session import StreamSession


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One answered request: value plus latency/staleness provenance."""

    request_id: int
    kind: str
    value: object
    submitted_version: int
    answered_version: int
    latency_s: float

    @property
    def staleness(self) -> int:
        """Graph deltas applied between submit and answer (0 == fresh)."""
        return self.answered_version - self.submitted_version


@dataclasses.dataclass
class _Pending:
    request_id: int
    kind: str   # similarity | linkpred | membership | tc | cliques | localcluster
    key: Tuple         # canonical (kind, args…) — the cache/coalescing unit
    measure: str
    pairs: Optional[np.ndarray]     # [P, 2] for similarity requests
    payload: dict
    submitted_version: int
    t_submit: float


def _freeze(value):
    """Mark an answer's arrays read-only before caching (hits share them)."""
    if isinstance(value, np.ndarray):
        value.setflags(write=False)
    elif isinstance(value, dict):
        for item in value.values():
            if isinstance(item, np.ndarray):
                item.setflags(write=False)
    return value


class BatchedQueryServer:
    """Accumulate-and-flush query server over one StreamSession.

    Args:
      stream:         the live session to serve from.
      min_batch:      pow2 padding floor shared by the pair, membership and
                      local-cluster seed batches (one compiled program per
                      size class, whatever the traffic).
      stats_window:   bounded latency/staleness window size.
      cache:          keep a footprint-invalidated result cache (default on;
                      answers stay bit-identical — see ``stream.cache``).
      cache_capacity: LRU entry bound for the cache.
      max_batch:      auto-flush as soon as this many requests are pending
                      (None = only explicit ``flush()``/``poll()``).
      max_wait_s:     ``poll()`` flushes once the oldest pending request has
                      waited this long (None = never due by age).
    """

    def __init__(self, stream: StreamSession, min_batch: int = 64,
                 stats_window: int = 65536, cache: bool = True,
                 cache_capacity: int = 4096,
                 max_batch: Optional[int] = None,
                 max_wait_s: Optional[float] = None):
        self.stream = stream
        self.min_batch = int(min_batch)
        self.max_batch = None if max_batch is None else int(max_batch)
        self.max_wait_s = None if max_wait_s is None else float(max_wait_s)
        self.cache = ResultCache(cache_capacity) if cache else None
        self._listener = None
        if self.cache is not None:
            # weakref-bound listener: a dropped server must not pin its
            # cache via the session's listener list, nor keep charging
            # every future delta for invalidating a dead cache — the
            # closure self-unsubscribes once the cache is collected
            cache_ref = weakref.ref(self.cache)
            stream_ref = weakref.ref(stream)

            def _invalidate(vertices):
                target = cache_ref()
                if target is None:
                    sess = stream_ref()
                    if sess is not None:
                        sess.remove_delta_listener(_invalidate)
                    return
                target.invalidate(vertices)

            self._listener = _invalidate
            stream.add_delta_listener(_invalidate)
        self._queue: List[_Pending] = []
        self._results: Dict[int, QueryResult] = {}
        self._next_id = 0
        # serving counters live in the per-server metrics registry;
        # ``stats()`` is a bit-compatible view over these instruments
        self.metrics = MetricsRegistry()
        self._c_served = self.metrics.counter("server_served_total")
        self._c_flushes = self.metrics.counter("server_flushes_total")
        self._c_coalesced = self.metrics.counter("server_coalesced_total")
        # bounded windows: a long-lived server must not grow per-query state
        self._h_latency = self.metrics.histogram("server_latency_s",
                                                 window=stats_window)
        self._h_staleness = self.metrics.histogram("server_staleness",
                                                   window=stats_window)
        # per-path (real, padded) row counters — membership and seed batches
        # pad very differently from the shared pair pass, so they are not
        # lumped into one overhead number; the plain dict stays the write
        # surface (tests poke it), mirrored into the registry by _pad_add
        self._pad = {"pairs": [0, 0], "membership": [0, 0],
                     "localcluster": [0, 0]}
        for name in self._pad:
            self.metrics.counter("server_pad_rows", path=name, rows="real")
            self.metrics.counter("server_pad_rows", path=name, rows="padded")

    @property
    def _served(self) -> int:
        return self._c_served.value

    @property
    def _flushes(self) -> int:
        return self._c_flushes.value

    @property
    def _coalesced(self) -> int:
        return self._c_coalesced.value

    def _pad_add(self, name: str, real: int, padded: int) -> None:
        """Meter one padded batch: real vs padded row counts for ``name``."""
        self._pad[name][0] += real
        self._pad[name][1] += padded
        self.metrics.counter("server_pad_rows", path=name,
                             rows="real").inc(real)
        self.metrics.counter("server_pad_rows", path=name,
                             rows="padded").inc(padded)

    def close(self) -> None:
        """Detach from the session's invalidation feed and drop the cache.

        Without the feed the cache can no longer be kept honest, so a
        closed server recomputes every answer instead of risking stale
        hits.
        """
        if self._listener is not None:
            self.stream.remove_delta_listener(self._listener)
            self._listener = None
        self.cache = None

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def _submit(self, kind: str, key: Tuple, measure: str = "",
                pairs: Optional[np.ndarray] = None, **payload) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, kind, key, measure, pairs, payload,
                                    self.stream.version, time.perf_counter()))
        if self.max_batch is not None and len(self._queue) >= self.max_batch:
            self._flush_queue()
        return rid

    def submit_similarity(self, pairs, measure: str = "jaccard") -> int:
        """Score vertex pairs [P, 2] under any cardinality-derived measure."""
        # copy, not view: the key snapshots the bytes here, and the flush
        # computes from this array — a caller reusing its buffer must not
        # be able to poison the cache with a key/value mismatch
        pairs = np.array(pairs, dtype=np.int32, copy=True).reshape(-1, 2)
        key = ("similarity", measure, pairs.shape[0], pairs.tobytes())
        return self._submit("similarity", key, measure, pairs)

    def submit_link_prediction(self, u: int, top_k: int = 8,
                               measure: str = "common") -> int:
        """Top-k predicted partners for u among its distance-2 non-neighbors
        (Listing-5 candidates, served online).

        The candidate set is materialized from the live graph at *flush*
        time, not here: with deltas interleaved between submit and flush, a
        submit-time candidate set would mix stale candidates (e.g. a vertex
        that became a neighbor still "predicted") with fresh scores.
        """
        key = ("linkpred", measure, int(u), int(top_k))
        return self._submit("linkpred", key, measure,
                            u=int(u), top_k=int(top_k))

    def submit_membership(self, u: int, candidates) -> int:
        """x ∈ N_u membership tests (BF answers straight from the sketch)."""
        cand = np.array(candidates, dtype=np.int32, copy=True)  # see above
        key = ("membership", int(u), cand.shape[0], cand.tobytes())
        return self._submit("membership", key, u=int(u), candidates=cand)

    def submit_triangle_count(self) -> int:
        """Triangle-count query over the live graph (shared engine pass)."""
        return self._submit("tc", ("tc",))

    def submit_clique_count(self, k: int = 4) -> int:
        """k-clique-count query (k in {4, 5}) over the live graph.

        Both sizes fold every edge, so like ``tc`` they carry a whole-graph
        footprint: any delta invalidates a cached count. k = 5 runs through
        the engine's compiled 4-way AND set expression.
        """
        if k not in (4, 5):
            raise ValueError(f"clique count supports k in {{4, 5}}, got {k}")
        return self._submit("cliques", ("cliques", int(k)), k=int(k))

    def submit_local_cluster(self, seed: int, alpha: float = 0.15,
                             eps: float = 1e-4) -> int:
        """Seed-centric local cluster query (``localcluster(seed, α, ε)``).

        All localcluster requests sharing ``(alpha, eps)`` in one flush run
        as a single pow2-padded seed batch through the vmapped PPR push +
        sweep — the local-clustering analogue of the shared cardinality
        pass. Duplicate seeds in a group dedup through the canonical key
        and fan back out by request id. The answer value is a dict with
        ``members`` (int32[size] vertex ids of the best cluster),
        ``conductance``, ``size`` and ``support``.
        """
        key = ("localcluster", int(seed), float(alpha), float(eps))
        return self._submit("localcluster", key, seed=int(seed),
                            alpha=float(alpha), eps=float(eps))

    def pending_count(self) -> int:
        """Number of submitted-but-unflushed requests."""
        return len(self._queue)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def flush(self) -> Dict[int, QueryResult]:
        """Answer everything pending; return (and clear) unclaimed results.

        Results answered earlier by the admission policy (``max_batch`` /
        ``poll()``) and not yet drained are included.
        """
        self._flush_queue()
        return self.drain()

    def poll(self) -> Dict[int, QueryResult]:
        """Apply the admission policy, then drain.

        Flushes when the queue holds ``max_batch`` requests or the oldest
        pending request has waited ``max_wait_s``; either way returns every
        answered-but-undrained result (possibly none).
        """
        if self._queue:
            due_batch = (self.max_batch is not None
                         and len(self._queue) >= self.max_batch)
            due_age = (self.max_wait_s is not None
                       and time.perf_counter() - self._queue[0].t_submit
                       >= self.max_wait_s)
            if due_batch or due_age:
                self._flush_queue()
        return self.drain()

    def drain(self) -> Dict[int, QueryResult]:
        """Return and clear every answered-but-unclaimed result."""
        out, self._results = self._results, {}
        return out

    def _link_candidates(self, u: int) -> np.ndarray:
        """Distance-2 non-neighbors of ``u`` on the *live* graph (sorted)."""
        dyn = self.stream.dyn
        nbrs = dyn.neighbors(int(u))
        cand = np.unique(np.concatenate(
            [dyn.neighbors(int(x)) for x in nbrs]
            or [np.zeros(0, np.int32)]))
        return cand[(cand != u) & ~np.isin(cand, nbrs)]

    def _flush_queue(self) -> None:
        """Answer every pending request: cache, coalesce, one batch per
        shape class for the misses, then fan out by request id."""
        if not self._queue:
            return
        with trace.span("server.flush") as fsp:
            self._flush_body(fsp)

    def _flush_body(self, fsp) -> None:
        """The traced body of :meth:`_flush_queue` (``fsp`` is its span)."""
        queue, self._queue = self._queue, []
        self._c_flushes.inc()
        sess = self.stream.session
        dyn = self.stream.dyn
        version = self.stream.version
        vol_now = 2.0 * dyn.m

        # coalesce: identical requests (same canonical key) compute once
        by_key: "collections.OrderedDict[Tuple, List[_Pending]]" = \
            collections.OrderedDict()
        for p in queue:
            by_key.setdefault(p.key, []).append(p)
        coalesced = len(queue) - len(by_key)
        self._c_coalesced.inc(coalesced)

        answers: Dict[Tuple, object] = {}
        misses: List[Tuple] = []
        with trace.span("cache.lookup", keys=len(by_key),
                        enabled=self.cache is not None) as csp:
            for key in by_key:
                if self.cache is not None:
                    hit = self.cache.get(
                        key, vol_now if key[0] == "localcluster" else None)
                    if hit is not None:
                        answers[key] = hit.value
                        continue
                misses.append(key)
            csp.set(hits=len(by_key) - len(misses))
        # invariant 8 provenance: every answer in this flush is attributable
        # to this span's cache/coalesce/pad accounting
        fsp.set(requests=len(queue), unique_keys=len(by_key),
                coalesced=coalesced, cache_hits=len(by_key) - len(misses),
                version=version)

        # one shared cardinality pass for ALL uncached pair-scored requests;
        # link-prediction candidates materialize HERE, from the live graph
        pair_keys: List[Tuple] = []
        pair_blocks: List[np.ndarray] = []
        lp_cand: Dict[Tuple, np.ndarray] = {}
        for key in misses:
            p0 = by_key[key][0]
            if p0.kind == "similarity":
                pair_keys.append(key)
                pair_blocks.append(p0.pairs)
            elif p0.kind == "linkpred":
                u = p0.payload["u"]
                cand = self._link_candidates(u)
                lp_cand[key] = cand
                pair_keys.append(key)
                pair_blocks.append(np.stack(
                    [np.full(cand.shape[0], u, np.int32),
                     cand.astype(np.int32)], axis=1))
        scores: Dict[Tuple, np.ndarray] = {
            key: np.zeros(0, np.float32) for key in pair_keys}
        total = sum(b.shape[0] for b in pair_blocks)
        if total:
            with trace.span("server.pair_batch", pairs=total) as psp:
                pairs = np.concatenate(pair_blocks, axis=0)
                padded = np.zeros((pow2_bucket(total, self.min_batch), 2),
                                  np.int32)
                padded[:total] = pairs
                self._pad_add("pairs", total, padded.shape[0])
                psp.set(padded=padded.shape[0])
                pairs_j = jnp.asarray(padded)
                with trace.span("engine.pair_cards",
                                pairs=padded.shape[0]) as ksp:
                    fn = eng.pair_cardinality_fn(sess.graph, sess.sketch,
                                                 sess.plan)
                    cards_j = eng.map_edges(pairs_j, fn, sess.plan)
                    ksp.fence(cards_j)
                # degrees gathered on device at the queried pairs only — a
                # full np.asarray(graph.deg) here would move O(n) bytes per
                # flush, against the streaming path's delta-sized-transfer
                # contract
                du_j = jnp.take(sess.graph.deg,
                                pairs_j[:, 0]).astype(jnp.float32)
                dv_j = jnp.take(sess.graph.deg,
                                pairs_j[:, 1]).astype(jnp.float32)
                cards = np.asarray(cards_j)
                du_all, dv_all = np.asarray(du_j), np.asarray(dv_j)
                if sess.sketch is not None:
                    # live error-interval estimate for the answers just
                    # computed (real rows only, padding excluded)
                    accuracy.record_pair_error(
                        sess.sketch, cards[:total], du_all[:total],
                        dv_all[:total], self.metrics)
                off = 0
                for key, block in zip(pair_keys, pair_blocks):
                    k = block.shape[0]
                    scores[key] = np.asarray(similarity_from_cardinalities(
                        jnp.asarray(cards[off:off + k]),
                        jnp.asarray(du_all[off:off + k]),
                        jnp.asarray(dv_all[off:off + k]),
                        by_key[key][0].measure))
                    off += k

        # one batched push + sweep per (alpha, eps) group of uncached seeds
        # (seeds are unique per group by construction: the key dedups them)
        lc_groups: "collections.OrderedDict[Tuple, List[Tuple]]" = \
            collections.OrderedDict()
        for key in misses:
            if key[0] == "localcluster":
                lc_groups.setdefault(key[2:], []).append(key)
        deg_host = dyn.deg
        for (alpha, eps), group in lc_groups.items():
            seeds = np.array([key[1] for key in group], np.int32)
            # pad with a repeat of the first seed (dropped below); the same
            # pow2 floor as the pair path keeps one compiled push/sweep per
            # batch size class
            padded = np.full(pow2_bucket(seeds.size, self.min_batch),
                             seeds[0], np.int32)
            padded[:seeds.size] = seeds
            self._pad_add("localcluster", int(seeds.size), padded.shape[0])
            with trace.span("server.localcluster_batch",
                            seeds=int(seeds.size), padded=padded.shape[0],
                            alpha=float(alpha), eps=float(eps)) as lsp:
                res = self.stream.local_cluster(padded, alpha=alpha, eps=eps)
                lsp.fence(res.best_conductance)
            sizes = np.asarray(res.best_size)
            phis = np.asarray(res.best_conductance)
            sup = np.asarray(res.support)
            order = np.asarray(res.order)
            for i, key in enumerate(group):
                value = {
                    # .copy(): a bare slice would pin the whole padded
                    # [S, n] order matrix for as long as the answer lives
                    "members": order[i, :sizes[i]].copy(),
                    "conductance": float(phis[i]),
                    "size": int(sizes[i]),
                    "support": int(sup[i]),
                }
                # frozen even with the cache off: coalesced duplicates
                # share this object across request ids
                answers[key] = _freeze(value)
                if self.cache is not None:
                    # conductance reads the total volume through
                    # min(vol, 2m − vol): cache only clusters provably on
                    # the small side, guarded against later volume drift
                    swept = order[i, :sup[i]]
                    swept = swept[swept < dyn.n]
                    max2vol = 2.0 * float(deg_host[swept].sum())
                    if self.cache.cacheable(max2vol, vol_now):
                        fp = Footprint.of(res.footprint(i), key[1])
                        self.cache.put(key, value, fp, version,
                                       max2vol=max2vol, vol_total=vol_now)

        # remaining miss kinds + cache fills
        for key in misses:
            kind = key[0]
            if kind == "localcluster":
                continue                       # answered in the group pass
            p0 = by_key[key][0]
            if kind == "similarity":
                value = scores[key]
                fp = Footprint.of(p0.pairs)
            elif kind == "linkpred":
                s = scores[key]
                cand = lp_cand[key]
                top = np.argsort(-s, kind="stable")[:p0.payload["top_k"]]
                value = {"candidates": cand[top], "scores": s[top]}
                # the candidate set itself is a function of N(u)'s rows: a
                # new edge at any neighbor mints a new candidate, so the
                # footprint is {u} ∪ N(u) ∪ candidates
                u = p0.payload["u"]
                fp = Footprint.of(u, dyn.neighbors(u), cand)
            elif kind == "membership":
                cand = p0.payload["candidates"]
                padded = np.full(pow2_bucket(cand.shape[0], self.min_batch),
                                 dyn.n, np.int32)
                padded[:cand.shape[0]] = cand
                self._pad_add("membership", cand.shape[0], padded.shape[0])
                value = np.asarray(self.stream.membership(
                    p0.payload["u"], padded))[:cand.shape[0]]
                fp = Footprint.of(p0.payload["u"])
            elif kind == "tc":
                value = float(sess.triangle_count())
                fp = Footprint.whole_graph()
            elif kind == "cliques":
                if p0.payload["k"] == 5:
                    value = float(self.stream.five_clique_count())
                else:
                    value = float(self.stream.four_clique_count())
                fp = Footprint.whole_graph()
            else:  # pragma: no cover - guarded at submit time
                raise ValueError(kind)
            # frozen unconditionally: coalesced duplicates (and later cache
            # hits) all share this object — nobody gets to mutate it
            answers[key] = _freeze(value)
            if self.cache is not None:
                self.cache.put(key, value, fp, version)

        # fan out: every request id gets its key's (shared) answer
        for p in queue:
            lat = time.perf_counter() - p.t_submit
            res = QueryResult(p.request_id, p.kind, answers[p.key],
                              p.submitted_version, version, lat)
            self._h_latency.observe(lat)
            self._h_staleness.observe(res.staleness)
            self._c_served.inc()
            self.metrics.counter("server_served_total", kind=p.kind).inc()
            self._results[p.request_id] = res

    def stats(self) -> dict:
        """Serving counters: per-kind served/pad numbers, latency
        percentiles (only once something was served), coalescing and cache
        effectiveness.

        A view over :attr:`metrics` — every number below is read back from
        a registry instrument; the dict shape and values are bit-compatible
        with the pre-registry implementation (percentiles recomputed from
        the histogram's raw window with the same numpy calls).
        """
        by_kind = {dict(labels)["kind"]: inst.value
                   for labels, inst in
                   self.metrics.labelled("server_served_total").items()
                   if labels}
        pad = {name: (
            self.metrics.value("server_pad_rows", path=name, rows="real"),
            self.metrics.value("server_pad_rows", path=name, rows="padded"))
            for name in self._pad}
        out = {
            "served": self._c_served.value,
            "flushes": self._c_flushes.value,
            "coalesced": self._c_coalesced.value,
            "by_kind": by_kind,
            "pad_overhead": {
                name: (padded / real - 1.0 if real else 0.0)
                for name, (real, padded) in pad.items()},
        }
        if self._c_served.value:
            lat = self._h_latency.values()
            out["latency_mean_s"] = float(lat.mean())
            out["latency_p95_s"] = float(np.percentile(lat, 95))
            out["staleness_mean"] = float(np.mean(self._h_staleness.values()))
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out
