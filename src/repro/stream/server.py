"""Batched query serving over a live StreamSession, with a result cache.

Requests (similarity / link-prediction / membership / triangle-count /
local clustering) accumulate in a queue; ``flush()`` groups them, pads each
group to fixed batch shapes (powers of two, so XLA recompiles stay bounded
under arbitrary traffic), and answers everything through the engine seam —
one ``pair_cardinality_fn`` evaluation serves *all* pair-scored requests in
a flush, whatever similarity measure each asked for, because every measure
derives from |N_u ∩ N_v| + degrees (``similarity_from_cardinalities``).

Serving-tier layers riding on top of the batching:

* **Snapshot isolation**: every flush captures one published
  :class:`~repro.stream.session.ServingView` and answers everything from
  it, so queries run concurrently with delta application — a delta landing
  mid-flush builds and publishes version N+1 while the flush keeps serving
  a consistent version N. Each answer's ``answered_version`` names the
  snapshot it was computed at.
* **Result cache** (:class:`repro.stream.cache.ResultCache`, on by
  default): answers are keyed by ``(kind, canonical args)`` and carry the
  exact vertex :class:`~repro.engine.Footprint` they were computed from;
  the session's delta feed (``touched ∪ rebuilt``) evicts precisely the
  intersecting entries, so a hit is — under the strict error-budget
  policy — bit-identical to recomputing on the live graph.
* **Coalescing**: identical pending requests in one flush compute once and
  fan out to every request id; duplicate local-cluster seeds in one
  ``(alpha, eps)`` group collapse the same way (the canonical key *is* the
  dedup unit).
* **Admission policy**: optional ``max_batch`` (auto-flush when the queue
  fills) and ``max_wait_s`` (``poll()`` flushes once the oldest pending
  request has waited long enough), extended per tenant: every submit may
  carry ``tenant=`` and ``deadline_s=``, a ``tenant_quota`` sheds
  over-quota submits with :class:`OverloadError` (counted per tenant), and
  flushes serve requests earliest-deadline-first.
* **Background flush worker** (``async_flush=True``): a daemon thread
  applies the admission policy — flushing on ``max_batch``, ``max_wait_s``
  and deadline pressure — so submitters never pay flush latency inline and
  delta application overlaps query service. ``flush()``/``poll()``/
  ``drain()`` keep their contracts (flush bodies are serialized either
  way).

Each response carries per-query latency (submit → answer wall time) and
staleness (graph deltas applied between submit and answer) so a serving tier
above this can reason about freshness.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import threading
import time
import weakref
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.algorithms.similarity import similarity_from_cardinalities
from ..engine import api as eng
from ..engine.api import Footprint, pow2_bucket
from ..obs import accuracy, trace
from ..obs.metrics import MetricsRegistry
from .cache import ResultCache
from .session import StreamSession


class OverloadError(RuntimeError):
    """A submit was shed because its tenant's pending quota is exhausted.

    Raised synchronously by ``submit_*``; the shed is counted in
    ``server_shed_total{tenant=...}`` so overload accounting survives even
    when callers swallow the exception.
    """


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One answered request: value plus latency/staleness provenance."""

    request_id: int
    kind: str
    value: object
    submitted_version: int
    answered_version: int
    latency_s: float
    tenant: str = "default"
    deadline_missed: bool = False

    @property
    def staleness(self) -> int:
        """Graph deltas applied between submit and answer (0 == fresh)."""
        return self.answered_version - self.submitted_version


@dataclasses.dataclass
class _Pending:
    request_id: int
    kind: str   # similarity | linkpred | membership | tc | cliques | localcluster
    key: Tuple         # canonical (kind, args…) — the cache/coalescing unit
    measure: str
    pairs: Optional[np.ndarray]     # [P, 2] for similarity requests
    payload: dict
    submitted_version: int
    t_submit: float
    tenant: str = "default"
    deadline: Optional[float] = None   # absolute perf_counter() SLO deadline


def _edf_key(p: _Pending) -> Tuple[float, int]:
    # earliest-deadline-first, submission order among the deadline-free
    return (p.deadline if p.deadline is not None else math.inf, p.request_id)


def _freeze(value):
    """Recursively mark an answer's arrays read-only before caching/sharing.

    Deep, not shallow: hits and coalesced duplicates share the whole object
    graph, so a writable array nested anywhere (a list of arrays, a dict
    inside a dict) would let one caller poison every later reader of the
    same key.
    """
    if isinstance(value, np.ndarray):
        value.setflags(write=False)
    elif isinstance(value, dict):
        for item in value.values():
            _freeze(item)
    elif isinstance(value, (list, tuple)):
        for item in value:
            _freeze(item)
    return value


class BatchedQueryServer:
    """Accumulate-and-flush query server over one StreamSession.

    Args:
      stream:         the live session to serve from.
      min_batch:      pow2 padding floor shared by the pair, membership and
                      local-cluster seed batches (one compiled program per
                      size class, whatever the traffic).
      stats_window:   bounded latency/staleness window size.
      cache:          keep a footprint-invalidated result cache (default on;
                      answers stay bit-identical — see ``stream.cache``).
      cache_capacity: LRU entry bound for the cache.
      max_batch:      auto-flush as soon as this many requests are pending
                      (None = only explicit ``flush()``/``poll()``).
      max_wait_s:     ``poll()`` (or the async worker) flushes once the
                      oldest pending request has waited this long (None =
                      never due by age).
      async_flush:    run a background worker thread that applies the
                      admission policy (max_batch / max_wait_s / deadline
                      pressure), so submits return immediately and flushes
                      overlap delta application.
      tenant_quota:   per-tenant pending-request bound; submits beyond it
                      raise :class:`OverloadError` (None = unbounded).
      max_backlog:    async-mode high-water mark: a submit that finds this
                      many requests already queued blocks until the worker
                      drains below it (defaults to ``4 * max_batch``, or
                      256 when ``max_batch`` is None). A hot submitting
                      thread would otherwise outrun — and, through the GIL
                      plus lock convoy, starve — the worker, growing the
                      queue without bound so every answer lands at the
                      final drain. A full backlog is itself a flush trigger
                      for the worker, so submits can never block forever
                      even with no other admission policy configured.
    """

    # machine-checked lock discipline (tools/pgcheck PG001): these fields
    # may only be touched under the named lock(s) — `_cond` wraps `_lock`,
    # so holding either is holding the same mutex. `write:` specs leave
    # reads free: `cache` is an atomic published reference (flushes alias
    # it once and run on the alias), matching the serving-view pattern.
    _GUARDED_BY = {
        "_queue": "_lock|_cond",
        "_results": "_lock|_cond",
        "_next_id": "_lock|_cond",
        "_pending_tenant": "_lock|_cond",
        "_closed": "_lock|_cond",
        "_pad": "_lock|_cond",
        "_service_ewma": "_lock|_cond",
        "_listener": "_lock|_cond",
        "cache": "write:_lock|_cond",
    }

    # machine-checked footprint coverage (tools/pgcheck PG005, invariant 7):
    # every query kind this server submits must declare how its cached
    # answers are invalidated — an exact Footprint built in the flush path,
    # or a whole-graph marker (any delta invalidates).
    _KIND_FOOTPRINTS = {
        "similarity": "exact",
        "linkpred": "exact",
        "membership": "exact",
        "localcluster": "exact",
        "tc": "whole_graph",
        "cliques": "whole_graph",
    }

    def __init__(self, stream: StreamSession, min_batch: int = 64,
                 stats_window: int = 65536, cache: bool = True,
                 cache_capacity: int = 4096,
                 max_batch: Optional[int] = None,
                 max_wait_s: Optional[float] = None,
                 async_flush: bool = False,
                 tenant_quota: Optional[int] = None,
                 max_backlog: Optional[int] = None):
        self.stream = stream
        self.min_batch = int(min_batch)
        self.max_batch = None if max_batch is None else int(max_batch)
        self.max_wait_s = None if max_wait_s is None else float(max_wait_s)
        self.max_backlog = (int(max_backlog) if max_backlog is not None
                            else 4 * self.max_batch if self.max_batch
                            else 256)
        self.tenant_quota = None if tenant_quota is None else int(tenant_quota)
        self.cache = ResultCache(cache_capacity) if cache else None
        self._listener = None
        if self.cache is not None:
            # weakref-bound listener: a dropped server must not pin its
            # cache via the session's listener list, nor keep charging
            # every future delta for invalidating a dead cache — the
            # closure self-unsubscribes once the cache is collected
            cache_ref = weakref.ref(self.cache)
            stream_ref = weakref.ref(stream)

            def _invalidate(vertices, epoch):
                target = cache_ref()
                if target is None:
                    sess = stream_ref()
                    if sess is not None:
                        sess.remove_delta_listener(_invalidate)
                    return
                target.invalidate(vertices, epoch)

            self._listener = _invalidate
            stream.add_delta_listener(_invalidate)
        self._queue: List[_Pending] = []
        self._results: Dict[int, QueryResult] = {}
        self._next_id = 0
        self._pending_tenant: Dict[str, int] = {}
        # _lock guards queue/results/counters; _cond wakes the worker and
        # flush() waiters; _flush_lock serializes flush *bodies* so two
        # flushes never interleave their snapshot reads and cache puts
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._flush_lock = threading.Lock()
        self._closed = False
        self._service_ewma = 0.0       # smoothed flush service time (s)
        self._stats_window = int(stats_window)
        # serving counters live in the per-server metrics registry;
        # ``stats()`` is a bit-compatible view over these instruments
        self.metrics = MetricsRegistry()
        self._c_served = self.metrics.counter("server_served_total")
        self._c_flushes = self.metrics.counter("server_flushes_total")
        self._c_coalesced = self.metrics.counter("server_coalesced_total")
        # bounded windows: a long-lived server must not grow per-query state
        self._h_latency = self.metrics.histogram("server_latency_s",
                                                 window=stats_window)
        self._h_staleness = self.metrics.histogram("server_staleness",
                                                   window=stats_window)
        # per-path (real, padded) row counters — membership and seed batches
        # pad very differently from the shared pair pass, so they are not
        # lumped into one overhead number; the plain dict stays the write
        # surface (tests poke it), mirrored into the registry by _pad_add
        self._pad = {"pairs": [0, 0], "membership": [0, 0],
                     "localcluster": [0, 0]}
        for name in self._pad:
            self.metrics.counter("server_pad_rows", path=name, rows="real")
            self.metrics.counter("server_pad_rows", path=name, rows="padded")
        self._worker: Optional[threading.Thread] = None
        if async_flush:
            self._worker = threading.Thread(target=self._worker_loop,
                                            name="server-flush-worker",
                                            daemon=True)
            self._worker.start()

    @property
    def _served(self) -> int:
        return self._c_served.value

    @property
    def _flushes(self) -> int:
        return self._c_flushes.value

    @property
    def _coalesced(self) -> int:
        return self._c_coalesced.value

    def _pad_add(self, name: str, real: int, padded: int) -> None:
        """Meter one padded batch: real vs padded row counts for ``name``.

        Called from flush bodies, which run under ``_flush_lock`` but *not*
        ``_lock`` — the `+=` through the shared dict needs the lock or a
        concurrent ``stats()`` read can observe a torn (real, padded) pair.
        """
        with self._lock:
            self._pad[name][0] += real
            self._pad[name][1] += padded
        self.metrics.counter("server_pad_rows", path=name,
                             rows="real").inc(real)
        self.metrics.counter("server_pad_rows", path=name,
                             rows="padded").inc(padded)

    def close(self) -> None:
        """Flush-then-detach shutdown: answer everything pending, stop the
        worker, leave the session's invalidation feed, and drop the cache.

        Every request submitted before ``close()`` is answered and stays
        claimable through :meth:`drain`; submits after ``close()`` raise.
        With ``async_flush`` the worker performs the final flush and is
        joined before this returns. The cache is dropped because a detached
        server can no longer keep it honest.
        """
        with self._cond:
            first = not self._closed
            self._closed = True
            self._cond.notify_all()
        if self._worker is not None:
            # the worker's exit path flushes whatever is still queued
            self._worker.join()
            self._worker = None
        elif first:
            self._flush_queue()        # answer stranded sync-mode requests
        # detach under the lock so a racing close() cannot double-remove the
        # listener; the session call itself runs outside it (the session
        # takes its own _mutate_lock — never nest the two)
        with self._lock:
            listener, self._listener = self._listener, None
            self.cache = None
        if listener is not None:
            self.stream.remove_delta_listener(listener)

    @property
    def closed(self) -> bool:
        """True once :meth:`close` has been called."""
        with self._lock:
            return self._closed

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def _submit(self, kind: str, key: Tuple, measure: str = "",
                pairs: Optional[np.ndarray] = None, *,
                tenant: str = "default",
                deadline_s: Optional[float] = None, **payload) -> int:
        t_now = time.perf_counter()
        deadline = None if deadline_s is None else t_now + float(deadline_s)
        with self._lock:
            if self._closed:
                raise RuntimeError(
                    "BatchedQueryServer is closed; new submits are rejected "
                    "(close() answered everything submitted before it)")
            pending = self._pending_tenant.get(tenant, 0)
            if self.tenant_quota is not None and pending >= self.tenant_quota:
                self.metrics.counter("server_shed_total", tenant=tenant).inc()
                raise OverloadError(
                    f"tenant {tenant!r} has {pending} pending requests "
                    f"(quota {self.tenant_quota}); request shed")
            rid = self._next_id
            self._next_id += 1
            self._queue.append(_Pending(
                rid, kind, key, measure, pairs, payload,
                self.stream.serving_view().version, t_now, tenant, deadline))
            self._pending_tenant[tenant] = pending + 1
            due = (self.max_batch is not None
                   and len(self._queue) >= self.max_batch)
            if self._worker is not None:
                self._cond.notify_all()    # admission runs on the worker
                # backpressure: block until the worker drains below the
                # high-water mark — cond.wait releases _lock, so this is
                # also what hands the convoyed lock to the worker
                throttled = False
                while (len(self._queue) >= self.max_backlog
                       and not self._closed):
                    if not throttled:
                        self.metrics.counter(
                            "server_backpressure_total").inc()
                        throttled = True
                    self._cond.wait(0.05)
                return rid
        if due:
            self._flush_queue()
        return rid

    def submit_similarity(self, pairs, measure: str = "jaccard", *,
                          tenant: str = "default",
                          deadline_s: Optional[float] = None) -> int:
        """Score vertex pairs [P, 2] under any cardinality-derived measure."""
        # copy, not view: the key snapshots the bytes here, and the flush
        # computes from this array — a caller reusing its buffer must not
        # be able to poison the cache with a key/value mismatch
        pairs = np.array(pairs, dtype=np.int32, copy=True).reshape(-1, 2)
        key = ("similarity", measure, pairs.shape[0], pairs.tobytes())
        return self._submit("similarity", key, measure, pairs,
                            tenant=tenant, deadline_s=deadline_s)

    def submit_link_prediction(self, u: int, top_k: int = 8,
                               measure: str = "common", *,
                               tenant: str = "default",
                               deadline_s: Optional[float] = None) -> int:
        """Top-k predicted partners for u among its distance-2 non-neighbors
        (Listing-5 candidates, served online).

        The candidate set is materialized from the flush's serving snapshot,
        not here: with deltas interleaved between submit and flush, a
        submit-time candidate set would mix stale candidates (e.g. a vertex
        that became a neighbor still "predicted") with fresh scores.
        """
        key = ("linkpred", measure, int(u), int(top_k))
        return self._submit("linkpred", key, measure, u=int(u),
                            top_k=int(top_k), tenant=tenant,
                            deadline_s=deadline_s)

    def submit_membership(self, u: int, candidates, *,
                          tenant: str = "default",
                          deadline_s: Optional[float] = None) -> int:
        """x ∈ N_u membership tests (BF answers straight from the sketch)."""
        cand = np.array(candidates, dtype=np.int32, copy=True)  # see above
        key = ("membership", int(u), cand.shape[0], cand.tobytes())
        return self._submit("membership", key, u=int(u), candidates=cand,
                            tenant=tenant, deadline_s=deadline_s)

    def submit_triangle_count(self, *, tenant: str = "default",
                              deadline_s: Optional[float] = None) -> int:
        """Triangle-count query over the live graph (shared engine pass)."""
        return self._submit("tc", ("tc",), tenant=tenant,
                            deadline_s=deadline_s)

    def submit_clique_count(self, k: int = 4, *, tenant: str = "default",
                            deadline_s: Optional[float] = None) -> int:
        """k-clique-count query (k in {4, 5}) over the live graph.

        Both sizes fold every edge, so like ``tc`` they carry a whole-graph
        footprint: any delta invalidates a cached count. k = 5 runs through
        the engine's compiled 4-way AND set expression.
        """
        if k not in (4, 5):
            raise ValueError(f"clique count supports k in {{4, 5}}, got {k}")
        return self._submit("cliques", ("cliques", int(k)), k=int(k),
                            tenant=tenant, deadline_s=deadline_s)

    def submit_local_cluster(self, seed: int, alpha: float = 0.15,
                             eps: float = 1e-4, *, tenant: str = "default",
                             deadline_s: Optional[float] = None) -> int:
        """Seed-centric local cluster query (``localcluster(seed, α, ε)``).

        All localcluster requests sharing ``(alpha, eps)`` in one flush run
        as a single pow2-padded seed batch through the vmapped PPR push +
        sweep — the local-clustering analogue of the shared cardinality
        pass. Duplicate seeds in a group dedup through the canonical key
        and fan back out by request id. The answer value is a dict with
        ``members`` (int32[size] vertex ids of the best cluster),
        ``conductance``, ``size`` and ``support``.
        """
        key = ("localcluster", int(seed), float(alpha), float(eps))
        return self._submit("localcluster", key, seed=int(seed),
                            alpha=float(alpha), eps=float(eps),
                            tenant=tenant, deadline_s=deadline_s)

    def pending_count(self) -> int:
        """Number of submitted-but-unflushed requests."""
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def flush(self) -> Dict[int, QueryResult]:
        """Answer everything pending; return (and clear) unclaimed results.

        Results answered earlier by the admission policy (``max_batch`` /
        ``poll()`` / the async worker) and not yet drained are included.
        Synchronous in both modes: the flush body runs on the calling
        thread, serialized against the worker.
        """
        self._flush_queue()
        return self.drain()

    def poll(self) -> Dict[int, QueryResult]:
        """Apply the admission policy, then drain.

        Flushes when the queue holds ``max_batch`` requests or the oldest
        pending request has waited ``max_wait_s``; either way returns every
        answered-but-undrained result (possibly none). With ``async_flush``
        the worker applies the policy continuously, so ``poll()`` just
        drains.
        """
        if self._worker is None:
            with self._lock:
                due, _ = self._due_locked()
            if due:
                self._flush_queue()
        return self.drain()

    def drain(self) -> Dict[int, QueryResult]:
        """Return and clear every answered-but-unclaimed result."""
        with self._lock:
            out, self._results = self._results, {}
        return out

    # ------------------------------------------------------------------
    # background flush worker
    # ------------------------------------------------------------------

    def _due_locked(self) -> Tuple[bool, Optional[float]]:
        """Admission decision under ``_lock``: ``(due_now, wait_timeout)``.

        Due when the queue reached ``max_batch``, the oldest request aged
        past ``max_wait_s``, the earliest SLO deadline leaves less slack
        than one smoothed flush service time, or (async mode) the queue hit
        the ``max_backlog`` high-water mark. Otherwise returns how long the
        worker may sleep before the earliest of those can trip.
        """
        if not self._queue:
            return False, None
        if self.max_batch is not None and len(self._queue) >= self.max_batch:
            return True, None
        if self._worker is not None and len(self._queue) >= self.max_backlog:
            # a full backlog must always drain: with no max_batch /
            # max_wait_s and deadline-free submits nothing else ever comes
            # due, and the submitter blocked on the backpressure loop
            # cannot rescue itself with an explicit flush()
            return True, None
        now = time.perf_counter()
        timeouts = []
        if self.max_wait_s is not None:
            age = now - self._queue[0].t_submit
            if age >= self.max_wait_s:
                return True, None
            timeouts.append(self.max_wait_s - age)
        deadlines = [p.deadline for p in self._queue
                     if p.deadline is not None]
        if deadlines:
            slack = min(deadlines) - now - self._service_ewma
            if slack <= 0.0:
                return True, None
            timeouts.append(slack)
        return False, (min(timeouts) if timeouts else None)

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                due, timeout = self._due_locked()
                while not due and not self._closed:
                    self._cond.wait(timeout)
                    due, timeout = self._due_locked()
                if self._closed and not self._queue:
                    return            # final flush already happened below
            self._flush_queue()

    # ------------------------------------------------------------------
    # the flush itself
    # ------------------------------------------------------------------

    def _link_candidates(self, host, u: int) -> np.ndarray:
        """Distance-2 non-neighbors of ``u`` on the flush snapshot (sorted)."""
        nbrs = host.neighbors(int(u))
        cand = np.unique(np.concatenate(
            [host.neighbors(int(x)) for x in nbrs]
            or [np.zeros(0, np.int32)]))
        return cand[(cand != u) & ~np.isin(cand, nbrs)]

    def _flush_queue(self) -> None:
        """Answer every pending request: cache, coalesce, one batch per
        shape class for the misses, then fan out by request id."""
        with self._flush_lock:
            with self._lock:
                queue, self._queue = self._queue, []
                for p in queue:
                    left = self._pending_tenant.get(p.tenant, 1) - 1
                    if left > 0:
                        self._pending_tenant[p.tenant] = left
                    else:
                        self._pending_tenant.pop(p.tenant, None)
            if not queue:
                return
            queue.sort(key=_edf_key)        # earliest-deadline-first
            t0 = time.perf_counter()
            with trace.span("server.flush") as fsp:
                # read lease: pins the captured view against device-buffer
                # donation for the whole flush (a delta landing meanwhile
                # builds version N+1 without donating version N's buffers)
                snap = self.stream.acquire_serving_view()
                try:
                    self._flush_body(queue, snap, fsp)
                finally:
                    self.stream.release_serving_view(snap)
            dt = time.perf_counter() - t0
            # smoothed service-time estimate drives the worker's
            # deadline-pressure check (how early must a flush start so its
            # requests still make their SLOs); _due_locked reads it under
            # _lock, so the read-modify-write must hold it too
            with self._lock:
                self._service_ewma = (
                    dt if self._service_ewma == 0.0
                    else 0.8 * self._service_ewma + 0.2 * dt)
        with self._cond:
            self._cond.notify_all()          # wake poll()/flush() waiters

    def _flush_body(self, queue: List[_Pending], snap, fsp) -> None:
        """The traced body of :meth:`_flush_queue` (``fsp`` is its span).

        Snapshot-isolated: ``snap`` is one lease-held published ServingView
        captured by the caller, and the body reads *nothing* from the live
        session — deltas applied concurrently publish later views and
        cannot tear this flush.
        """
        self._c_flushes.inc()
        # one read of the published cache reference for the whole body: a
        # concurrent close() nulls self.cache, and re-reading it mid-flush
        # would turn that into an AttributeError between the None check and
        # the use (the alias keeps the cache alive until this flush ends)
        cache = self.cache
        sess = snap.session
        host = snap.host
        version = snap.version
        vol_now = 2.0 * host.m

        # coalesce: identical requests (same canonical key) compute once
        by_key: "collections.OrderedDict[Tuple, List[_Pending]]" = \
            collections.OrderedDict()
        for p in queue:
            by_key.setdefault(p.key, []).append(p)
        coalesced = len(queue) - len(by_key)
        self._c_coalesced.inc(coalesced)

        answers: Dict[Tuple, object] = {}
        misses: List[Tuple] = []
        with trace.span("cache.lookup", keys=len(by_key),
                        enabled=cache is not None) as csp:
            for key in by_key:
                if cache is not None:
                    hit = cache.get(
                        key, vol_now if key[0] == "localcluster" else None)
                    if hit is not None:
                        answers[key] = hit.value
                        continue
                misses.append(key)
            csp.set(hits=len(by_key) - len(misses))
        # invariant 8 provenance: every answer in this flush is attributable
        # to this span's cache/coalesce/pad accounting
        fsp.set(requests=len(queue), unique_keys=len(by_key),
                coalesced=coalesced, cache_hits=len(by_key) - len(misses),
                version=version, epoch=snap.epoch,
                tenants=len({p.tenant for p in queue}))

        # one shared cardinality pass for ALL uncached pair-scored requests;
        # link-prediction candidates materialize HERE, from the snapshot
        pair_keys: List[Tuple] = []
        pair_blocks: List[np.ndarray] = []
        lp_cand: Dict[Tuple, np.ndarray] = {}
        for key in misses:
            p0 = by_key[key][0]
            if p0.kind == "similarity":
                pair_keys.append(key)
                pair_blocks.append(p0.pairs)
            elif p0.kind == "linkpred":
                u = p0.payload["u"]
                cand = self._link_candidates(host, u)
                lp_cand[key] = cand
                pair_keys.append(key)
                pair_blocks.append(np.stack(
                    [np.full(cand.shape[0], u, np.int32),
                     cand.astype(np.int32)], axis=1))
        scores: Dict[Tuple, np.ndarray] = {
            key: np.zeros(0, np.float32) for key in pair_keys}
        total = sum(b.shape[0] for b in pair_blocks)
        if total:
            with trace.span("server.pair_batch", pairs=total) as psp:
                pairs = np.concatenate(pair_blocks, axis=0)
                padded = np.zeros((pow2_bucket(total, self.min_batch), 2),
                                  np.int32)
                padded[:total] = pairs
                self._pad_add("pairs", total, padded.shape[0])
                psp.set(padded=padded.shape[0])
                pairs_j = jnp.asarray(padded)
                with trace.span("engine.pair_cards",
                                pairs=padded.shape[0]) as ksp:
                    fn = eng.pair_cardinality_fn(sess.graph, sess.sketch,
                                                 sess.plan)
                    cards_j = eng.map_edges(pairs_j, fn, sess.plan)
                    ksp.fence(cards_j)
                # degrees gathered on device at the queried pairs only — a
                # full np.asarray(graph.deg) here would move O(n) bytes per
                # flush, against the streaming path's delta-sized-transfer
                # contract
                du_j = jnp.take(sess.graph.deg,
                                pairs_j[:, 0]).astype(jnp.float32)
                dv_j = jnp.take(sess.graph.deg,
                                pairs_j[:, 1]).astype(jnp.float32)
                # fence the gathers on the batch span before copying to
                # host: the asarray below would otherwise block inside the
                # span with the wait charged to whatever syncs first
                psp.fence((du_j, dv_j))
                cards = np.asarray(cards_j)
                du_all, dv_all = np.asarray(du_j), np.asarray(dv_j)
                if sess.sketch is not None:
                    # live error-interval estimate for the answers just
                    # computed (real rows only, padding excluded)
                    accuracy.record_pair_error(
                        sess.sketch, cards[:total], du_all[:total],
                        dv_all[:total], self.metrics)
                off = 0
                for key, block in zip(pair_keys, pair_blocks):
                    k = block.shape[0]
                    scores[key] = np.asarray(similarity_from_cardinalities(
                        jnp.asarray(cards[off:off + k]),
                        jnp.asarray(du_all[off:off + k]),
                        jnp.asarray(dv_all[off:off + k]),
                        by_key[key][0].measure))
                    off += k

        # one batched push + sweep per (alpha, eps) group of uncached seeds
        # (seeds are unique per group by construction: the key dedups them;
        # groups run in EDF order because the queue was EDF-sorted)
        lc_groups: "collections.OrderedDict[Tuple, List[Tuple]]" = \
            collections.OrderedDict()
        for key in misses:
            if key[0] == "localcluster":
                lc_groups.setdefault(key[2:], []).append(key)
        deg_host = host.deg
        for (alpha, eps), group in lc_groups.items():
            seeds = np.array([key[1] for key in group], np.int32)
            # pad with a repeat of the first seed (dropped below); the same
            # pow2 floor as the pair path keeps one compiled push/sweep per
            # batch size class
            padded = np.full(pow2_bucket(seeds.size, self.min_batch),
                             seeds[0], np.int32)
            padded[:seeds.size] = seeds
            self._pad_add("localcluster", int(seeds.size), padded.shape[0])
            with trace.span("server.localcluster_batch",
                            seeds=int(seeds.size), padded=padded.shape[0],
                            alpha=float(alpha), eps=float(eps)) as lsp:
                res = sess.local_cluster(padded, alpha=alpha, eps=eps)
                lsp.set(sparse=res.frontier is not None,
                        spilled=bool(res.spilled))
                lsp.fence(res.best_conductance)
            sizes = np.asarray(res.best_size)
            phis = np.asarray(res.best_conductance)
            sup = np.asarray(res.support)
            order = np.asarray(res.order)
            for i, key in enumerate(group):
                value = {
                    # .copy(): a bare slice would pin the whole padded
                    # [S, n] order matrix for as long as the answer lives
                    "members": order[i, :sizes[i]].copy(),
                    "conductance": float(phis[i]),
                    "size": int(sizes[i]),
                    "support": int(sup[i]),
                }
                # frozen even with the cache off: coalesced duplicates
                # share this object across request ids
                answers[key] = _freeze(value)
                if cache is not None:
                    # conductance reads the total volume through
                    # min(vol, 2m − vol): cache only clusters provably on
                    # the small side, guarded against later volume drift
                    swept = order[i, :sup[i]]
                    swept = swept[swept < host.n]
                    max2vol = 2.0 * float(deg_host[swept].sum())
                    if cache.cacheable(max2vol, vol_now):
                        fp = Footprint.of(res.footprint(i), key[1])
                        cache.put(key, value, fp, version,
                                  max2vol=max2vol, vol_total=vol_now,
                                  epoch=snap.epoch)

        # remaining miss kinds + cache fills
        for key in misses:
            kind = key[0]
            if kind == "localcluster":
                continue                       # answered in the group pass
            p0 = by_key[key][0]
            if kind == "similarity":
                value = scores[key]
                fp = Footprint.of(p0.pairs)
            elif kind == "linkpred":
                s = scores[key]
                cand = lp_cand[key]
                top = np.argsort(-s, kind="stable")[:p0.payload["top_k"]]
                value = {"candidates": cand[top], "scores": s[top]}
                # the candidate set itself is a function of N(u)'s rows: a
                # new edge at any neighbor mints a new candidate, so the
                # footprint is {u} ∪ N(u) ∪ candidates
                u = p0.payload["u"]
                fp = Footprint.of(u, host.neighbors(u), cand)
            elif kind == "membership":
                cand = p0.payload["candidates"]
                padded = np.full(pow2_bucket(cand.shape[0], self.min_batch),
                                 host.n, np.int32)
                padded[:cand.shape[0]] = cand
                self._pad_add("membership", cand.shape[0], padded.shape[0])
                value = np.asarray(snap.membership(
                    p0.payload["u"], padded))[:cand.shape[0]]
                fp = Footprint.of(p0.payload["u"])
            elif kind == "tc":
                value = float(sess.triangle_count())
                fp = Footprint.whole_graph()
            elif kind == "cliques":
                if p0.payload["k"] == 5:
                    value = float(sess.five_clique_count())
                else:
                    value = float(sess.four_clique_count())
                fp = Footprint.whole_graph()
            else:  # pragma: no cover - guarded at submit time
                raise ValueError(kind)
            # frozen unconditionally: coalesced duplicates (and later cache
            # hits) all share this object — nobody gets to mutate it
            answers[key] = _freeze(value)
            if cache is not None:
                cache.put(key, value, fp, version, epoch=snap.epoch)

        # fan out: every request id gets its key's (shared) answer
        misses_deadline = 0
        with self._lock:
            for p in queue:
                t_now = time.perf_counter()
                lat = t_now - p.t_submit
                missed = p.deadline is not None and t_now > p.deadline
                misses_deadline += missed
                res = QueryResult(p.request_id, p.kind, answers[p.key],
                                  p.submitted_version, version, lat,
                                  p.tenant, missed)
                self._h_latency.observe(lat)
                self._h_staleness.observe(res.staleness)
                self._c_served.inc()
                self.metrics.counter("server_served_total", kind=p.kind).inc()
                self.metrics.counter("server_tenant_served_total",
                                     tenant=p.tenant).inc()
                self.metrics.histogram("server_tenant_latency_s",
                                       window=self._stats_window,
                                       tenant=p.tenant).observe(lat)
                if missed:
                    self.metrics.counter("server_deadline_miss_total",
                                         tenant=p.tenant).inc()
                self._results[p.request_id] = res
        fsp.set(deadline_misses=misses_deadline)

    def stats(self) -> dict:
        """Serving counters: per-kind served/pad numbers, latency
        percentiles (only once something was served), coalescing, cache
        effectiveness, and per-tenant admission accounting (served / shed /
        deadline misses / latency tail) once any tenant-labelled traffic
        exists.

        A view over :attr:`metrics` — every number below is read back from
        a registry instrument; the dict shape and values are bit-compatible
        with the pre-registry implementation (percentiles recomputed from
        the histogram's raw window with the same numpy calls).
        """
        by_kind = {dict(labels)["kind"]: inst.value
                   for labels, inst in
                   self.metrics.labelled("server_served_total").items()
                   if labels}
        with self._lock:
            pad_names = list(self._pad)
        pad = {name: (
            self.metrics.value("server_pad_rows", path=name, rows="real"),
            self.metrics.value("server_pad_rows", path=name, rows="padded"))
            for name in pad_names}
        out = {
            "served": self._c_served.value,
            "flushes": self._c_flushes.value,
            "coalesced": self._c_coalesced.value,
            "by_kind": by_kind,
            "pad_overhead": {
                name: (padded / real - 1.0 if real else 0.0)
                for name, (real, padded) in pad.items()},
        }
        if self._c_served.value:
            lat = self._h_latency.values()
            out["latency_mean_s"] = float(lat.mean())
            out["latency_p95_s"] = float(np.percentile(lat, 95))
            out["staleness_mean"] = float(np.mean(self._h_staleness.values()))
        tenants: Dict[str, dict] = {}
        for name, field in (("server_tenant_served_total", "served"),
                            ("server_shed_total", "shed"),
                            ("server_deadline_miss_total",
                             "deadline_missed")):
            for labels, inst in self.metrics.labelled(name).items():
                t = dict(labels)["tenant"]
                tenants.setdefault(t, {"served": 0, "shed": 0,
                                       "deadline_missed": 0})[field] = \
                    inst.value
        for labels, inst in \
                self.metrics.labelled("server_tenant_latency_s").items():
            vals = inst.values()
            if vals.size:
                tenants.setdefault(
                    dict(labels)["tenant"],
                    {"served": 0, "shed": 0, "deadline_missed": 0}).update(
                    latency_p50_s=float(np.percentile(vals, 50)),
                    latency_p95_s=float(np.percentile(vals, 95)),
                    latency_p99_s=float(np.percentile(vals, 99)))
        if tenants:
            out["tenants"] = tenants
            out["shed"] = sum(t["shed"] for t in tenants.values())
        cache = self.cache              # one read; close() may null it
        if cache is not None:
            out["cache"] = cache.stats()
        return out
