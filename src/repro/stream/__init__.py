"""Streaming graph subsystem: incremental sketch maintenance + serving.

The batch engine (``repro.engine``) answers many queries over one frozen
graph; this package makes the graph itself mutable without giving up the
sketches. Bloom inserts are monotone ORs and MinHash/KMV inserts are
min-merges, so edge deltas are absorbed incrementally (bit-identical to a
from-scratch rebuild); deletions mark rows dirty and are repaired by
selective rebuild under an error-budget policy driven by the paper's own
accuracy bounds.

    from repro.stream import stream_session, BatchedQueryServer
    st = stream_session(graph, "bf", storage_budget=0.25)
    st.apply_delta(inserts=new_edges, deletes=gone_edges)
    server = BatchedQueryServer(st)
    rid = server.submit_similarity(pairs, "jaccard")
    answer = server.flush()[rid]          # .value, .latency_s, .staleness
"""
from .cache import CacheEntry, ResultCache
from .dynamic_graph import (DeltaResult, DeviceGraphState, DynamicGraph,
                            HostGraphSnapshot, TrafficMeter)
from .maintenance import STRICT_POLICY, ErrorBudgetPolicy, SketchMaintainer
from .server import BatchedQueryServer, OverloadError, QueryResult
from .session import ServingView, StreamSession, stream_session

__all__ = [
    "CacheEntry", "ResultCache",
    "DeltaResult", "DeviceGraphState", "DynamicGraph", "HostGraphSnapshot",
    "TrafficMeter",
    "ErrorBudgetPolicy", "SketchMaintainer", "STRICT_POLICY",
    "BatchedQueryServer", "OverloadError", "QueryResult",
    "ServingView", "StreamSession", "stream_session",
]
