"""Sharded checkpointing with atomic publish, async writes and elastic restore.

Layout:  <dir>/step_<N>/   leaf files "<flattened.path>.npy" + meta.json
         <dir>/step_<N>.tmp.<pid> during write (renamed atomically on success)

Fault-tolerance contract:
  * a crash mid-save never corrupts the latest checkpoint (tmp dir + rename)
  * `keep` most-recent checkpoints are retained (bounded disk)
  * restore accepts a *different* mesh/sharding than the one that saved —
    leaves are loaded as host arrays and re-placed with the target shardings
    (elastic scaling: resume a 512-chip run on 256 chips or vice versa)
  * AsyncCheckpointer overlaps serialization with the next train steps and
    is drained on exit (no torn writes on clean shutdown)
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"idx{p.idx}"
    return str(p)


def save_checkpoint(directory: str, step: int, tree, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + f".tmp.{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, key + ".npy"), arr)
        meta["leaves"][key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def _list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "meta.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def load_meta(directory: str, step: int) -> Dict[str, Any]:
    """Read a checkpoint's meta.json (leaf shapes/dtypes) without loading the
    arrays — enough to build a ShapeDtypeStruct target tree for restore when
    the caller does not know the saved shapes (e.g. a streamed graph whose
    edge count grew since the snapshot)."""
    with open(os.path.join(directory, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None):
    """Restore into the structure of `target_tree` (arrays or ShapeDtypeStruct).

    `shardings`: optional pytree of NamedSharding matching target_tree — when
    given, leaves are placed with those shardings (elastic resharding).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, tgt in flat_target.items():
        arr = np.load(os.path.join(path, key + ".npy"))
        want_dtype = np.dtype(getattr(tgt, "dtype", arr.dtype))
        if arr.dtype != want_dtype:
            arr = arr.astype(want_dtype)
        sh = flat_shard.get(key)
        loaded[key] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
    # rebuild tree in target structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(target_tree)[0]
    tdef = jax.tree_util.tree_structure(target_tree)
    ordered = [loaded[_SEP.join(_path_str(p) for p in path_)] for path_, _ in leaves_paths]
    return jax.tree_util.tree_unflatten(tdef, ordered)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with bounded queue depth 1."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
