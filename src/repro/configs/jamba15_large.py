"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 (hf).

72L in 9 periods of 8 (1 attention + 7 mamba, the paper's 1:7 interleave):
d_model 8192, attention 64 heads GQA (kv=8, head_dim 128); SSM blocks use
the SSD formulation (state 128, head_dim 64). MoE (16 experts, top-2,
expert d_ff 24576) on every other layer. vocab 65536. SSM-dominated state
=> runs the ``long_500k`` cell (attention KV is 9 layers only).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba15_large",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    act="silu",
    layer_pattern=("attn", "ssm", "ssm", "ssm", "ssm", "ssm", "ssm", "ssm"),
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    moe_layer_start=1,
    moe_every=2,
    ssm=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    ssm_conv=4,
    rope_theta=10_000.0,
)
