"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full ModelConfig; ``registry()`` lists all ids.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_ARCH_IDS: List[str] = [
    "gemma_2b",
    "codeqwen15_7b",
    "h2o_danube3_4b",
    "qwen3_8b",
    "phi35_moe",
    "deepseek_v3",
    "musicgen_large",
    "mamba2_130m",
    "qwen2_vl_72b",
    "jamba15_large",
]

_ALIASES = {
    "gemma-2b": "gemma_2b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen3-8b": "qwen3_8b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "deepseek-v3-671b": "deepseek_v3",
    "musicgen-large": "musicgen_large",
    "mamba2-130m": "mamba2_130m",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "jamba-1.5-large-398b": "jamba15_large",
}


def get(name: str) -> ModelConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def registry() -> List[str]:
    return list(_ARCH_IDS)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in _ARCH_IDS}


# which shape cells apply per arch (per DESIGN.md §Arch-applicability):
# long_500k only for sub-quadratic decode (ssm / hybrid / sliding-window)
LONG_CONTEXT_ARCHS = {"mamba2_130m", "jamba15_large", "h2o_danube3_4b"}


def shapes_for(arch: str) -> List[str]:
    arch = _ALIASES.get(arch, arch)
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_ARCHS:
        shapes.append("long_500k")
    return shapes
