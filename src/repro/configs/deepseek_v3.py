"""deepseek-v3-671b [moe] — arXiv:2412.19437 (hf).

61L, d_model 7168, 128 heads with MLA (q_lora 1536, kv_lora 512,
qk_nope 128, qk_rope 64, v 128), vocab 129280. First 3 layers dense
(d_ff 18432), remaining 58 layers MoE: 256 routed experts top-8 + 1 shared,
expert d_ff 2048. MTP (multi-token prediction) heads are a training-loss
add-on, not a backbone change — omitted and noted in DESIGN.md.

Decode uses the absorbed-matrix MLA path: the KV cache stores only the
compressed (kv_lora + rope) stream — this is the memory feature that makes
decode_32k fit.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_v3",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=192,            # qk_nope + qk_rope (derived; MLA path governs)
    d_ff=18432,
    vocab_size=129280,
    act="silu",
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    moe_num_experts=256,
    moe_top_k=8,
    moe_d_ff=2048,
    moe_shared_experts=1,
    moe_layer_start=3,
    moe_every=1,
    rope_theta=10_000.0,
)
