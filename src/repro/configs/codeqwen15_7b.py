"""codeqwen1.5-7b [dense] — hf:Qwen/CodeQwen1.5-7B.

32L, d_model 4096, 32 heads MHA (kv=32), head_dim 128, SwiGLU d_ff 13440,
vocab 92416. (QKV biases of the qwen1.5 family are omitted — bias terms are
<0.01% of params and do not change sharding or roofline terms.)
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen15_7b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    act="silu",
    rope_theta=1_000_000.0,
)
