"""musicgen-large [audio] — arXiv:2306.05284 (hf).

48L decoder-only over EnCodec tokens: d_model 2048, 32 heads MHA (kv=32),
head_dim 64, d_ff 8192, vocab 2048 (one codebook head). The EnCodec frontend
and the 4-codebook delay-pattern interleave are the modality STUB:
``input_specs()`` provides precomputed frame embeddings [B, S, d_model]
(sum of codebook embeddings), per the assignment brief.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen_large",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    input_mode="embeddings",
    rope_theta=10_000.0,
)
