"""phi3.5-moe-42b-a6.6b [moe] — hf:microsoft/Phi-3.5-MoE-instruct.

32L, d_model 4096, 32 heads GQA (kv=8), head_dim 128, vocab 32064,
16 experts top-2 with expert d_ff 6400 in every layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi35_moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    act="silu",
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=6400,
    moe_layer_start=0,
    moe_every=1,
    rope_theta=10_000.0,
)
