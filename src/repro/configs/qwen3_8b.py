"""qwen3-8b [dense] — hf:Qwen/Qwen3-8B.

36L, d_model 4096, 32 heads GQA (kv=8), head_dim 128, SwiGLU d_ff 12288,
vocab 151936, per-head qk RMS-norm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_8b",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    act="silu",
    qk_norm=True,
    rope_theta=1_000_000.0,
)
