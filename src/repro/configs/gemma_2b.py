"""gemma-2b [dense] — arXiv:2403.08295 (hf).

18L, d_model 2048, 8 heads with MQA (kv=1), head_dim 256, GeGLU d_ff 16384,
vocab 256000, tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma_2b",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)
