"""h2o-danube-3-4b [dense] — arXiv:2401.16818 (unverified tier).

24L, d_model 3840, 32 heads GQA (kv=8), head_dim 120, SwiGLU d_ff 10240,
vocab 32000, mistral-style sliding-window attention (window 4096). The SWA
ring-buffer KV cache bounds decode state, so this arch runs the ``long_500k``
cell (DESIGN.md §Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o_danube3_4b",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    act="silu",
    sliding_window=4096,
    rope_theta=10_000.0,
)
