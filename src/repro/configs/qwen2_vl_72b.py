"""qwen2-vl-72b [vlm] — arXiv:2409.12191 (hf).

80L backbone: d_model 8192, 64 heads GQA (kv=8), head_dim 128, SwiGLU
d_ff 29568, vocab 152064, M-RoPE with (t, h, w) sections (16, 24, 24) over
the 64 half-dim frequencies. The dynamic-resolution ViT frontend is the
modality STUB: ``input_specs()`` provides precomputed patch embeddings
[B, S, d_model] plus [B, S, 3] M-RoPE positions.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_72b",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    act="silu",
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    input_mode="embeddings",
    rope_theta=1_000_000.0,
)
