"""mamba2-130m [ssm] — arXiv:2405.21060 (unverified tier).

24L attention-free SSD blocks: d_model 768, expand 2 (d_inner 1536),
ssm_state 128, head_dim 64 (24 ssm heads), vocab 50280. O(1) decode state
=> runs the ``long_500k`` cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2_130m",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    ssm_conv=4,
    tie_embeddings=True,
)
