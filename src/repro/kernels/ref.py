"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bf_intersect_pairs(a: jax.Array, b: jax.Array) -> jax.Array:
    """popcount(a AND b) summed over words. a, b: uint32[E, W] -> int32[E]."""
    return jnp.sum(jax.lax.population_count(a & b), axis=-1).astype(jnp.int32)


def bf_union_pairs(a: jax.Array, b: jax.Array) -> jax.Array:
    """popcount(a OR b) summed over words (for the OR estimator)."""
    return jnp.sum(jax.lax.population_count(a | b), axis=-1).astype(jnp.int32)


def bf_intersect3_pairs(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """popcount(a AND b AND c) (4-clique triple intersections)."""
    return jnp.sum(jax.lax.population_count(a & b & c), axis=-1).astype(jnp.int32)


def bf_edge_intersect(bloom: jax.Array, edges: jax.Array) -> jax.Array:
    """Gather rows u, v from bloom[n, W] per edge and AND-popcount."""
    a = jnp.take(bloom, edges[:, 0], axis=0)
    b = jnp.take(bloom, edges[:, 1], axis=0)
    return bf_intersect_pairs(a, b)


def bf_edge_intersect3(bloom: jax.Array, triples: jax.Array) -> jax.Array:
    """Gather rows u, v, w from bloom[n, W] per triple and AND-popcount."""
    a = jnp.take(bloom, triples[:, 0], axis=0)
    b = jnp.take(bloom, triples[:, 1], axis=0)
    c = jnp.take(bloom, triples[:, 2], axis=0)
    return bf_intersect3_pairs(a, b, c)


def mh_intersect_pairs(a: jax.Array, b: jax.Array, sentinel: int) -> jax.Array:
    """|set(a) ∩ set(b)| for sentinel-padded duplicate-free int32[E, k] rows."""
    eq = a[..., :, None] == b[..., None, :]
    valid = (a[..., :, None] < sentinel) & (b[..., None, :] < sentinel)
    return jnp.sum(eq & valid, axis=(-2, -1)).astype(jnp.int32)


def khash_match_pairs(a: jax.Array, b: jax.Array, sentinel: int) -> jax.Array:
    """Aligned (per-hash-function) match count for k-Hash sketches."""
    return jnp.sum((a == b) & (a < sentinel) & (b < sentinel), axis=-1).astype(jnp.int32)


def causal_attention(q, k, v, window: int = 0):
    """Plain causal (optionally sliding-window) attention oracle.

    q: [B,S,H,D], k/v: [B,S,KV,D] -> [B,S,H,D]; fp32 softmax.
    """
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bqkgd,bckd->bkgqc", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    qpos = jnp.arange(s)[:, None]
    cpos = jnp.arange(s)[None, :]
    mask = cpos <= qpos
    if window:
        mask &= cpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)
