"""Pallas TPU kernel for MinHash/KMV sketch intersections.

CPU ProbGraph merges two sorted k-element lists. A data-dependent merge
serializes on the VPU, so the TPU-native form is a dense O(k²) equality
compare — for k ≤ ~256 the k² lane-parallel compares are cheaper than a
length-2k sequential merge, and the op keeps the fixed-shape / fixed-work
property that makes ProbGraph shardable.

Also provides the aligned k-Hash match kernel (elementwise, O(k)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mh_kernel(a_ref, b_ref, o_ref, *, sentinel: int):
    a = a_ref[...]
    b = b_ref[...]
    eq = (a[:, :, None] == b[:, None, :])
    valid = (a[:, :, None] < sentinel) & (b[:, None, :] < sentinel)
    o_ref[...] = jnp.sum(eq & valid, axis=(1, 2)).astype(jnp.int32)


def mh_intersect_pairs(a: jax.Array, b: jax.Array, sentinel: int, *,
                       block_e: int = 128, interpret: bool = False) -> jax.Array:
    """int32[E, k] x int32[E, k] -> int32[E] distinct-element intersections."""
    e, k = a.shape
    block_e = min(block_e, e)
    grid = (pl.cdiv(e, block_e),)
    import functools
    return pl.pallas_call(
        functools.partial(_mh_kernel, sentinel=sentinel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, k), lambda i: (i, 0)),
            pl.BlockSpec((block_e, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(a, b)


def _khash_kernel(a_ref, b_ref, o_ref, *, sentinel: int):
    a = a_ref[...]
    b = b_ref[...]
    m = (a == b) & (a < sentinel) & (b < sentinel)
    o_ref[...] = jnp.sum(m, axis=1).astype(jnp.int32)


def khash_match_pairs(a: jax.Array, b: jax.Array, sentinel: int, *,
                      block_e: int = 512, interpret: bool = False) -> jax.Array:
    """Aligned per-hash-function match counts (k-Hash Jaccard numerator)."""
    e, k = a.shape
    block_e = min(block_e, e)
    grid = (pl.cdiv(e, block_e),)
    import functools
    return pl.pallas_call(
        functools.partial(_khash_kernel, sentinel=sentinel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, k), lambda i: (i, 0)),
            pl.BlockSpec((block_e, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(a, b)
