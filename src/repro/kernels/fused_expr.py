"""Fused Pallas evaluation of set-algebra expressions over sketch rows.

The SISA layer's code generator target: ``repro.engine.setexpr`` lowers a
``SetExpr`` tree (k-way AND/OR/ANDNOT over Bloom rows, popcount-reduced) to
*one* call into this module instead of one hand-rolled kernel per workload.
Two lowered forms cover every current consumer:

  * :func:`fused_gather_popcount` — the block-gather form generalizing
    ``bf_intersect._edge_block_kernel`` / ``_edge3_block_kernel`` to an
    arbitrary slab count: per (block_e, block_w) grid step, one pipelined
    DMA burst (``bf_intersect._gather_rows``) pulls every referenced sketch
    row of the tuple block from the ANY/HBM-resident matrix into one VMEM
    slab per expression leaf, the bitwise tree is evaluated in registers,
    and the popcount reduction accumulates over the word-grid axis.
  * :func:`fused_rows_popcount` — the dense form generalizing
    ``bf_intersect._pairs_kernel`` / ``_pairs3_kernel``: operand rows are
    already materialized ``[E, W]`` matrices (the sweep-cut prefix filter is
    computed, not gathered), tiled (block_e × block_w) with the same
    accumulate-over-word-tiles discipline.

Both forms take the expression as ``eval_fn``: a pure function from a tuple
of uint32 word arrays (one per leaf slab, identical shapes) to one uint32
word array. The same callable evaluates the tree on VMEM slab values inside
the kernel and on gathered jnp arrays in the engine's fallback path, which
is what makes kernel/jnp popcounts bit-identical by construction.

Padding contracts match the legacy kernels: the tuple/row count must be a
multiple of ``block_e`` (pad gather indices with 0 — row 0 always exists —
and dense rows with zero words) and W a multiple of ``block_w`` (zero words
contribute no bits). ``repro.engine.setexpr`` pads and slices; see
`docs/ARCHITECTURE.md <../../../docs/ARCHITECTURE.md#kernel-layer-the-set-expression-compiler>`__
for the data flow.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .bf_intersect import _gather_rows

EvalFn = Callable[[Tuple[jax.Array, ...]], jax.Array]


def _popcount_accumulate(o_ref, j, val) -> None:
    """Init the output block at the first word tile, then accumulate the
    popcount reduction of one evaluated (block_e, block_w) slab."""
    @pl.when(j == 0)
    def _init():
        """Zero the per-block output on the first word-grid step."""
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(val)
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def _gather_expr_kernel(*refs, eval_fn: EvalFn, arity: int, block_e: int,
                        block_w: int):
    """Block-gather kernel body: DMA ``arity`` rows per tuple, evaluate the
    expression on the slabs, popcount-accumulate (positional refs are the
    ``arity`` prefetched index arrays, the sketch matrix, the output block,
    the ``arity`` VMEM scratch slabs, and the DMA semaphore array)."""
    idx_refs = refs[:arity]
    bloom_ref = refs[arity]
    o_ref = refs[arity + 1]
    bufs = refs[arity + 2:arity + 2 + arity]
    sems = refs[arity + 2 + arity]
    i = pl.program_id(0)
    j = pl.program_id(1)
    _gather_rows(idx_refs, i * block_e, bloom_ref, bufs, sems,
                 count=block_e, block_w=block_w, j=j)
    _popcount_accumulate(o_ref, j, eval_fn(tuple(buf[...] for buf in bufs)))


def fused_gather_popcount(bloom: jax.Array, cols: Sequence[jax.Array],
                          eval_fn: EvalFn, *, block_e: int = 8,
                          block_w: int = 512,
                          interpret: bool = False) -> jax.Array:
    """One fused VMEM pass over gathered sketch rows: int32[T] popcounts.

    Args:
      bloom:    uint32[n, W] sketch matrix (stays in ANY/HBM; rows are
                DMA-gathered per block). W must be a multiple of ``block_w``.
      cols:     one int32[T] row-index array per expression leaf (scalar-
                prefetched to SMEM). T must be a multiple of ``block_e``.
      eval_fn:  bitwise expression evaluator over the gathered slabs.
      block_e:  tuples per grid step (rows DMAed per burst, per slab).
      block_w:  sketch words per grid step.
      interpret: run the kernel body in Python (non-TPU backends).

    Returns:
      int32[T] — popcount of the evaluated expression row per tuple.
    """
    arity = len(cols)
    t = cols[0].shape[0]
    n, w = bloom.shape
    grid = (pl.cdiv(t, block_e), pl.cdiv(w, block_w))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=arity,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
        out_specs=pl.BlockSpec((block_e,), lambda i, j, *_: (i,)),
        scratch_shapes=(
            [pltpu.VMEM((block_e, block_w), jnp.uint32)] * arity
            + [pltpu.SemaphoreType.DMA((arity,))]),
    )
    kern = functools.partial(_gather_expr_kernel, eval_fn=eval_fn,
                             arity=arity, block_e=block_e, block_w=block_w)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t,), jnp.int32),
        interpret=interpret,
    )(*cols, bloom)


def _rows_expr_kernel(*refs, eval_fn: EvalFn):
    """Dense kernel body: evaluate the expression on the operand blocks and
    popcount-accumulate over the word-tile grid axis."""
    *in_refs, o_ref = refs
    j = pl.program_id(1)
    _popcount_accumulate(o_ref, j, eval_fn(tuple(r[...] for r in in_refs)))


def fused_rows_popcount(rows: Sequence[jax.Array], eval_fn: EvalFn, *,
                        block_e: int = 256, block_w: int = 512,
                        interpret: bool = False) -> jax.Array:
    """One fused pass over dense operand rows: int32[E] popcounts.

    Args:
      rows:     one uint32[E, W] operand matrix per expression leaf (already
                materialized — e.g. the sweep cut's computed prefix filter).
                E must be a multiple of ``block_e`` and W of ``block_w``.
      eval_fn:  bitwise expression evaluator over the operand blocks.
      block_e:  rows per grid step.
      block_w:  words per grid step.
      interpret: run the kernel body in Python (non-TPU backends).

    Returns:
      int32[E] — popcount of the evaluated expression row per input row.
    """
    e, w = rows[0].shape
    grid = (pl.cdiv(e, block_e), pl.cdiv(w, block_w))
    spec = pl.BlockSpec((block_e, block_w), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_rows_expr_kernel, eval_fn=eval_fn),
        grid=grid,
        in_specs=[spec] * len(rows),
        out_specs=pl.BlockSpec((block_e,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(*rows)


__all__ = ["EvalFn", "fused_gather_popcount", "fused_rows_popcount"]
