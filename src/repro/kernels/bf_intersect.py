"""Pallas TPU kernels for Bloom-filter set algebra (ProbGraph hot loop).

The paper's CPU hot loop is `popcnt(AND(Bx, By))` over AVX lanes; the TPU
adaptation runs it on the VPU (8×128 lanes) with explicit VMEM tiling:

  * ``bf_intersect_pairs_kernel``: dense [E, W] x [E, W] -> [E] AND+popcount,
    tiled (block_e × block_w), accumulating over the word-tile grid axis.
    This is the roofline-friendly form: arithmetic intensity is fixed
    (1 AND + 1 popcount + 1 add per 8 bytes), so the kernel is HBM-bound and
    tiles are chosen to stream at full bandwidth.

  * ``bf_edge_intersect_kernel``: the fused-gather form. The edge list lives
    in SMEM via PrefetchScalarGridSpec; the BlockSpec ``index_map`` reads the
    row ids and DMAs the two Bloom rows straight from the sketch matrix in
    HBM — no [E, W] gather is ever materialized. This is the TPU-idiomatic
    replacement of the CPU pointer-gather, and saves 2·E·W words of HBM
    round-trip when E ≫ n (skewed graphs revisit hub rows, which then stay
    in VMEM across consecutive edges).

  * 3-way AND variant for the 4-clique triple intersections.

All kernels validate in interpret mode against ``ref.py`` (see tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ----------------------------------------------------------------------------
# dense pairs kernel
# ----------------------------------------------------------------------------

def _pairs_kernel(a_ref, b_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(a_ref[...] & b_ref[...])
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def bf_intersect_pairs(a: jax.Array, b: jax.Array, *, block_e: int = 256,
                       block_w: int = 512, interpret: bool = False) -> jax.Array:
    """uint32[E, W] x uint32[E, W] -> int32[E]; E, W already block-padded."""
    e, w = a.shape
    block_e = min(block_e, e)
    block_w = min(block_w, w)
    grid = (pl.cdiv(e, block_e), pl.cdiv(w, block_w))
    return pl.pallas_call(
        _pairs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, block_w), lambda i, j: (i, j)),
            pl.BlockSpec((block_e, block_w), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(a, b)


def _pairs3_kernel(a_ref, b_ref, c_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(a_ref[...] & b_ref[...] & c_ref[...])
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def bf_intersect3_pairs(a: jax.Array, b: jax.Array, c: jax.Array, *,
                        block_e: int = 256, block_w: int = 512,
                        interpret: bool = False) -> jax.Array:
    e, w = a.shape
    block_e = min(block_e, e)
    block_w = min(block_w, w)
    grid = (pl.cdiv(e, block_e), pl.cdiv(w, block_w))
    spec = pl.BlockSpec((block_e, block_w), lambda i, j: (i, j))
    return pl.pallas_call(
        _pairs3_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((block_e,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(a, b, c)


# ----------------------------------------------------------------------------
# fused-gather edge kernel (scalar-prefetched edge list)
# ----------------------------------------------------------------------------

def _edge_kernel(u_ref, v_ref, a_ref, b_ref, o_ref):
    # u_ref/v_ref are the prefetched scalar index arrays (SMEM); the actual
    # gather already happened in the index_map; here we just AND+popcount.
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(a_ref[...] & b_ref[...])
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def bf_edge_intersect(bloom: jax.Array, edges: jax.Array, *,
                      block_w: int = 512, interpret: bool = False) -> jax.Array:
    """uint32[n, W] sketch matrix + int32[E, 2] edges -> int32[E].

    Rows are gathered inside the BlockSpec index_map (scalar prefetch);
    grid = (E, W/block_w); each step DMAs two (1, block_w) row slabs.
    """
    n, w = bloom.shape
    e = edges.shape[0]
    block_w = min(block_w, w)
    grid = (e, pl.cdiv(w, block_w))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_w), lambda i, j, u, v: (u[i], j)),
            pl.BlockSpec((1, block_w), lambda i, j, u, v: (v[i], j)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i, j, u, v: (i,)),
    )
    return pl.pallas_call(
        _edge_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(edges[:, 0], edges[:, 1], bloom, bloom)
