"""Pallas TPU kernels for Bloom-filter set algebra (ProbGraph hot loop).

The paper's CPU hot loop is `popcnt(AND(Bx, By))` over AVX lanes; the TPU
adaptation runs it on the VPU (8×128 lanes) with explicit VMEM tiling:

  * ``bf_intersect_pairs_kernel``: dense [E, W] x [E, W] -> [E] AND+popcount,
    tiled (block_e × block_w), accumulating over the word-tile grid axis.
    This is the roofline-friendly form: arithmetic intensity is fixed
    (1 AND + 1 popcount + 1 add per 8 bytes), so the kernel is HBM-bound and
    tiles are chosen to stream at full bandwidth.

  * ``bf_edge_intersect``: the block-gather form (SISA-style: many set
    operations per issued grid step). The edge list lives in SMEM via
    PrefetchScalarGridSpec; each (block_e, block_w) grid step issues
    ``block_e`` row-pair DMAs from the sketch matrix (kept in ANY/HBM) into
    VMEM scratch slabs and AND+popcounts the whole slab in one VPU pass.
    Compared to the earlier per-edge form (grid=(E, W/block_w), two (1,
    block_w) slabs per step) this amortizes grid/DMA issue overhead over
    ``block_e`` edges and lets degree-ordered edge blocks (see
    ``repro.engine.plan.order_edges_by_hub``) reuse hub rows that are already
    resident in the same slab's HBM stream.

  * ``bf_edge_intersect3``: the 3-way block-gather variant for 4-clique
    triple intersections popcnt(Bu AND Bv AND Bw) over (u, v, w) triples.

Callers must pad: E to a multiple of ``block_e`` (pad edges with (0, 0) —
row 0 always exists and results are sliced off) and W to a multiple of
``block_w`` (zero words contribute no bits). ``repro.kernels.ops`` does both.

These raw kernels are now *private* (``_pairs_impl``/``_edge_impl`` family):
the public seam is ``repro.kernels.ops``, whose entrypoints compile the
equivalent set expression (``repro.engine.setexpr``) down to the generalized
fused pass in ``fused_expr.py``. The old public names here remain importable
as ``DeprecationWarning`` shims, and the private impls double as the golden
oracles the bit-identity tests compare the compiled expressions against.

All kernels validate in interpret mode against ``ref.py`` (see tests).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ----------------------------------------------------------------------------
# dense pairs kernel
# ----------------------------------------------------------------------------

def _pairs_kernel(a_ref, b_ref, o_ref):
    """AND+popcount one (block_e, block_w) tile pair, accumulating over j."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(a_ref[...] & b_ref[...])
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def _pairs_impl(a: jax.Array, b: jax.Array, *, block_e: int = 256,
                block_w: int = 512, interpret: bool = False) -> jax.Array:
    """uint32[E, W] x uint32[E, W] -> int32[E]; E, W already block-padded."""
    e, w = a.shape
    block_e = min(block_e, e)
    block_w = min(block_w, w)
    grid = (pl.cdiv(e, block_e), pl.cdiv(w, block_w))
    return pl.pallas_call(
        _pairs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, block_w), lambda i, j: (i, j)),
            pl.BlockSpec((block_e, block_w), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_e,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(a, b)


def _pairs3_kernel(a_ref, b_ref, c_ref, o_ref):
    """3-way AND+popcount one tile triple, accumulating over j."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(a_ref[...] & b_ref[...] & c_ref[...])
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def _pairs3_impl(a: jax.Array, b: jax.Array, c: jax.Array, *,
                 block_e: int = 256, block_w: int = 512,
                 interpret: bool = False) -> jax.Array:
    """3-way dense variant of :func:`_pairs_impl` -> int32[E]."""
    e, w = a.shape
    block_e = min(block_e, e)
    block_w = min(block_w, w)
    grid = (pl.cdiv(e, block_e), pl.cdiv(w, block_w))
    spec = pl.BlockSpec((block_e, block_w), lambda i, j: (i, j))
    return pl.pallas_call(
        _pairs3_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((block_e,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(a, b, c)


# ----------------------------------------------------------------------------
# block-gather edge kernels (scalar-prefetched edge list, manual row DMA)
# ----------------------------------------------------------------------------

def _gather_rows(ids_ref, base, bloom_ref, bufs, sems, *, count, block_w, j):
    """DMA `count` sketch rows (word slab j) into the VMEM scratch slabs.

    ids_ref is a tuple of SMEM-prefetched index arrays (one per slab). All
    row copies are started first and waited on afterwards, so the per-row
    fetches pipeline: the whole (count × len(bufs)) DMA burst is in flight
    at once instead of serializing row by row.
    """
    def row_copies(r):
        """The per-slab async copies fetching row ``r`` of this burst."""
        return [pltpu.make_async_copy(
            bloom_ref.at[ids[base + r], pl.ds(j * block_w, block_w)],
            buf.at[r], sems.at[s])
            for s, (ids, buf) in enumerate(zip(ids_ref, bufs))]

    def start(r, carry):
        """fori_loop body: launch row ``r``'s copies without blocking."""
        for cp in row_copies(r):
            cp.start()
        return carry

    def wait(r, carry):
        """fori_loop body: block until row ``r``'s copies have landed."""
        for cp in row_copies(r):
            cp.wait()
        return carry

    jax.lax.fori_loop(0, count, start, 0)
    jax.lax.fori_loop(0, count, wait, 0)


def _edge_block_kernel(u_ref, v_ref, bloom_ref, o_ref, a_buf, b_buf, sems, *,
                       block_e, block_w):
    """Gather block_e row pairs, AND+popcount the slabs, accumulate over j."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    _gather_rows((u_ref, v_ref), i * block_e, bloom_ref, (a_buf, b_buf), sems,
                 count=block_e, block_w=block_w, j=j)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(a_buf[...] & b_buf[...])
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def _edge_impl(bloom: jax.Array, edges: jax.Array, *, block_e: int = 8,
               block_w: int = 512, interpret: bool = False) -> jax.Array:
    """uint32[n, W] sketch matrix + int32[E, 2] edges -> int32[E].

    Block-gather: grid = (E/block_e, W/block_w); each step DMAs block_e
    Bloom-row pairs into (block_e, block_w) VMEM slabs and reduces them in
    one VPU pass. E must be a multiple of block_e and W of block_w.
    """
    n, w = bloom.shape
    e = edges.shape[0]
    block_w = min(block_w, w)
    block_e = min(block_e, e)
    grid = (pl.cdiv(e, block_e), pl.cdiv(w, block_w))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
        out_specs=pl.BlockSpec((block_e,), lambda i, j, u, v: (i,)),
        scratch_shapes=[
            pltpu.VMEM((block_e, block_w), jnp.uint32),
            pltpu.VMEM((block_e, block_w), jnp.uint32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kern = functools.partial(_edge_block_kernel, block_e=block_e,
                             block_w=block_w)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(edges[:, 0], edges[:, 1], bloom)


def _edge3_block_kernel(u_ref, v_ref, w_ref, bloom_ref, o_ref, a_buf, b_buf,
                        c_buf, sems, *, block_e, block_w):
    """3-slab variant of :func:`_edge_block_kernel` for (u, v, w) triples."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    _gather_rows((u_ref, v_ref, w_ref), i * block_e, bloom_ref,
                 (a_buf, b_buf, c_buf), sems, count=block_e, block_w=block_w,
                 j=j)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cnt = jax.lax.population_count(a_buf[...] & b_buf[...] & c_buf[...])
    o_ref[...] += jnp.sum(cnt.astype(jnp.int32), axis=1)


def _edge3_impl(bloom: jax.Array, triples: jax.Array, *,
                block_e: int = 8, block_w: int = 512,
                interpret: bool = False) -> jax.Array:
    """uint32[n, W] + int32[T, 3] triples -> int32[T] popcnt(Bu & Bv & Bw).

    Same block-gather treatment as :func:`_edge_impl` with three slabs —
    the 4-clique triple-intersection hot loop.
    """
    n, w = bloom.shape
    t = triples.shape[0]
    block_w = min(block_w, w)
    block_e = min(block_e, t)
    grid = (pl.cdiv(t, block_e), pl.cdiv(w, block_w))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)],
        out_specs=pl.BlockSpec((block_e,), lambda i, j, u, v, w: (i,)),
        scratch_shapes=[
            pltpu.VMEM((block_e, block_w), jnp.uint32),
            pltpu.VMEM((block_e, block_w), jnp.uint32),
            pltpu.VMEM((block_e, block_w), jnp.uint32),
            pltpu.SemaphoreType.DMA((3,)),
        ],
    )
    kern = functools.partial(_edge3_block_kernel, block_e=block_e,
                             block_w=block_w)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t,), jnp.int32),
        interpret=interpret,
    )(triples[:, 0], triples[:, 1], triples[:, 2], bloom)


# ----------------------------------------------------------------------------
# deprecation shims for the old public (raw, unpadded) entrypoints
# ----------------------------------------------------------------------------

def _deprecated(old: str, new: str, impl):
    """Wrap a private impl as a ``DeprecationWarning``-emitting shim."""
    @functools.wraps(impl)
    def shim(*args, **kwargs):
        """Forward to the private impl after warning (deprecated name)."""
        warnings.warn(
            f"repro.kernels.bf_intersect.{old} is deprecated; use {new}",
            DeprecationWarning, stacklevel=2)
        return impl(*args, **kwargs)

    shim.__name__ = old
    shim.__qualname__ = old
    shim.__doc__ = (f"Deprecated alias of the raw kernel; use {new}. "
                    f"See ``repro.engine.setexpr`` for arbitrary expressions.")
    return shim


bf_intersect_pairs = _deprecated(
    "bf_intersect_pairs", "repro.kernels.ops.bf_intersect_pairs", _pairs_impl)
bf_intersect3_pairs = _deprecated(
    "bf_intersect3_pairs", "repro.kernels.ops.bf_intersect3_pairs",
    _pairs3_impl)
bf_edge_intersect = _deprecated(
    "bf_edge_intersect", "repro.kernels.ops.bf_edge_intersect", _edge_impl)
bf_edge_intersect3 = _deprecated(
    "bf_edge_intersect3", "repro.kernels.ops.bf_edge_intersect3", _edge3_impl)

__all__ = [
    "bf_edge_intersect", "bf_edge_intersect3", "bf_intersect_pairs",
    "bf_intersect3_pairs",
]
