"""Canonical public kernel entrypoints (padded, compiled via ``setexpr``).

This module is the *one* public seam over the Pallas sketch kernels. The
Bloom-filter popcount family (`bf_*`) no longer binds one hand-rolled kernel
per workload: each entrypoint builds the equivalent set expression and asks
``repro.engine.setexpr`` for the cached compiled form, which lowers to one
fused VMEM pass (``repro.kernels.fused_expr``). On non-TPU backends the
fused pass runs in Pallas interpret mode so correctness is validated
everywhere; on TPU it compiles to Mosaic. Inputs are padded to pow2/block
multiples inside the compiled object and the pad is sliced off, so callers
never see blocking constraints.

Tuning knobs (``block_e``, ``block_w``, ``interpret``) are keyword-only.
The former raw duplicates in ``bf_intersect.py`` (same names, unpadded
signatures) are now ``DeprecationWarning`` shims; new code — including any
new workload — should either call these entrypoints or compile its own
expression with ``repro.engine.setexpr.compile_expr``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import mh_intersect as _mh


def _interpret() -> bool:
    """Pallas interpret mode everywhere but real TPU backends."""
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, mult: int, fill=0) -> jax.Array:
    """Pad the leading axis to a multiple of ``mult`` with ``fill``."""
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad, *x.shape[1:]), fill, x.dtype)], axis=0)


def _pad_cols(x: jax.Array, mult: int, fill=0) -> jax.Array:
    """Pad the trailing axis to a multiple of ``mult`` with ``fill``."""
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((x.shape[0], pad), fill, x.dtype)], axis=1)


def _compiled_and(k: int, *, block_e: int, block_w: int,
                  interpret: Optional[bool]):
    """The cached compiled k-way AND expression (lazy engine import —
    ``repro.engine`` imports this module, so the reverse edge stays inside
    the function body)."""
    from ..engine import setexpr

    return setexpr.compile_expr(setexpr.and_all(*setexpr.rows(k)),
                                block_e=block_e, block_w=block_w,
                                use_kernel=True, interpret=interpret)


def bf_intersect_pairs(a: jax.Array, b: jax.Array, *, block_e: int = 256,
                       block_w: int = 512,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Dense AND+popcount: uint32[E, W] x uint32[E, W] -> int32[E].

    Lowered as the compiled 2-way AND expression in dense (``ones_rows``)
    form — one fused pass, no blocking constraints on E or W.
    """
    return _compiled_and(2, block_e=block_e, block_w=block_w,
                         interpret=interpret).ones_rows(a, b)


def bf_intersect3_pairs(a: jax.Array, b: jax.Array, c: jax.Array, *,
                        block_e: int = 256, block_w: int = 512,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Dense 3-way AND+popcount over row-aligned operands -> int32[E]."""
    return _compiled_and(3, block_e=block_e, block_w=block_w,
                         interpret=interpret).ones_rows(a, b, c)


def bf_edge_intersect(bloom: jax.Array, edges: jax.Array, *,
                      block_e: int = 8, block_w: int = 512,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Block-gather AND+popcount over an edge list -> int32[E].

    Lowered as the compiled 2-way AND expression in gather form: edge
    endpoints index sketch rows, one pipelined DMA burst per edge block.
    """
    return _compiled_and(2, block_e=block_e, block_w=block_w,
                         interpret=interpret).ones(bloom, edges)


def bf_edge_intersect3(bloom: jax.Array, triples: jax.Array, *,
                       block_e: int = 8, block_w: int = 512,
                       interpret: Optional[bool] = None) -> jax.Array:
    """3-way block-gather popcount over (u, v, w) triples (4-clique path)."""
    return _compiled_and(3, block_e=block_e, block_w=block_w,
                         interpret=interpret).ones(bloom, triples)


@functools.partial(jax.jit, static_argnames=("sentinel", "block_e"))
def mh_intersect_pairs(a: jax.Array, b: jax.Array, sentinel: int, *,
                       block_e: int = 128) -> jax.Array:
    """MinHash signature match count per row pair -> int32[E]."""
    e = a.shape[0]
    be = min(block_e, max(e, 1))
    a2 = _pad_rows(a, be, fill=sentinel)
    b2 = _pad_rows(b, be, fill=sentinel)
    out = _mh.mh_intersect_pairs(a2, b2, sentinel, block_e=be,
                                 interpret=_interpret())
    return out[:e]


@functools.partial(jax.jit, static_argnames=("sentinel", "block_e"))
def khash_match_pairs(a: jax.Array, b: jax.Array, sentinel: int, *,
                      block_e: int = 512) -> jax.Array:
    """Sorted k-hash sample intersection count per row pair -> int32[E]."""
    e = a.shape[0]
    be = min(block_e, max(e, 1))
    a2 = _pad_rows(a, be, fill=sentinel)
    b2 = _pad_rows(b, be, fill=sentinel)
    out = _mh.khash_match_pairs(a2, b2, sentinel, block_e=be,
                                interpret=_interpret())
    return out[:e]


__all__ = [
    "bf_edge_intersect", "bf_edge_intersect3", "bf_intersect_pairs",
    "bf_intersect3_pairs", "khash_match_pairs", "mh_intersect_pairs",
]
