"""jit'd public wrappers around the Pallas kernels.

On non-TPU backends the kernels run in interpret mode (Python evaluation of
the kernel body) so correctness is validated everywhere; on TPU they compile
to Mosaic. Inputs are padded to block multiples here and the pad is sliced
off after the call, so callers never see blocking constraints.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import bf_intersect as _bf
from . import mh_intersect as _mh


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x: jax.Array, mult: int, fill=0) -> jax.Array:
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad, *x.shape[1:]), fill, x.dtype)], axis=0)


def _pad_cols(x: jax.Array, mult: int, fill=0) -> jax.Array:
    pad = (-x.shape[1]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((x.shape[0], pad), fill, x.dtype)], axis=1)


@functools.partial(jax.jit, static_argnames=("block_e", "block_w"))
def bf_intersect_pairs(a: jax.Array, b: jax.Array, block_e: int = 256,
                       block_w: int = 512) -> jax.Array:
    e = a.shape[0]
    be = min(block_e, max(e, 1))
    a2 = _pad_cols(_pad_rows(a, be), 2)
    b2 = _pad_cols(_pad_rows(b, be), 2)
    out = _bf.bf_intersect_pairs(a2, b2, block_e=be, block_w=block_w,
                                 interpret=_interpret())
    return out[:e]


@functools.partial(jax.jit, static_argnames=("block_e", "block_w"))
def bf_intersect3_pairs(a: jax.Array, b: jax.Array, c: jax.Array,
                        block_e: int = 256, block_w: int = 512) -> jax.Array:
    e = a.shape[0]
    be = min(block_e, max(e, 1))
    a2 = _pad_cols(_pad_rows(a, be), 2)
    b2 = _pad_cols(_pad_rows(b, be), 2)
    c2 = _pad_cols(_pad_rows(c, be), 2)
    out = _bf.bf_intersect3_pairs(a2, b2, c2, block_e=be, block_w=block_w,
                                  interpret=_interpret())
    return out[:e]


@functools.partial(jax.jit, static_argnames=("block_e", "block_w"))
def bf_edge_intersect(bloom: jax.Array, edges: jax.Array,
                      block_e: int = 8, block_w: int = 512) -> jax.Array:
    """Block-gather AND+popcount over an edge list.

    Edges are padded to a block_e multiple with (0, 0) — row 0 always exists
    in the sketch matrix and the padded results are sliced off — and the
    sketch matrix is padded to a block_w word multiple with zero words.
    """
    e = edges.shape[0]
    if e == 0:
        return jnp.zeros((0,), jnp.int32)
    be = min(block_e, e)
    bw = min(block_w, bloom.shape[1])
    bloom2 = _pad_cols(bloom, bw)
    edges2 = _pad_rows(edges.astype(jnp.int32), be)
    out = _bf.bf_edge_intersect(bloom2, edges2, block_e=be, block_w=bw,
                                interpret=_interpret())
    return out[:e]


@functools.partial(jax.jit, static_argnames=("block_e", "block_w"))
def bf_edge_intersect3(bloom: jax.Array, triples: jax.Array,
                       block_e: int = 8, block_w: int = 512) -> jax.Array:
    """3-way block-gather popcount over (u, v, w) triples (4-clique path)."""
    t = triples.shape[0]
    if t == 0:
        return jnp.zeros((0,), jnp.int32)
    be = min(block_e, t)
    bw = min(block_w, bloom.shape[1])
    bloom2 = _pad_cols(bloom, bw)
    triples2 = _pad_rows(triples.astype(jnp.int32), be)
    out = _bf.bf_edge_intersect3(bloom2, triples2, block_e=be, block_w=bw,
                                 interpret=_interpret())
    return out[:t]


@functools.partial(jax.jit, static_argnames=("sentinel", "block_e"))
def mh_intersect_pairs(a: jax.Array, b: jax.Array, sentinel: int,
                       block_e: int = 128) -> jax.Array:
    e = a.shape[0]
    be = min(block_e, max(e, 1))
    a2 = _pad_rows(a, be, fill=sentinel)
    b2 = _pad_rows(b, be, fill=sentinel)
    out = _mh.mh_intersect_pairs(a2, b2, sentinel, block_e=be,
                                 interpret=_interpret())
    return out[:e]


@functools.partial(jax.jit, static_argnames=("sentinel", "block_e"))
def khash_match_pairs(a: jax.Array, b: jax.Array, sentinel: int,
                      block_e: int = 512) -> jax.Array:
    e = a.shape[0]
    be = min(block_e, max(e, 1))
    a2 = _pad_rows(a, be, fill=sentinel)
    b2 = _pad_rows(b, be, fill=sentinel)
    out = _mh.khash_match_pairs(a2, b2, sentinel, block_e=be,
                                interpret=_interpret())
    return out[:e]
