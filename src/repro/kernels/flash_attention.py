"""Pallas flash-attention (forward) — the designed fix for the dominant
memory term of the train/prefill cells (EXPERIMENTS.md §Perf).

The XLA-level chunked attention in models/layers.py must materialize every
[chunk_q, chunk_kv] f32 probability block in HBM (scan residuals / dot
operands); profiling shows those blocks dominate HBM traffic for every
attention arch. This kernel keeps the running max / denominator / output
accumulator in VMEM across kv blocks, so HBM traffic drops to Q/K/V/O only
(≈ 4·S·D vs S²-proportional).

Layout: q [BH, Sq, D] (GQA groups folded into the leading dim), k/v
[BKV, Skv, D]; grid (BH, nq). Each step streams kv blocks with an in-kernel
fori_loop over VMEM-resident K/V rows. The TPU production variant would
put nkv in the grid with VMEM scratch accumulators; this form keeps the
whole K/V in VMEM per (bh, qi) step — correct, and sufficient for
interpret-mode validation + roofline modeling (HBM bytes = 4·S·D·dtype).

Causal + optional sliding window. Backward runs through jax.checkpoint
recompute of this kernel (custom_vjp with dedicated bwd kernels is the
follow-up noted in §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, block_kv: int, window: int,
               scale: float):
    bq = q_ref.shape[1]
    skv = k_ref.shape[1]
    d = q_ref.shape[2]
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale                    # [bq, D]
    q_pos = qi * bq + lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    nkv = skv // block_kv

    def body(j, carry):
        """Online-softmax update over one (bq, block_kv) score tile."""
        m, l, acc = carry
        k_blk = lax.dynamic_slice_in_dim(k_ref[0], j * block_kv, block_kv, 0)
        v_blk = lax.dynamic_slice_in_dim(v_ref[0], j * block_kv, block_kv, 0)
        kv_pos = j * block_kv + lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)
        s = jnp.dot(q, k_blk.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32)          # [bq, bkv]
        mask = kv_pos <= q_pos
        if window:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_new = acc * alpha + jnp.dot(p, v_blk.astype(jnp.float32),
                                        preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    a0 = jnp.zeros((bq, d), jnp.float32)
    m, l, acc = lax.fori_loop(0, nkv, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_folded(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           groups: int, window: int = 0, block_q: int = 128,
                           block_kv: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: [BH, Sq, D] with BH = B·KV·groups; k/v: [BKV, Skv, D]."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    grid = (bh, pl.cdiv(sq, block_q))
    scale = 1.0 / math.sqrt(d)
    return pl.pallas_call(
        functools.partial(_fa_kernel, block_kv=block_kv, window=window,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda i, qi: (i, qi, 0)),
            pl.BlockSpec((1, skv, d), lambda i, qi, g=groups: (i // g, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda i, qi, g=groups: (i // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda i, qi: (i, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int = 0, block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """Standard layout wrapper: q [B,S,H,D], k/v [B,S,KV,D] -> [B,S,H,D]."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = jnp.moveaxis(q.reshape(b, sq, kv, g, d), 1, 3).reshape(b * kv * g, sq, d)
    kf = jnp.moveaxis(k, 1, 2).reshape(b * kv, -1, d)
    vf = jnp.moveaxis(v, 1, 2).reshape(b * kv, -1, d)
    of = flash_attention_folded(qf, kf, vf, groups=g, window=window,
                                block_q=block_q, block_kv=block_kv,
                                interpret=interpret)
    o = of.reshape(b, kv, g, sq, d)
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, d)
