"""Pallas TPU kernels for ProbGraph hot spots (+ ops wrappers, ref oracles)."""
from . import ops, ref

__all__ = ["ops", "ref"]
