"""Pallas TPU kernels for ProbGraph hot spots.

Public surface: the padded entrypoints in :mod:`repro.kernels.ops` (re-
exported here), the generalized fused expression pass in
:mod:`repro.kernels.fused_expr`, and the pure-jnp oracles in
:mod:`repro.kernels.ref`. The raw per-workload kernels in
``bf_intersect.py`` are private; their old public names warn.
"""
from . import fused_expr, ops, ref
from .fused_expr import fused_gather_popcount, fused_rows_popcount
from .ops import (
    bf_edge_intersect,
    bf_edge_intersect3,
    bf_intersect_pairs,
    bf_intersect3_pairs,
    khash_match_pairs,
    mh_intersect_pairs,
)

__all__ = [
    "bf_edge_intersect",
    "bf_edge_intersect3",
    "bf_intersect_pairs",
    "bf_intersect3_pairs",
    "fused_expr",
    "fused_gather_popcount",
    "fused_rows_popcount",
    "khash_match_pairs",
    "mh_intersect_pairs",
    "ops",
    "ref",
]
