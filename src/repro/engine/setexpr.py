"""Set-expression IR + compiler over the Pallas sketch kernels (SISA layer).

ProbGraph turns vertex-set operations into sketch bitwise algebra; SISA's
observation is that a *small set-centric instruction set* — not one kernel
per workload — is the right abstraction. This module is that instruction
set: a tiny IR of :class:`SetExpr` nodes (k-way ``AND``/``OR``/``ANDNOT``
over sketch rows, implicitly popcount-reduced) and a compiler that lowers
any expression tree to **one** fused Pallas VMEM pass
(:mod:`repro.kernels.fused_expr`) — block-gather DMA of every referenced
sketch row per tuple block, bitwise evaluation in registers, popcount
reduction — or to the equivalent jnp gather when the plan stays off the
kernel path. Kernel and jnp lowerings evaluate the *same* expression
closure on the same integers, so their popcounts are bit-identical by
construction.

The three formerly hand-rolled kernels are expressions here::

    rows(2)[0] & rows(2)[1]                # 2-way AND: edge cardinalities
    and_all(*rows(3))                      # 3-way AND: 4-clique triples
    rows(2)[0] & rows(2)[1]  (dense form)  # sweep-cut prefix-OR gating

and the 4-way AND behind 5-clique counting needed no new kernel — that is
the API earning its keep.

Compiled objects are cached (module-level, keyed by expression *structure*
plus block shapes and dispatch flags) and pad the tuple axis to power-of-two
buckets, so arbitrary workload sizes reuse a bounded set of compiled
programs — the same discipline as ``plan.pow2_bucket`` everywhere else.

Usage::

    from repro.engine import setexpr
    u, v, w = setexpr.rows(3)
    ce = setexpr.compile_expr((u & v) - w)       # |N_u ∩ N_v ∖ bits(B_w)|
    ones = ce.ones(sketch.data, tuples)          # int32[T] popcounts
    size = ce.cardinality(sketch, tuples)        # Swamidass estimate
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from typing import Callable, Dict, Optional, Sequence, Tuple

from ..obs import trace
from ..obs.metrics import REGISTRY
from .plan import pow2_bucket


# ----------------------------------------------------------------------------
# the IR
# ----------------------------------------------------------------------------

class SetExpr:
    """Base class of set-algebra expression nodes over sketch rows.

    Supports operator sugar: ``a & b`` (intersection/AND), ``a | b``
    (union/OR), ``a - b`` (difference/ANDNOT). Expressions are immutable
    and hash by structure, which is what the compile cache keys on.
    """

    def __and__(self, other: "SetExpr") -> "SetExpr":
        """k-way AND; chains flatten (``a & b & c`` is one 3-way node)."""
        return and_all(self, other)

    def __or__(self, other: "SetExpr") -> "SetExpr":
        """k-way OR; chains flatten like AND."""
        return or_all(self, other)

    def __sub__(self, other: "SetExpr") -> "SetExpr":
        """Set difference lowered as ANDNOT: ``a & ~b`` on the bit rows."""
        return AndNot(self, other)

    def key(self) -> tuple:
        """Canonical structure key (nested tuples) — the cache identity."""
        raise NotImplementedError

    def __hash__(self) -> int:
        return hash(self.key())

    def __eq__(self, other) -> bool:
        return isinstance(other, SetExpr) and self.key() == other.key()


class Row(SetExpr):
    """A leaf: the sketch row of tuple column ``slot`` (0-based)."""

    def __init__(self, slot: int):
        if slot < 0:
            raise ValueError("Row slot must be >= 0")
        self.slot = int(slot)

    def key(self) -> tuple:
        """``("row", slot)``."""
        return ("row", self.slot)

    def __repr__(self) -> str:
        return f"Row({self.slot})"


class _NAry(SetExpr):
    """Internal k-way node (``op`` is "and" | "or"); built via the
    :func:`and_all` / :func:`or_all` constructors, which flatten chains."""

    def __init__(self, op: str, args: Tuple[SetExpr, ...]):
        self.op = op
        self.args = args

    def key(self) -> tuple:
        """``(op, child_key, ...)``."""
        return (self.op, *(a.key() for a in self.args))

    def __repr__(self) -> str:
        sep = " & " if self.op == "and" else " | "
        return "(" + sep.join(map(repr, self.args)) + ")"


class AndNot(SetExpr):
    """Binary difference node: bits of ``a`` with ``b``'s bits cleared."""

    def __init__(self, a: SetExpr, b: SetExpr):
        self.a = a
        self.b = b

    def key(self) -> tuple:
        """``("andnot", a_key, b_key)``."""
        return ("andnot", self.a.key(), self.b.key())

    def __repr__(self) -> str:
        return f"({self.a!r} - {self.b!r})"


def rows(k: int) -> Tuple[Row, ...]:
    """The first ``k`` leaf rows — ``rows(3)`` ≡ ``(Row(0), Row(1), Row(2))``."""
    return tuple(Row(i) for i in range(k))


def _flatten(op: str, args: Sequence[SetExpr]) -> Tuple[SetExpr, ...]:
    out: list[SetExpr] = []
    for a in args:
        if isinstance(a, _NAry) and a.op == op:
            out.extend(a.args)
        else:
            out.append(a)
    return tuple(out)


def and_all(*args: SetExpr) -> SetExpr:
    """k-way AND of the given expressions (nested ANDs flatten)."""
    flat = _flatten("and", args)
    return flat[0] if len(flat) == 1 else _NAry("and", flat)


def or_all(*args: SetExpr) -> SetExpr:
    """k-way OR of the given expressions (nested ORs flatten)."""
    flat = _flatten("or", args)
    return flat[0] if len(flat) == 1 else _NAry("or", flat)


def expr_slots(expr: SetExpr) -> Tuple[int, ...]:
    """Sorted distinct tuple columns the expression reads (its leaves)."""
    found: set[int] = set()

    def walk(e: SetExpr) -> None:
        """Collect leaf slots depth-first."""
        if isinstance(e, Row):
            found.add(e.slot)
        elif isinstance(e, _NAry):
            for a in e.args:
                walk(a)
        elif isinstance(e, AndNot):
            walk(e.a)
            walk(e.b)
        else:  # pragma: no cover - new node kinds must extend the walker
            raise TypeError(f"unknown SetExpr node {type(e).__name__}")

    walk(expr)
    return tuple(sorted(found))


def _make_eval(expr: SetExpr, pos: Dict[int, int]
               ) -> Callable[[Tuple[jax.Array, ...]], jax.Array]:
    """Build the bitwise evaluator closure: slab tuple -> uint32 word array.

    The closure is pure jnp ops (&, |, ~) so the *same* function body runs
    on VMEM slab values inside the fused kernel and on gathered rows in the
    jnp fallback — the source of kernel/jnp bit-identity.
    """
    def ev(e: SetExpr, vals: Tuple[jax.Array, ...]) -> jax.Array:
        """Recursive structural evaluation."""
        if isinstance(e, Row):
            return vals[pos[e.slot]]
        if isinstance(e, _NAry):
            acc = ev(e.args[0], vals)
            for a in e.args[1:]:
                acc = (acc & ev(a, vals)) if e.op == "and" \
                    else (acc | ev(a, vals))
            return acc
        if isinstance(e, AndNot):
            return ev(e.a, vals) & ~ev(e.b, vals)
        raise TypeError(f"unknown SetExpr node {type(e).__name__}")

    return lambda vals: ev(expr, vals)


# ----------------------------------------------------------------------------
# the compiler
# ----------------------------------------------------------------------------

def _default_interpret() -> bool:
    """Pallas interpret mode everywhere but real TPU backends."""
    return jax.default_backend() != "tpu"


def _pad_axis0(x: jax.Array, to: int, fill=0) -> jax.Array:
    """Zero-fill (or ``fill``-fill) the leading axis up to length ``to``."""
    pad = to - x.shape[0]
    if pad <= 0:
        return x
    return jnp.concatenate(
        [x, jnp.full((pad, *x.shape[1:]), fill, x.dtype)], axis=0)


def _pad_words(x: jax.Array, mult: int) -> jax.Array:
    """Zero-pad the word axis to a multiple of ``mult`` (no bits added)."""
    pad = (-x.shape[-1]) % mult
    if pad == 0:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((*x.shape[:-1], pad), x.dtype)], axis=-1)


class CompiledSetExpr:
    """One expression lowered to a fused popcount pass (plus jnp fallback).

    Instances come from :func:`compile_expr` (which caches them by
    expression structure + block shapes + dispatch flags — do not construct
    directly unless you want to bypass the cache). Two input forms:

      * :meth:`ones` — *gather* form: sketch matrix + int32[T, >max_slot]
        tuple array; each leaf ``Row(s)`` reads the sketch row indexed by
        tuple column ``s``.
      * :meth:`ones_rows` — *dense* form: one uint32[E, W] operand matrix
        per distinct leaf slot, in sorted-slot order (for operands that are
        computed rather than resident in the sketch matrix, like the sweep
        cut's prefix filter).

    The tuple/row axis is padded to a pow2 bucket (then to a ``block_e``
    multiple) so varying workload sizes share compiled programs; the word
    axis pads with zero words, which add no bits to any popcount.
    """

    def __init__(self, expr: SetExpr, *, block_e: int, block_w: int,
                 use_kernel: bool, interpret: Optional[bool] = None):
        self.expr = expr
        self.slots = expr_slots(expr)
        if not self.slots:
            raise ValueError("expression references no Row leaves")
        self.arity = len(self.slots)
        self.block_e = int(block_e)
        self.block_w = int(block_w)
        self.use_kernel = bool(use_kernel)
        self.interpret = (_default_interpret() if interpret is None
                          else bool(interpret))
        self._eval = _make_eval(expr, {s: i for i, s in enumerate(self.slots)})
        self._ones_jit = jax.jit(self._ones_impl)
        self._rows_jit = jax.jit(self._ones_rows_impl)

    # -- gather form --------------------------------------------------------

    def _ones_impl(self, data: jax.Array, tuples: jax.Array) -> jax.Array:
        """Padded lowering of the gather form (jitted per input shape)."""
        t = tuples.shape[0]
        if self.use_kernel:
            from ..kernels import fused_expr

            t_b = pow2_bucket(t)
            be = min(self.block_e, t_b)
            t_pad = -(-t_b // be) * be
            bw = min(self.block_w, data.shape[1])
            cols = [_pad_axis0(tuples[:, s], t_pad) for s in self.slots]
            out = fused_expr.fused_gather_popcount(
                _pad_words(data, bw), cols, self._eval, block_e=be,
                block_w=bw, interpret=self.interpret)
            return out[:t]
        vals = tuple(jnp.take(data, tuples[:, s], axis=0)
                     for s in self.slots)
        return jnp.sum(jax.lax.population_count(self._eval(vals)),
                       axis=-1).astype(jnp.int32)

    def ones(self, data: jax.Array, tuples: jax.Array) -> jax.Array:
        """Evaluate over gathered sketch rows: int32[T] popcounts.

        Args:
          data:   uint32[n, W] sketch matrix (e.g. ``SketchSet.data``).
          tuples: int32[T, k] row-index tuples; leaf ``Row(s)`` reads
                  column ``s`` (k must exceed the largest referenced slot).
        """
        tuples = jnp.asarray(tuples, jnp.int32)
        if tuples.shape[0] == 0:
            return jnp.zeros((0,), jnp.int32)
        if tuples.shape[1] <= self.slots[-1]:
            raise ValueError(
                f"expression reads tuple column {self.slots[-1]} but tuples "
                f"have width {tuples.shape[1]}")
        return self._ones_jit(data, tuples)

    def cardinality(self, sketch, tuples: jax.Array) -> jax.Array:
        """Swamidass size estimate of the expression row per tuple.

        Exact for the AND family (paper Eq. 2 applied to the k-way AND
        row); for OR/ANDNOT rows it is the same ones→size map applied to
        the evaluated bit row — see ``core.bounds.bf_kway_and_mse_bound``
        for when this is quantitatively trustworthy.
        """
        from ..core import estimators as est
        return est.bf_intersection_and_from_ones(
            self.ones(sketch.data, tuples), sketch.total_bits,
            sketch.num_hashes)

    # -- dense form ---------------------------------------------------------

    def _ones_rows_impl(self, *rows: jax.Array) -> jax.Array:
        """Padded lowering of the dense form (jitted per input shape)."""
        e, w = rows[0].shape
        if self.use_kernel:
            from ..kernels import fused_expr

            e_b = pow2_bucket(e)
            be = min(self.block_e, e_b)
            e_pad = -(-e_b // be) * be
            w2 = w + (w % 2)                     # lane-friendly even width
            bw = min(self.block_w, w2)
            w_pad = -(-w2 // bw) * bw
            padded = [jnp.pad(_pad_axis0(r, e_pad), ((0, 0), (0, w_pad - w)))
                      for r in rows]
            out = fused_expr.fused_rows_popcount(
                padded, self._eval, block_e=be, block_w=bw,
                interpret=self.interpret)
            return out[:e]
        return jnp.sum(jax.lax.population_count(self._eval(tuple(rows))),
                       axis=-1).astype(jnp.int32)

    def ones_rows(self, *rows: jax.Array) -> jax.Array:
        """Evaluate over dense operand matrices: int32[E] popcounts.

        Args:
          *rows: one uint32[E, W] matrix per distinct leaf slot, in sorted
                 slot order (``Row(0)``'s operand first).
        """
        if len(rows) != self.arity:
            raise ValueError(
                f"expression has {self.arity} distinct leaves, got "
                f"{len(rows)} operand matrices")
        if rows[0].shape[0] == 0:
            return jnp.zeros((0,), jnp.int32)
        return self._rows_jit(*rows)

    def __repr__(self) -> str:
        return (f"CompiledSetExpr({self.expr!r}, block_e={self.block_e}, "
                f"block_w={self.block_w}, use_kernel={self.use_kernel})")


# the shared compile cache: expression structure + block shapes + dispatch
_CACHE: Dict[tuple, CompiledSetExpr] = {}
_CACHE_HITS = 0


def compile_expr(expr: SetExpr, *, block_e: int = 8, block_w: int = 512,
                 use_kernel: bool = True,
                 interpret: Optional[bool] = None) -> CompiledSetExpr:
    """Compile (with caching) a set expression to a fused popcount pass.

    Args:
      expr:       the expression tree (see :func:`rows` and the operators).
      block_e:    tuples/rows per Pallas grid step (keyword-only knob).
      block_w:    sketch words per grid step (keyword-only knob).
      use_kernel: lower to the fused Pallas pass; ``False`` lowers to the
                  equivalent jnp gather + popcount (bit-identical ints).
      interpret:  force Pallas interpret mode (default: auto — interpret on
                  non-TPU backends).

    Returns:
      The cached :class:`CompiledSetExpr` for this structure/configuration —
      repeated compiles of the same shape of query are free, and their
      jitted programs (bounded by pow2 size buckets) are shared process-wide.
    """
    global _CACHE_HITS
    key = (expr.key(), int(block_e), int(block_w), bool(use_kernel),
           interpret)
    hit = _CACHE.get(key)
    if hit is not None:
        _CACHE_HITS += 1
        REGISTRY.counter("setexpr_compile_total", result="hit").inc()
        return hit
    with trace.span("setexpr.compile", expr=repr(expr)):
        ce = CompiledSetExpr(expr, block_e=block_e, block_w=block_w,
                             use_kernel=use_kernel, interpret=interpret)
    REGISTRY.counter("setexpr_compile_total", result="miss").inc()
    _CACHE[key] = ce
    return ce


def cache_info() -> dict:
    """Compile-cache counters: distinct compiled expressions and hits."""
    return {"size": len(_CACHE), "hits": _CACHE_HITS}


def cache_clear() -> None:
    """Drop every cached compiled expression (mainly for tests)."""
    global _CACHE_HITS
    _CACHE.clear()
    _CACHE_HITS = 0


__all__ = [
    "AndNot", "CompiledSetExpr", "Row", "SetExpr", "and_all", "cache_clear",
    "cache_info", "compile_expr", "expr_slots", "or_all", "rows",
]
