"""Execution planning for the batched mining engine.

An :class:`EnginePlan` is the single description of *how* set-intersection
work is executed — edge batching/padding, Pallas block shapes, sketch
estimator selection, degree-ordered edge layout, and optional edge-axis
sharding. Every algorithm consumes one instead of carrying its own chunk
plumbing (the GBBS "shared parallel primitives" discipline applied to the
ProbGraph hot loop).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.graph import Graph
from ..core.sketches import SketchSet
from ..obs import trace


@dataclasses.dataclass(frozen=True)
class EnginePlan:
    """Static execution parameters shared by all mining algorithms.

    Attributes:
      edge_chunk:   edges per scan-fold step (HBM working-set knob).
      block_e:      Bloom-row pairs gathered per Pallas grid step.
      block_w:      sketch words per Pallas grid step.
      use_kernel:   route BF popcounts through the block-gather Pallas kernels.
      degree_order: sort edge blocks by hub endpoint so high-degree rows are
                    revisited by consecutive blocks (VMEM/HBM-stream reuse).
      estimator:    estimator override (e.g. "bf_l" on a "bf" sketch).
      variant:      1-Hash Jaccard variant ("union" | "naive").
      shard_edges:  shard_map the edge fold over the active mesh's edge axis
                    (see repro.distributed.sharding; no-op without a mesh).
      sweep_cap:    max swept prefix length for local clustering sweep cuts
                    (bounds the per-seed sweep tensor shapes).
      frontier_mode: PPR push frontier layout — "dense" keeps the classic
                    ``[S, n]`` residual tensors, "sparse" stores per-seed
                    support in capped ``[S, cap]`` index+value buffers, and
                    "auto" (default) picks sparse only when the cap implied
                    by ``1/(alpha·eps)`` is far enough below ``n`` to pay.
      frontier_cap: explicit sparse-frontier capacity override (entries per
                    seed; pow2-bucketed). ``None`` sizes it from the ACL
                    support bound ``O(1/(alpha·eps))``. Undersizing is safe:
                    overflow spills to the dense push (slower, never wrong).
    """

    edge_chunk: int = 65536
    block_e: int = 8
    block_w: int = 512
    use_kernel: bool = False
    degree_order: bool = False
    estimator: Optional[str] = None
    variant: str = "union"
    shard_edges: bool = False
    sweep_cap: int = 512
    frontier_mode: str = "auto"
    frontier_cap: Optional[int] = None

    def with_(self, **overrides) -> "EnginePlan":
        """Return a copy of this plan with the given fields replaced."""
        return dataclasses.replace(self, **overrides)


def pow2_bucket(size: int, lo: int = 1) -> int:
    """Next power of two ≥ max(size, lo).

    The fixed batch shapes that bound XLA recompiles under varying-size work:
    session cache refresh, streaming sketch inserts/rebuilds, and query-server
    batches all pad to these buckets.
    """
    return max(lo, 1 << (max(int(size), 1) - 1).bit_length())


def plan_for(graph: Graph, sketch: Optional[SketchSet] = None,
             **overrides) -> EnginePlan:
    """Heuristic default plan for a (graph, sketch) pair.

    Chunk size is clamped so a chunk's gathered sketch rows stay well under
    VMEM-scale working sets; degree ordering is enabled on the kernel path
    where block locality pays for the one-time sort.
    """
    with trace.span("engine.plan_for", n=int(graph.n), m=int(graph.m),
                    kind=sketch.kind if sketch is not None else "exact"):
        words = (sketch.data.shape[1]
                 if sketch is not None and sketch.kind == "bf" else 64)
        target_words = 1 << 22              # ~16 MiB of gathered uint32 rows
        chunk = max(1024, min(65536, target_words // max(words, 1)))
        base = EnginePlan(edge_chunk=int(chunk),
                          degree_order=bool(overrides.get("use_kernel",
                                                          False)))
        return base.with_(**overrides)


# ----------------------------------------------------------------------------
# edge layout: degree-bucketed ordering for hub-row residency
# ----------------------------------------------------------------------------

def order_edges_by_hub(graph: Graph, edges: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """Reorder edges so blocks revisit hub rows consecutively.

    Sort key is (hub degree bucket desc, hub id): edges sharing their
    highest-degree endpoint become adjacent, so consecutive (block_e, block_w)
    gather steps re-read the same sketch row while it is hot. Returns
    (edges_sorted, inv) with ``values_sorted[inv] == values_original_order``.
    """
    du = jnp.take(graph.deg, edges[:, 0])
    dv = jnp.take(graph.deg, edges[:, 1])
    hub = jnp.where(du >= dv, edges[:, 0], edges[:, 1])
    hub_deg = jnp.maximum(du, dv).astype(jnp.int32)
    # bucket = floor(log2(deg)) + 1, via the float exponent; descending so
    # hub-heavy blocks lead the schedule
    bucket = jnp.frexp(jnp.maximum(hub_deg, 1).astype(jnp.float32))[1]
    perm = jnp.lexsort((hub, -bucket))
    inv = jnp.argsort(perm)
    return jnp.take(edges, perm, axis=0), inv


# ----------------------------------------------------------------------------
# shared chunked fold / map over edge-like index arrays
# ----------------------------------------------------------------------------

def _pad_edges(edges: jax.Array, chunk: int):
    m = edges.shape[0]
    pad = (-m) % chunk
    edges_p = jnp.concatenate(
        [edges, jnp.zeros((pad, edges.shape[1]), edges.dtype)], axis=0)
    mask = jnp.concatenate([jnp.ones(m, bool), jnp.zeros(pad, bool)])
    return edges_p, mask


def fold_edges_masked(edges: jax.Array, mask: jax.Array, chunk_fn,
                      plan: EnginePlan) -> jax.Array:
    """Scan-fold of ``chunk_fn(pairs, mask) -> scalar`` with a caller-supplied
    validity mask; ``edges`` must already be chunk-padded when chunked."""
    m = edges.shape[0]
    if m == 0:
        return jnp.float32(0)
    if m <= plan.edge_chunk:
        return chunk_fn(edges, mask)

    def body(c, xs):
        """Scan step: accumulate one chunk's masked partial sum."""
        pairs, msk = xs
        return c + chunk_fn(pairs, msk), None

    total, _ = jax.lax.scan(
        body, jnp.float32(0),
        (edges.reshape(-1, plan.edge_chunk, edges.shape[1]),
         mask.reshape(-1, plan.edge_chunk)))
    return total


def fold_edges(edges: jax.Array, chunk_fn, plan: EnginePlan) -> jax.Array:
    """Masked scan-fold of ``chunk_fn(pairs, mask) -> scalar`` over chunks."""
    m = edges.shape[0]
    if m == 0:
        return jnp.float32(0)
    if m <= plan.edge_chunk:
        return chunk_fn(edges, jnp.ones(m, bool))
    edges_p, mask = _pad_edges(edges, plan.edge_chunk)
    return fold_edges_masked(edges_p, mask, chunk_fn, plan)


def map_edges(edges: jax.Array, chunk_fn, plan: EnginePlan) -> jax.Array:
    """Chunked map of ``chunk_fn(pairs) -> [C]`` over edges; returns [m]."""
    m = edges.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.float32)
    if m <= plan.edge_chunk:
        return chunk_fn(edges)
    edges_p, _ = _pad_edges(edges, plan.edge_chunk)
    out = jax.lax.map(chunk_fn,
                      edges_p.reshape(-1, plan.edge_chunk, edges.shape[1]))
    return out.reshape(-1)[:m]
