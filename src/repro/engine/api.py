"""Stable engine facade for downstream packages (``launch``, ``stream``).

The engine's internals move (kernels get rerouted, helpers get renamed);
this module is the surface that does not. Downstream code imports plans,
sessions, footprints, the fold/map executors and the set-expression
compiler from here instead of reaching into ``repro.engine.engine`` /
``repro.engine.plan`` private helpers (``_sharded_fold`` and friends are
deliberately not re-exported).
"""
from __future__ import annotations

from . import setexpr
from .engine import (
    DeviceCarry,
    Footprint,
    MiningSession,
    edge_cardinalities,
    pair_cardinality_fn,
    resolve_plan,
    session,
    sum_edge_cardinalities,
    triple_cardinality_ones,
    tuple_cardinality_ones,
    wedge_quad_ones,
    wedge_triple_ones,
)
from .plan import (
    EnginePlan,
    fold_edges,
    map_edges,
    order_edges_by_hub,
    plan_for,
    pow2_bucket,
)
from .setexpr import (
    CompiledSetExpr,
    Row,
    SetExpr,
    and_all,
    compile_expr,
    or_all,
    rows,
)

__all__ = [
    "CompiledSetExpr", "DeviceCarry", "EnginePlan", "Footprint",
    "MiningSession", "Row", "SetExpr", "and_all", "compile_expr",
    "edge_cardinalities", "fold_edges", "map_edges", "or_all",
    "order_edges_by_hub", "pair_cardinality_fn", "plan_for", "pow2_bucket",
    "resolve_plan", "rows", "session", "setexpr", "sum_edge_cardinalities",
    "triple_cardinality_ones", "tuple_cardinality_ones", "wedge_quad_ones",
    "wedge_triple_ones",
]
