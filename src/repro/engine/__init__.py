"""Batched mining engine: the shared execution seam for ProbGraph algorithms.

``EnginePlan`` describes *how* set-intersection work runs (batching, Pallas
block shapes, estimator dispatch, edge-axis sharding); ``session`` amortizes
one sketch build across many queries; ``setexpr`` is the set-expression
compiler every sketch popcount routes through. Downstream packages
(``launch``, ``stream``) should import from :mod:`repro.engine.api`, the
facade that pins the supported surface. See engine.py for the full story.
"""
from . import api, setexpr
from .plan import (EnginePlan, fold_edges, fold_edges_masked, map_edges,
                   order_edges_by_hub, plan_for, pow2_bucket)
from .engine import (
    DeviceCarry,
    Footprint,
    MiningSession,
    edge_cardinalities,
    pair_cardinality_fn,
    resolve_plan,
    session,
    sum_edge_cardinalities,
    triple_cardinality_ones,
    tuple_cardinality_ones,
    wedge_quad_ones,
    wedge_triple_ones,
)

__all__ = [
    "DeviceCarry", "EnginePlan", "Footprint", "MiningSession", "api",
    "edge_cardinalities",
    "fold_edges", "fold_edges_masked", "map_edges", "order_edges_by_hub",
    "pair_cardinality_fn", "plan_for", "pow2_bucket", "resolve_plan",
    "session", "setexpr", "sum_edge_cardinalities",
    "triple_cardinality_ones", "tuple_cardinality_ones", "wedge_quad_ones",
    "wedge_triple_ones",
]
