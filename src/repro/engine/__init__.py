"""Batched mining engine: the shared execution seam for ProbGraph algorithms.

``EnginePlan`` describes *how* set-intersection work runs (batching, Pallas
block shapes, estimator dispatch, edge-axis sharding); ``session`` amortizes
one sketch build across many queries. See engine.py for the full story.
"""
from .plan import (EnginePlan, fold_edges, fold_edges_masked, map_edges,
                   order_edges_by_hub, plan_for)
from .engine import (
    DeviceCarry,
    Footprint,
    MiningSession,
    edge_cardinalities,
    pair_cardinality_fn,
    resolve_plan,
    session,
    sum_edge_cardinalities,
    triple_cardinality_ones,
    wedge_triple_ones,
)

__all__ = [
    "DeviceCarry", "EnginePlan", "Footprint", "MiningSession",
    "edge_cardinalities",
    "fold_edges", "fold_edges_masked", "map_edges", "order_edges_by_hub",
    "pair_cardinality_fn", "plan_for", "resolve_plan", "session",
    "sum_edge_cardinalities", "triple_cardinality_ones", "wedge_triple_ones",
]
