"""Batched mining engine: one execution seam for every ProbGraph algorithm.

Responsibilities (SISA's set-centric batching + GBBS's shared primitives):

  * ``pair_cardinality_fn``  — the |N_u ∩ N_v| provider, plan-dispatched
    between the exact galloping baseline, jnp estimator paths, and the
    block-gather Pallas kernels.
  * ``edge_cardinalities`` / ``sum_edge_cardinalities`` — chunked per-edge
    map / fold over an edge list with degree-ordered layout and optional
    shard_map over the edge axis (repro.distributed.sharding rules).
  * ``tuple_cardinality_ones`` / ``triple_cardinality_ones`` — the k-way
    popcount provider over row-index tuples, compiled from the k-way AND
    set expression (``repro.engine.setexpr``) to one fused block-gather
    pass or the equivalent jnp gather (bit-identical popcounts).
  * ``session`` — multi-query amortization: build the sketch once, run
    TC + LCC + clustering + 4-clique over the shared sketch and the shared
    per-edge cardinality pass.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import Graph
from ..core.intersect import CardFn, make_pair_cardinality_fn
from ..core.sketches import SketchSet, build as build_sketch
from ..distributed import sharding
from ..obs import trace
from . import setexpr
from .plan import (EnginePlan, fold_edges, fold_edges_masked, map_edges,
                   order_edges_by_hub, plan_for, pow2_bucket)

_PLAN_KWARGS = ("edge_chunk", "block_e", "block_w", "use_kernel",
                "degree_order", "estimator", "variant", "shard_edges",
                "sweep_cap", "frontier_mode", "frontier_cap")


def resolve_plan(plan: Optional[EnginePlan], graph: Graph,
                 sketch: Optional[SketchSet] = None, kw: Optional[dict] = None
                 ) -> EnginePlan:
    """Merge legacy per-call kwargs (edge_chunk=, use_kernel=, ...) into a
    plan; keeps the pre-engine algorithm signatures working unchanged."""
    kw = kw or {}
    unknown = set(kw) - set(_PLAN_KWARGS)
    if unknown:
        raise TypeError(f"unknown plan option(s): {sorted(unknown)}")
    if plan is None:
        return plan_for(graph, sketch, **kw)
    return plan.with_(**kw) if kw else plan


def pair_cardinality_fn(graph: Graph, sketch: Optional[SketchSet],
                        plan: EnginePlan) -> CardFn:
    """The single |N_u ∩ N_v| seam, dispatched by the plan."""
    return make_pair_cardinality_fn(
        graph, sketch, use_kernel=plan.use_kernel, variant=plan.variant,
        estimator=plan.estimator, block_e=plan.block_e, block_w=plan.block_w)


def edge_cardinalities(graph: Graph, sketch: Optional[SketchSet],
                       plan: EnginePlan, edges: Optional[jax.Array] = None
                       ) -> jax.Array:
    """Per-edge |N_u ∩ N_v| (float32[m]) in the caller's edge order.

    Degree-ordered layout is applied internally (and inverted on the way
    out) so the kernel path sees hub-clustered blocks.
    """
    fn = pair_cardinality_fn(graph, sketch, plan)
    edges = graph.edges if edges is None else edges
    if plan.degree_order and edges.shape[0] > 1:
        edges_s, inv = order_edges_by_hub(graph, edges)
        return jnp.take(map_edges(edges_s, fn, plan), inv)
    return map_edges(edges, fn, plan)


def sum_edge_cardinalities(graph: Graph, sketch: Optional[SketchSet],
                           plan: EnginePlan,
                           card_fn: Optional[CardFn] = None) -> jax.Array:
    """Σ_{(u,v)∈E} |N_u ∩ N_v| — the TC numerator, fold-executed."""
    fn = card_fn or pair_cardinality_fn(graph, sketch, plan)
    edges = graph.edges
    if plan.degree_order and edges.shape[0] > 1:
        edges, _ = order_edges_by_hub(graph, edges)   # sums need no unsort

    def chunk(pairs, mask):
        """Masked partial sum of one edge chunk's cardinalities."""
        return jnp.sum(jnp.where(mask, fn(pairs), 0.0))

    if plan.shard_edges:
        return _sharded_fold(edges, chunk, plan)
    return fold_edges(edges, chunk, plan)


def _sharded_fold(edges: jax.Array, chunk_fn, plan: EnginePlan) -> jax.Array:
    """shard_map the masked edge fold over the active mesh's edge axes.

    Falls back to the local fold when no mesh is active. Fixed-size sketch
    rows mean every shard does identical work — the paper's no-straggler
    property — so a plain psum closes the reduction.
    """
    from jax.experimental.shard_map import shard_map

    mesh = sharding.active_mesh()
    if mesh is None:
        return fold_edges(edges, chunk_fn, plan)
    spec = sharding.spec_for(("edge", None), mesh=mesh)
    axes = spec[0]
    if axes is None:
        return fold_edges(edges, chunk_fn, plan)
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    nshards = int(np.prod([mesh.shape[a] for a in axes]))
    m = edges.shape[0]
    pad = (-m) % (nshards * min(plan.edge_chunk, max(m, 1)))
    edges_p = jnp.concatenate(
        [edges, jnp.zeros((pad, edges.shape[1]), edges.dtype)], axis=0)
    mask = jnp.concatenate([jnp.ones(m, bool), jnp.zeros(pad, bool)])

    mask_spec = jax.sharding.PartitionSpec(spec[0])

    @functools.partial(shard_map, mesh=mesh, in_specs=(spec, mask_spec),
                       out_specs=jax.sharding.PartitionSpec())
    def fold_shard(edge_shard, mask_shard):
        """Per-shard fold, psum-reduced over the edge axes."""
        local = fold_edges_masked(edge_shard, mask_shard, chunk_fn, plan)
        for ax in axes:
            local = jax.lax.psum(local, ax)
        return local

    return fold_shard(edges_p, mask)


def tuple_cardinality_ones(sketch: SketchSet, tuples: jax.Array,
                           plan: EnginePlan) -> jax.Array:
    """popcnt(AND of the k referenced rows) per tuple — int32[T].

    The plan-dispatched face of the set-expression compiler for the common
    k-way AND: ``tuples`` is int32[T, k] and the cached compiled expression
    lowers to one fused block-gather pass (``plan.use_kernel``) or the
    equivalent jnp gather. Both produce identical popcounts, so downstream
    estimates are bit-identical.
    """
    if sketch.kind != "bf":
        raise ValueError("tuple_cardinality_ones needs a Bloom sketch")
    k = tuples.shape[1]
    ce = setexpr.compile_expr(setexpr.and_all(*setexpr.rows(k)),
                              block_e=plan.block_e, block_w=plan.block_w,
                              use_kernel=plan.use_kernel)
    return ce.ones(sketch.data, tuples)


def triple_cardinality_ones(sketch: SketchSet, triples: jax.Array,
                            plan: EnginePlan) -> jax.Array:
    """popcnt(Bu & Bv & Bw) per (u, v, w) triple — int32[T].

    The k=3 case of :func:`tuple_cardinality_ones` (kept as the named
    4-clique seam).
    """
    return tuple_cardinality_ones(sketch, triples, plan)


def wedge_triple_ones(sketch: SketchSet, u: jax.Array, v: jax.Array,
                      w_grid: jax.Array, plan: EnginePlan) -> jax.Array:
    """popcnt(Bu & Bv & Bw) over a wedge grid: u, v int32[C], w int32[C, d]
    -> int32[C, d] (the 4-clique triple-intersection provider).

    Kernel path flattens to (u, v, w) triples for the 3-way block-gather
    kernel; the jnp path keeps the broadcast form so the u/v rows are
    gathered once per edge rather than once per wedge. Identical integer
    popcounts either way.
    """
    c, d = w_grid.shape
    if plan.use_kernel:
        triples = jnp.stack([
            jnp.broadcast_to(u[:, None], (c, d)).reshape(-1),
            jnp.broadcast_to(v[:, None], (c, d)).reshape(-1),
            w_grid.reshape(-1)], axis=1)
        return triple_cardinality_ones(sketch, triples, plan).reshape(c, d)
    ru = jnp.take(sketch.data, u, axis=0)[:, None, :]
    rv = jnp.take(sketch.data, v, axis=0)[:, None, :]
    rw = jnp.take(sketch.data, w_grid, axis=0)
    return jnp.sum(jax.lax.population_count(ru & rv & rw), axis=-1
                   ).astype(jnp.int32)


def wedge_quad_ones(sketch: SketchSet, u: jax.Array, v: jax.Array,
                    w_grid: jax.Array, x_grid: jax.Array,
                    plan: EnginePlan) -> jax.Array:
    """popcnt(Bu & Bv & Bw & Bx) over a wedge-pair grid: u, v int32[C],
    w int32[C, dw], x int32[C, dx] -> int32[C, dw, dx] (the 5-clique 4-way
    intersection provider).

    Kernel path flattens to (u, v, w, x) quads for the compiled 4-way AND
    expression — the workload that needed no new hand-rolled kernel; the
    jnp path keeps the broadcast form so u/v rows are gathered once per
    edge. Identical integer popcounts either way.
    """
    c, dw = w_grid.shape
    dx = x_grid.shape[1]
    if plan.use_kernel:
        quads = jnp.stack([
            jnp.broadcast_to(u[:, None, None], (c, dw, dx)).reshape(-1),
            jnp.broadcast_to(v[:, None, None], (c, dw, dx)).reshape(-1),
            jnp.broadcast_to(w_grid[:, :, None], (c, dw, dx)).reshape(-1),
            jnp.broadcast_to(x_grid[:, None, :], (c, dw, dx)).reshape(-1),
        ], axis=1)
        return tuple_cardinality_ones(sketch, quads, plan).reshape(c, dw, dx)
    ru = jnp.take(sketch.data, u, axis=0)[:, None, None, :]
    rv = jnp.take(sketch.data, v, axis=0)[:, None, None, :]
    rw = jnp.take(sketch.data, w_grid, axis=0)[:, :, None, :]
    rx = jnp.take(sketch.data, x_grid, axis=0)[:, None, :, :]
    return jnp.sum(jax.lax.population_count(ru & rv & rw & rx), axis=-1
                   ).astype(jnp.int32)


# ----------------------------------------------------------------------------
# answer footprints (the serving tier's invalidation unit)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Footprint:
    """The vertex set one answer was computed from.

    ProbGraph's fixed-size sketch rows make answer provenance *precise*: a
    pair score reads exactly two sketch rows and two degrees, a membership
    test one row, a local cluster the rows/degrees of its PPR support — so
    ``vertices`` lists exactly the vertex ids whose adjacency, degree, or
    sketch row the answer depends on. A result cached above the engine stays
    valid until a delta touches (or a maintenance flush rebuilds) a footprint
    vertex; ``vertices is None`` marks whole-graph answers (triangle counts
    fold every edge) that no delta can survive.
    """

    vertices: Optional[np.ndarray]

    @classmethod
    def whole_graph(cls) -> "Footprint":
        """Footprint of an answer that reads every edge (e.g. TC)."""
        return cls(None)

    @classmethod
    def of(cls, *vertex_sets) -> "Footprint":
        """Union footprint of the given vertex id arrays / scalars."""
        arrs = [np.asarray(a, dtype=np.int64).reshape(-1)
                for a in vertex_sets if a is not None]
        arrs = [a for a in arrs if a.size]
        if not arrs:
            return cls(np.zeros(0, np.int64))
        return cls(np.unique(np.concatenate(arrs)))

    @property
    def is_whole_graph(self) -> bool:
        """True when the answer depends on the entire graph."""
        return self.vertices is None

    def intersects(self, vertices) -> bool:
        """Does any of ``vertices`` invalidate this footprint?"""
        if self.vertices is None:
            return True
        return bool(np.isin(np.asarray(vertices, dtype=np.int64),
                            self.vertices).any())


# ----------------------------------------------------------------------------
# multi-query session
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeviceCarry:
    """Device-resident carry for :meth:`MiningSession.refresh`.

    The host-array carry contract uploads O(m) indices per refresh; a
    device-resident streaming graph instead derives the position carry on
    device (from its edge-list splice) and uploads only the delta-sized
    recompute set, so refresh traffic scales with the delta.

    Attributes:
      carry:         int32[>= m_new] device — new edge j carried old position
                     ``carry[j]`` (>= 0), or < 0 for an inserted edge. Entries
                     in the recompute set may be stale; they are overwritten.
      recompute_pos: int32[R_b] device — positions whose cached cardinality
                     must be recomputed (covers every carry < 0 and every
                     edge with an invalidated endpoint), padded with >= m_new
                     (dropped by the scatter).
      n_recompute:   the true number R of recomputed positions.
      edges_full:    int32[E_cap, 2] device — the capacity-padded edge buffer
                     the recompute edges are gathered from. Its *stable*
                     shape keeps the gather's compiled program cached across
                     deltas (graph.edges is [m, 2] and m changes every
                     delta); rows at padded positions are sentinels whose
                     cardinalities the scatter drops.
    """

    carry: jax.Array
    recompute_pos: jax.Array
    n_recompute: int
    edges_full: jax.Array


@functools.partial(jax.jit, static_argnames=("m_new",))
def _carry_cards(old_cards, carry, *, m_new):
    c = jnp.clip(carry[:m_new], 0, old_cards.shape[0] - 1)
    return jnp.take(old_cards, c)


@functools.partial(jax.jit, static_argnames=("m_new",))
def _carry_scatter_cards(old_cards, carry, pos, sub, *, m_new):
    """One fused program per (m_old, m_new, R_b): slice-gather the carried
    cardinalities, overwrite the recomputed subset (padded pos >= m_new are
    dropped)."""
    c = jnp.clip(carry[:m_new], 0, old_cards.shape[0] - 1)
    return jnp.take(old_cards, c).at[pos].set(sub, mode="drop")


class MiningSession:
    """Amortizes one sketch build + one per-edge cardinality pass across
    TC, LCC, Jarvis-Patrick and 4-clique queries on the same graph."""

    def __init__(self, graph: Graph, sketch: Optional[SketchSet],
                 plan: EnginePlan):
        self.graph = graph
        self.sketch = sketch
        self.plan = plan
        self._edge_cards: Optional[jax.Array] = None

    def fork(self) -> "MiningSession":
        """Copy-on-write twin sharing this session's state by reference.

        Every field a session mutates (``graph``, ``sketch``,
        ``_edge_cards``) is only ever *rebound*, never edited in place, so a
        fork plus :meth:`refresh` builds the next version's session while
        the original keeps serving the old one untouched — the
        snapshot-isolation seam ``StreamSession`` publishes through.
        """
        new = MiningSession(self.graph, self.sketch, self.plan)
        new._edge_cards = self._edge_cards
        return new

    def edge_cardinalities(self) -> jax.Array:
        """Cached |N_u ∩ N_v| over graph.edges (the shared mining pass)."""
        if self._edge_cards is None:
            with trace.span("engine.edge_cards",
                            edges=int(self.graph.m)) as sp:
                self._edge_cards = sp.fence(edge_cardinalities(
                    self.graph, self.sketch, self.plan))
        return self._edge_cards

    def triangle_count(self) -> jax.Array:
        """Scalar TC estimate from the shared per-edge cardinality pass."""
        return jnp.sum(self.edge_cardinalities()) / 3.0

    def local_clustering(self) -> jax.Array:
        """Per-vertex clustering coefficients float32[n] (shared pass)."""
        from ..core.algorithms.tc import local_clustering_coefficient
        return local_clustering_coefficient(
            self.graph, self.sketch, plan=self.plan,
            edge_cards=self.edge_cardinalities())

    def jarvis_patrick(self, similarity: str = "common",
                       threshold: float = 2.0):
        """Jarvis–Patrick clustering ``(labels int32[n], num_clusters)``."""
        from ..core.algorithms.clustering import jarvis_patrick
        return jarvis_patrick(self.graph, self.sketch, similarity, threshold,
                              plan=self.plan,
                              edge_cards=self.edge_cardinalities())

    def four_clique_count(self, **kw) -> jax.Array:
        """Scalar 4-clique count estimate (3-way sketch intersections)."""
        from ..core.algorithms.cliques import four_clique_count
        return four_clique_count(self.graph, self.sketch, plan=self.plan, **kw)

    def five_clique_count(self, **kw) -> jax.Array:
        """Scalar 5-clique count estimate (4-way sketch intersections)."""
        from ..core.algorithms.cliques import five_clique_count
        return five_clique_count(self.graph, self.sketch, plan=self.plan,
                                 **kw)

    def similarity(self, pairs: jax.Array, measure: str = "jaccard"
                   ) -> jax.Array:
        """Similarity scores float32[P] for vertex pairs int32[P, 2]."""
        from ..core.algorithms.similarity import pair_similarity
        return pair_similarity(self.graph, pairs, measure, self.sketch,
                               plan=self.plan)

    def local_cluster(self, seeds, alpha: float = 0.15, eps: float = 1e-4,
                      **kw):
        """Seed-centric local clustering (PPR push + sketch-gated sweep).

        Args:
          seeds: int32[S] (or scalar) seed vertex ids; the whole batch runs
                 as one vmapped push + sweep.
          alpha: PPR teleport probability.
          eps:   push tolerance (residual threshold per unit degree).
          **kw:  forwarded to :func:`core.algorithms.localcluster.local_cluster`
                 (e.g. ``max_iters=``, or plan overrides such as
                 ``frontier_mode=`` / ``frontier_cap=``).

        Returns:
          A :class:`~repro.core.algorithms.localcluster.LocalClusterResult`
          with per-seed sweep order, conductance profile and best prefix.
          The push frontier layout (dense ``[S, n]`` vs capped sparse
          ``[S, cap]``) follows the session plan's ``frontier_mode``.
        """
        from ..core.algorithms.localcluster import local_cluster
        with trace.span("engine.local_cluster", alpha=float(alpha),
                        eps=float(eps)) as sp:
            res = local_cluster(self.graph, seeds, alpha, eps, self.sketch,
                                plan=self.plan, **kw)
            sp.set(sparse=res.frontier is not None, spilled=bool(res.spilled))
            return res

    def edge_similarity(self, measure: str = "jaccard") -> jax.Array:
        """Similarity scores over graph.edges from the cached shared pass."""
        from ..core.algorithms.similarity import similarity_from_cardinalities
        edges = self.graph.edges
        du = jnp.take(self.graph.deg, edges[:, 0]).astype(jnp.float32)
        dv = jnp.take(self.graph.deg, edges[:, 1]).astype(jnp.float32)
        return similarity_from_cardinalities(self.edge_cardinalities(),
                                             du, dv, measure)

    def refresh(self, graph: Graph, sketch: Optional[SketchSet] = None,
                carry_index: Optional[np.ndarray] = None) -> Optional[int]:
        """Delta-aware cache invalidation: repoint the session at an updated
        (graph, sketch) and recompute only the invalidated edge cardinalities.

        ``carry_index[j]`` is the position of new edge j in the *previous*
        ``graph.edges`` when its cached cardinality is still valid (neither
        endpoint's neighborhood, degree, or sketch row changed), or -1 to
        recompute. With ``carry_index=None`` the whole cache is dropped.
        A :class:`DeviceCarry` keeps the whole exchange on device (carried
        values are gathered by the device permutation, only the delta-sized
        recompute positions were uploaded). Returns the number of per-edge
        cardinalities recomputed, or ``None`` when the cache was dropped
        instead (the full pass then happens lazily — nothing was carried
        over).

        Per-pair estimators are elementwise in the pair, so recomputing only
        the invalidated subset is bit-identical to a from-scratch pass.
        """
        with trace.span("engine.refresh") as sp:
            result = self._refresh(graph, sketch, carry_index)
            sp.set(recomputed=-1 if result is None else result)
            return result

    def _refresh(self, graph, sketch, carry_index):
        old_cards = self._edge_cards
        self.graph = graph
        if sketch is not None:
            self.sketch = sketch
        if (old_cards is None or carry_index is None
                or int(old_cards.shape[0]) == 0):
            self._edge_cards = None
            return None
        if isinstance(carry_index, DeviceCarry):
            return self._refresh_device(old_cards, carry_index)
        carry = np.asarray(carry_index, dtype=np.int64)
        if carry.shape[0] == 0:
            self._edge_cards = jnp.zeros((0,), jnp.float32)
            return 0
        recompute = np.nonzero(carry < 0)[0]
        cards = jnp.take(old_cards, jnp.asarray(np.where(carry < 0, 0, carry)))
        if recompute.size:
            # pad the subset to a power-of-two bucket so repeated deltas of
            # varying size reuse one compiled cardinality program per bucket
            bucket = pow2_bucket(recompute.size)
            edges_np = np.asarray(graph.edges)
            sub_edges = np.zeros((bucket, 2), dtype=edges_np.dtype)
            sub_edges[:recompute.size] = edges_np[recompute]
            sub = edge_cardinalities(self.graph, self.sketch, self.plan,
                                     edges=jnp.asarray(sub_edges))
            cards = cards.at[jnp.asarray(recompute)].set(
                sub[:recompute.size])
        self._edge_cards = cards
        return int(recompute.size)

    def _refresh_device(self, old_cards: jax.Array, dc: DeviceCarry) -> int:
        """Device-side cache carry: gather by the splice permutation, then
        recompute only the invalidated positions (edges gathered on device,
        no host round-trip)."""
        m_new = self.graph.m
        if m_new == 0:
            self._edge_cards = jnp.zeros((0,), jnp.float32)
            return 0
        if dc.n_recompute:
            # gather from the stable-shape buffer so the compiled gather is
            # reused across deltas; padded positions hit sentinel rows whose
            # (garbage) cardinalities the fused scatter below drops. Clamp
            # the sentinel vertex id n to a real row first: the Pallas
            # kernel path DMAs rows by raw index and must never see an
            # out-of-bounds one (the jnp path would merely clip).
            sub_edges = jnp.minimum(
                jnp.take(dc.edges_full, dc.recompute_pos, axis=0),
                jnp.int32(max(self.graph.n - 1, 0)))
            sub = edge_cardinalities(self.graph, self.sketch, self.plan,
                                     edges=sub_edges)
            self._edge_cards = _carry_scatter_cards(
                old_cards, dc.carry, dc.recompute_pos, sub, m_new=m_new)
        else:
            self._edge_cards = _carry_cards(old_cards, dc.carry, m_new=m_new)
        return int(dc.n_recompute)

    def stats(self) -> dict:
        """Session facts: graph sizes, sketch kind/bytes, JSON-able plan."""
        sk = self.sketch
        return {
            "n": self.graph.n, "m": self.graph.m,
            "sketch": sk.kind if sk is not None else "exact",
            "sketch_bytes": int(sk.data.size * sk.data.dtype.itemsize)
            if sk is not None else 0,
            "plan": dataclasses.asdict(self.plan),
        }


def session(graph: Graph, sketch: Optional[SketchSet] | str = "bf",
            storage_budget: float = 0.25, num_hashes: int = 2, seed: int = 0,
            plan: Optional[EnginePlan] = None, **plan_kw) -> MiningSession:
    """Open a multi-query mining session over one shared sketch build.

    ``sketch`` may be a prebuilt SketchSet, a kind string ("bf" | "kh" |
    "1h" | "kmv") to build here, or None for the exact baseline.
    """
    if isinstance(sketch, str):
        sketch = build_sketch(graph, sketch, storage_budget,
                              num_hashes=num_hashes, seed=seed)
    return MiningSession(graph, sketch, resolve_plan(plan, graph, sketch,
                                                     plan_kw))
