"""Deterministic synthetic LM data pipeline.

Production shape: documents -> (MinHash dedup, see dedup.py) -> token stream
-> packed fixed-length sequences -> per-host sharded batches. The synthetic
corpus is a mixture of order-2 Markov chains so a ~100M model demonstrably
learns (loss drops well below unigram entropy) in a few hundred steps —
used by examples/train_small.py.

Determinism contract: batch content is a pure function of (seed, step),
independent of host count — restart/elastic-resume safe.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    seed: int = 0
    num_modes: int = 8          # distinct Markov chains (≈ document styles)
    branch: int = 4             # out-degree of each state

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # per-mode sparse transition tables: next token = table[mode, cur, br]
        self._table = rng.integers(0, v, size=(self.num_modes, v, self.branch),
                                   dtype=np.int64)

    def batch(self, step: int, global_batch: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        b, s = global_batch, self.seq_len
        mode = rng.integers(0, self.num_modes, size=(b, 1))
        seq = np.empty((b, s + 1), dtype=np.int64)
        seq[:, 0] = rng.integers(0, self.vocab_size, size=b)
        choices = rng.integers(0, self.branch, size=(b, s))
        rows = np.arange(b)
        for t in range(s):
            seq[:, t + 1] = self._table[mode[:, 0], seq[:, t], choices[:, t]]
        return {"inputs": seq[:, :-1].astype(np.int32),
                "labels": seq[:, 1:].astype(np.int32)}

    def batches(self, global_batch: int, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, global_batch)
            step += 1


class TokenBatcher:
    """Packs a ragged token-document stream into fixed [B, S] batches."""

    def __init__(self, docs, seq_len: int, pad_id: int = 0):
        self.seq_len = seq_len
        self.pad_id = pad_id
        stream = np.concatenate([np.asarray(d, np.int32) for d in docs]) \
            if docs else np.zeros((0,), np.int32)
        self.stream = stream

    def num_batches(self, batch: int) -> int:
        per = batch * self.seq_len
        return int(len(self.stream) // per)

    def batch(self, i: int, batch: int) -> Dict[str, np.ndarray]:
        per = batch * self.seq_len
        chunk = self.stream[i * per:(i + 1) * per]
        if len(chunk) < per:
            chunk = np.pad(chunk, (0, per - len(chunk)), constant_values=self.pad_id)
        x = chunk.reshape(batch, self.seq_len)
        y = np.roll(x, -1, axis=1)
        return {"inputs": x, "labels": y}
