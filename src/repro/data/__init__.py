from .pipeline import SyntheticLMData, TokenBatcher
from .dedup import minhash_dedup, document_sketches

__all__ = ["SyntheticLMData", "TokenBatcher", "minhash_dedup", "document_sketches"]
