"""MinHash near-duplicate dedup — the ProbGraph technique inside the LM
data pipeline (DESIGN.md §4.1).

Documents -> w-gram shingles -> k-Hash MinHash sketches (core.hashing, same
murmur3 finalizer as the graph sketches) -> LSH banding for candidate pairs
-> Jaccard estimate Ĵ_kH = matches/k -> drop docs with Ĵ ≥ threshold.

The paper's Prop IV.2 bound makes k quantitative:
P(|Ĵ−J| ≥ t) ≤ 2·exp(−2kt²), so ``k_for(j_gap, delta)`` returns the sketch
size guaranteeing false-match probability ≤ delta at a Jaccard margin j_gap.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.hashing import np_hash_u32

_GOLDEN = 0x9E3779B9


def k_for(j_gap: float, delta: float) -> int:
    """Smallest k with P(|Ĵ−J| ≥ j_gap) ≤ delta (Hoeffding/Prop IV.2 on Ĵ)."""
    return int(np.ceil(np.log(2.0 / delta) / (2.0 * j_gap ** 2)))


def _shingles(tokens: np.ndarray, w: int) -> np.ndarray:
    """Rolling w-gram hashes of a token array (uint32)."""
    tokens = np.asarray(tokens, dtype=np.uint32)
    if len(tokens) < w:
        return np_hash_u32(tokens, 7)
    h = np.zeros(len(tokens) - w + 1, dtype=np.uint32)
    with np.errstate(over="ignore"):
        for i in range(w):
            h = (h * np.uint32(1000003)) ^ np_hash_u32(tokens[i:i + len(h)], 7 + i)
    return h


def document_sketches(docs: Sequence[np.ndarray], k: int, w: int = 5,
                      seed: int = 0) -> np.ndarray:
    """k-Hash MinHash sketches over shingles: uint32[N, k] (min hash values)."""
    out = np.full((len(docs), k), 0xFFFFFFFF, dtype=np.uint32)
    for di, doc in enumerate(docs):
        sh = _shingles(doc, w)
        if len(sh) == 0:
            continue
        for i in range(k):
            s = np.uint32((i + seed * _GOLDEN) & 0xFFFFFFFF)
            out[di, i] = np_hash_u32(sh, int(s)).min()
    return out


def jaccard_estimate(sk_a: np.ndarray, sk_b: np.ndarray) -> float:
    """Ĵ_kH = aligned matches / k (paper Eq. 5 numerator)."""
    return float(np.mean(sk_a == sk_b))


def minhash_dedup(docs: Sequence[np.ndarray], threshold: float = 0.8,
                  k: int = 64, w: int = 5, bands: int = 0,
                  seed: int = 0) -> Tuple[np.ndarray, Dict]:
    """Returns (keep mask bool[N], stats). Keeps the first doc of each
    near-duplicate group (banded-LSH candidates, Ĵ_kH confirmation)."""
    n = len(docs)
    if bands <= 0:
        bands = max(8, k // 4)   # 4 rows/band: P(candidate) ≈ 1 at J ≥ 0.7
    sketches = document_sketches(docs, k, w, seed)
    rows_per_band = max(1, k // bands)
    buckets: Dict[Tuple[int, bytes], List[int]] = defaultdict(list)
    for di in range(n):
        for b in range(bands):
            band = sketches[di, b * rows_per_band:(b + 1) * rows_per_band]
            buckets[(b, band.tobytes())].append(di)

    keep = np.ones(n, dtype=bool)
    checked = 0
    dropped_pairs = []
    for key, members in buckets.items():
        if len(members) < 2:
            continue
        members = sorted(members)
        anchor = members[0]
        for other in members[1:]:
            if not keep[other] or not keep[anchor]:
                continue
            checked += 1
            j = jaccard_estimate(sketches[anchor], sketches[other])
            if j >= threshold:
                keep[other] = False
                dropped_pairs.append((anchor, other, j))
    stats = {"checked_pairs": checked, "dropped": int((~keep).sum()),
             "dropped_pairs": dropped_pairs[:32], "k": k, "bands": bands}
    return keep, stats
