"""Expert-parallel MoE via shard_map (the §Perf fix for GSPMD dispatch).

Problem (baseline, see EXPERIMENTS.md §Perf): the scatter-based capacity
dispatch in `layers.moe_fwd` makes GSPMD materialize and **all-reduce the
whole [E·C, d] dispatch buffer over the data axis** (deepseek train_4k:
8.4 TB all-reduce + 4.4 TB all-to-all per device per step).

Insight: activations are *batch-sharded only* — every model-axis rank
already holds its data-shard's full token slab. So expert dispatch needs no
token movement at all: each (data, model) device gathers, from its local
tokens, the ones routed to ITS experts (experts are sharded over 'model'),
runs its expert FFNs, scatters partial outputs back to local token slots,
and a single `psum` over 'model' combines expert contributions — the same
collective shape as ordinary tensor parallelism (2(g-1)/g · t_loc · d
bytes/layer instead of the buffer-sized all-reduce).

Capacity becomes per-(data-shard × expert): C_loc = t_loc·k/E·cf — dropping
decisions are local, which is how real EP systems behave under skew.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as SH
from .config import ModelConfig
from .layers import _act, mlp_fwd

Params = dict


def _dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def moe_fwd_ep(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Drop-in for layers.moe_fwd when a mesh with a 'model' axis is active
    and the expert count divides it. Falls back to the caller otherwise."""
    mesh = SH._CTX.mesh
    if mesh is None or "model" not in mesh.shape \
            or cfg.moe_num_experts % mesh.shape["model"] != 0:
        from .layers import moe_fwd
        return moe_fwd(p, x, cfg)

    dp = _dp_axes(mesh)
    ep = mesh.shape["model"]
    b, s, d = x.shape
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    e_loc = e // ep

    x_spec = P(dp, None, None)           # batch-sharded, replicated on model
    router_spec = P(None, None)
    # expert weights stay ZeRO-3 sharded at rest (expert -> model, d -> data)
    # and are all-gathered over 'data' just-in-time inside the block.
    wi_spec = P("model", "data", None)
    wo_spec = P("model", "data", None)
    # shared experts: TP-sharded on ff inside the block; their partial output
    # joins the experts' psum, so the layer pays ONE all-reduce total and no
    # duplicate compute.
    shared = p.get("shared") if cfg.moe_shared_experts else None
    if shared is not None:
        sh_in_spec = P(None, "model")
        sh_out_spec = P("model", None)
        sh_args = (shared["wi_gate"], shared["wi_up"], shared["wo"])
    else:  # replicated placeholders so the block signature is static
        sh_in_spec = sh_out_spec = P(None, None)
        z = jnp.zeros((1, 1), x.dtype)
        sh_args = (z, z, z)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(x_spec, router_spec, wi_spec, wi_spec, wo_spec,
                  sh_in_spec, sh_in_spec, sh_out_spec),
        out_specs=x_spec, check_rep=False)
    def ep_block(x_loc, router, wi_gate, wi_up, wo, sh_gate, sh_up, sh_wo):
        if "data" in mesh.shape and mesh.shape["data"] > 1:
            wi_gate = lax.all_gather(wi_gate, "data", axis=1, tiled=True)
            wi_up = lax.all_gather(wi_up, "data", axis=1, tiled=True)
            wo = lax.all_gather(wo, "data", axis=1, tiled=True)
        bl, sl, _ = x_loc.shape
        t_loc = bl * sl
        cap = max(1, int(math.ceil(t_loc * k / e * cfg.capacity_factor)))
        xt = x_loc.reshape(t_loc, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = lax.top_k(probs, k)                       # [t_loc, k]
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

        my_first = lax.axis_index("model") * e_loc
        # rank-within-(local)expert via sort over the local assignment list
        e_flat = idx.reshape(t_loc * k)
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = jnp.take(e_flat, order)
        counts = jax.ops.segment_sum(jnp.ones_like(e_sorted, jnp.int32),
                                     e_sorted, num_segments=e)
        starts = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(t_loc * k, dtype=jnp.int32) - jnp.take(starts, e_sorted)
        rank = jnp.zeros((t_loc * k,), jnp.int32).at[order].set(rank_sorted)

        local_e = e_flat - my_first
        mine = (local_e >= 0) & (local_e < e_loc) & (rank < cap)
        dest = jnp.where(mine, local_e * cap + rank, e_loc * cap)
        tok_of = jnp.arange(t_loc * k, dtype=jnp.int32) // k

        buf = jnp.zeros((e_loc * cap + 1, d), xt.dtype)
        buf = buf.at[dest].add(jnp.take(xt, tok_of, axis=0))
        buf = buf[:-1].reshape(e_loc, cap, d)

        h = _act(cfg)(jnp.einsum("ecd,edf->ecf", buf, wi_gate))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wi_up)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo)

        flat_out = jnp.concatenate(
            [out_buf.reshape(e_loc * cap, d), jnp.zeros((1, d), out_buf.dtype)],
            axis=0)
        y_assign = jnp.take(flat_out, dest, axis=0)
        y = jnp.sum(y_assign.reshape(t_loc, k, d)
                    * gates.astype(y_assign.dtype)[..., None], axis=1)
        y = y.astype(x_loc.dtype)
        if shared is not None:
            # ff-sharded shared expert: partial [t, d] joins the same psum
            hs = _act(cfg)(jnp.einsum("td,df->tf", xt, sh_gate))
            hs = hs * jnp.einsum("td,df->tf", xt, sh_up)
            y = y + jnp.einsum("tf,fd->td", hs, sh_wo)
        # ONE all-reduce combines routed-expert and shared contributions;
        # wire format stays in the compute dtype (fp32 promotion from the
        # gates would double the bytes)
        y = lax.psum(y, "model")
        return y.reshape(bl, sl, d)

    return ep_block(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"],
                    *sh_args)
