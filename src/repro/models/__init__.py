"""Assigned architecture pool: model definitions in pure JAX."""
from .config import ModelConfig, ShapeConfig, SHAPES, reduced
from .model import (
    init_params, forward, loss_fn, init_cache, decode_step,
    params_logical_axes, cache_logical_axes, build_plan, layer_sigs,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "reduced",
    "init_params", "forward", "loss_fn", "init_cache", "decode_step",
    "params_logical_axes", "cache_logical_axes", "build_plan", "layer_sigs",
]
