"""Model / shape configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    act: str = "silu"               # "silu" | "gelu" (both gated: SwiGLU/GeGLU)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_kind: str = "default"      # "default" | "mrope"
    mrope_sections: Tuple[int, ...] = ()
    sliding_window: int = 0         # 0 = full attention

    # MoE
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_experts: int = 0     # deepseek shared expert count
    moe_layer_start: int = 0        # first MoE layer (leading layers are dense)
    moe_every: int = 1              # MoE applied every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    moe_impl: str = "gspmd"         # "gspmd" | "ep" (shard_map expert parallel)

    # MLA (deepseek-v3)
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 SSD)
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4

    # hybrid interleave (jamba): kinds within one repeating period
    layer_pattern: Tuple[str, ...] = ()   # e.g. ("ssm","ssm","ssm","attn",...)

    input_mode: str = "tokens"      # "tokens" | "embeddings" (stub frontend)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: bool = True
    logical_rules: Optional[str] = None   # sharding rule-set override name

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the 'model' mesh axis always divides it
        (embedding/head/logits shard cleanly). Logits beyond vocab_size are
        masked to -1e30; labels never reference the pad region."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.ssm and not self.layer_pattern and self.num_heads == 0

    @property
    def period(self) -> int:
        return len(self.layer_pattern) if self.layer_pattern else 1

    def active_params(self) -> int:
        """Approximate active parameter count (per-token, MoE-aware)."""
        return _param_count(self, active_only=True)

    def total_params(self) -> int:
        return _param_count(self, active_only=False)


def _param_count(c: ModelConfig, active_only: bool) -> int:
    d = c.d_model
    emb = c.vocab_size * d * (1 if c.tie_embeddings else 2)

    def attn_params() -> int:
        if c.mla:
            q = d * c.q_lora_rank + c.q_lora_rank * c.num_heads * (c.qk_nope_head_dim + c.qk_rope_head_dim)
            kv = d * (c.kv_lora_rank + c.qk_rope_head_dim)
            kv += c.kv_lora_rank * c.num_heads * (c.qk_nope_head_dim + c.v_head_dim)
            o = c.num_heads * c.v_head_dim * d
            return q + kv + o
        q = d * c.num_heads * c.head_dim
        kv = 2 * d * c.num_kv_heads * c.head_dim
        o = c.num_heads * c.head_dim * d
        return q + kv + o

    def mlp_params(ff: int) -> int:
        return 3 * d * ff

    def moe_params() -> int:
        n_active = c.moe_top_k + c.moe_shared_experts
        n = n_active if active_only else (c.moe_num_experts + c.moe_shared_experts)
        return n * mlp_params(c.moe_d_ff) + d * c.moe_num_experts

    def ssm_params() -> int:
        di, h, n = c.ssm_d_inner, c.ssm_heads, c.ssm_state
        in_proj = d * (2 * di + 2 * n + h)
        out = di * d
        return in_proj + out + c.ssm_conv * (di + 2 * n) + 2 * h

    total = emb
    pattern = c.layer_pattern or (("ssm",) if c.attention_free else ("attn",))
    reps = c.num_layers // len(pattern)
    for li in range(c.num_layers):
        kind = pattern[li % len(pattern)]
        if kind == "attn" or not c.layer_pattern and not c.ssm:
            total += attn_params()
        if kind == "ssm" or (c.ssm and not c.layer_pattern):
            total += ssm_params()
        # feed-forward
        is_moe = (c.moe_num_experts > 0 and li >= c.moe_layer_start
                  and (li - c.moe_layer_start) % c.moe_every == 0)
        if is_moe:
            total += moe_params()
        elif not (c.ssm and not c.layer_pattern):
            total += mlp_params(c.d_ff)
    return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced(c: ModelConfig, layers: int = 2, d_model: int = 64, heads: int = 4,
            kv: Optional[int] = None, ff: int = 128, vocab: int = 512) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw: dict = dict(
        num_layers=layers, d_model=d_model, d_ff=ff, vocab_size=vocab)
    if c.num_heads:
        kw.update(num_heads=heads, num_kv_heads=min(kv if kv is not None else max(1, heads // 2), heads),
                  head_dim=max(8, d_model // heads))
    else:
        kw.update(num_heads=0, num_kv_heads=0, head_dim=0)
    if c.moe_num_experts:
        kw.update(moe_num_experts=4, moe_top_k=2, moe_d_ff=ff,
                  moe_shared_experts=min(c.moe_shared_experts, 1),
                  moe_layer_start=min(c.moe_layer_start, 1), moe_every=c.moe_every)
    if c.mla:
        kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16)
    if c.ssm:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if c.layer_pattern:
        pat = c.layer_pattern[:4] if layers % len(c.layer_pattern[:4]) == 0 else c.layer_pattern
        # keep a 1-attn + (p-1)-ssm period that divides num_layers
        kw.update(layer_pattern=("attn", "ssm"), num_layers=max(2, layers - layers % 2))
    if c.sliding_window:
        kw.update(sliding_window=64)
    if c.mrope_sections:
        hd = kw.get("head_dim", 16)
        kw.update(mrope_sections=(hd // 4, hd // 8, hd // 8))
    return dataclasses.replace(c, **kw)
