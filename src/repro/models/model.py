"""Model assembly: stacked-parameter scan-over-layers, heterogeneous block
planning (dense/MoE prefixes, hybrid periods), train loss and cached decode.

Layers are grouped into homogeneous *blocks* so `lax.scan` keeps the HLO size
O(1) in depth (MaxText-style):

  * dense/MoE uniform stacks -> one scan each (deepseek: 3 dense + 58 MoE)
  * jamba's (attn + 7×ssm, alternating MoE) period -> scan over 9 periods
    whose body unrolls the 8 sublayers

Params are nested dicts. Logical sharding axes come from the *axes twins*
(`params_logical_axes` / `cache_logical_axes`) which never materialize
arrays, so the 671B dry-run can build shardings from `jax.eval_shape` alone.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard_as
from . import layers as L
from . import ssm as SSM
from .config import ModelConfig

Params = Dict[str, Any]
LayerSig = Tuple[str, str]  # (mixer_kind, ffn_kind)


# ---------------------------------------------------------------------------
# Block planning
# ---------------------------------------------------------------------------

def layer_sigs(cfg: ModelConfig) -> List[LayerSig]:
    sigs = []
    for li in range(cfg.num_layers):
        if cfg.layer_pattern:
            mixer = cfg.layer_pattern[li % len(cfg.layer_pattern)]
        elif cfg.ssm:
            mixer = "ssm"
        elif cfg.mla:
            mixer = "mla"
        else:
            mixer = "attn"
        if cfg.ssm and not cfg.layer_pattern:
            ffn = "none"  # pure mamba block has no separate FFN
        elif (cfg.moe_num_experts > 0 and li >= cfg.moe_layer_start
              and (li - cfg.moe_layer_start) % cfg.moe_every == 0):
            ffn = "moe"
        else:
            ffn = "mlp"
        sigs.append((mixer, ffn))
    return sigs


@dataclasses.dataclass(frozen=True)
class Block:
    sigs: Tuple[LayerSig, ...]   # sublayers unrolled inside the scan body
    repeat: int                  # scan length


def build_plan(cfg: ModelConfig) -> List[Block]:
    sigs = layer_sigs(cfg)
    n = len(sigs)
    runs: List[Tuple[LayerSig, int]] = []
    for s in sigs:
        if runs and runs[-1][0] == s:
            runs[-1] = (s, runs[-1][1] + 1)
        else:
            runs.append((s, 1))
    if len(runs) <= 4:
        return [Block((s,), c) for s, c in runs]
    for p in range(1, min(n, 16) + 1):
        if n % p == 0 and all(sigs[i] == sigs[i % p] for i in range(n)):
            return [Block(tuple(sigs[:p]), n // p)]
    return [Block((s,), 1) for s in sigs]


# ---------------------------------------------------------------------------
# Sublayer init / apply
# ---------------------------------------------------------------------------

def _init_sublayer(cfg: ModelConfig, sig: LayerSig, key) -> Params:
    mixer, ffn = sig
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    p: Params = {"ln1": L.init_rms_norm(cfg.d_model, dt)[0]}
    if mixer == "attn":
        p["mixer"] = L.init_attention(cfg, k1)[0]
    elif mixer == "mla":
        p["mixer"] = L.init_mla(cfg, k1)[0]
    elif mixer == "ssm":
        p["mixer"] = SSM.init_ssm(cfg, k1)[0]
    else:
        raise ValueError(mixer)
    if ffn != "none":
        p["ln2"] = L.init_rms_norm(cfg.d_model, dt)[0]
        p["ffn"] = (L.init_moe(cfg, k2)[0] if ffn == "moe"
                    else L.init_mlp(cfg, k2)[0])
    return p


def _sublayer_axes(cfg: ModelConfig, sig: LayerSig) -> Params:
    mixer, ffn = sig
    ax: Params = {"ln1": ("embed",)}
    if mixer == "attn":
        ax["mixer"] = {
            "wq": ("embed_fsdp", "heads", "head_dim_tp"),
            "wk": ("embed_fsdp", "kv_heads", "head_dim_tp"),
            "wv": ("embed_fsdp", "kv_heads", "head_dim_tp"),
            "wo": ("heads", "head_dim_tp", "embed_fsdp"),
        }
        if cfg.qk_norm:
            ax["mixer"]["q_norm"] = ("head_dim",)
            ax["mixer"]["k_norm"] = ("head_dim",)
    elif mixer == "mla":
        ax["mixer"] = {
            "wdq": ("embed_fsdp", "q_lora"), "q_norm": ("q_lora",),
            "wuq": ("q_lora", "heads", None),
            "wdkv": ("embed_fsdp", "kv_lora"), "kv_norm": ("kv_lora",),
            "wuk": ("kv_lora", "heads", None), "wuv": ("kv_lora", "heads", None),
            "wo": ("heads", None, "embed_fsdp"),
        }
    else:
        ax["mixer"] = {
            "z_proj": ("embed_fsdp", "ssm_inner"),
            "x_proj": ("embed_fsdp", "ssm_inner"),
            "bc_proj": ("embed_fsdp", None),
            "dt_proj": ("embed_fsdp", None),
            "conv_wx": ("conv", "ssm_inner"), "conv_bx": ("ssm_inner",),
            "conv_wbc": ("conv", None), "conv_bbc": (None,),
            "a_log": (None,), "d_skip": (None,),
            "dt_bias": (None,), "norm": ("ssm_inner",),
            "out_proj": ("ssm_inner", "embed_fsdp"),
        }
    if ffn != "none":
        ax["ln2"] = ("embed",)
        if ffn == "moe":
            ax["ffn"] = {
                "router": ("embed", None),
                "wi_gate": ("expert", "embed_fsdp", "ff"),
                "wi_up": ("expert", "embed_fsdp", "ff"),
                "wo": ("expert", "ff", "embed_fsdp"),
            }
            if cfg.moe_shared_experts:
                ax["ffn"]["shared"] = {"wi_gate": ("embed_fsdp", "ff"),
                                       "wi_up": ("embed_fsdp", "ff"),
                                       "wo": ("ff", "embed_fsdp")}
        else:
            ax["ffn"] = {"wi_gate": ("embed_fsdp", "ff"),
                         "wi_up": ("embed_fsdp", "ff"),
                         "wo": ("ff", "embed_fsdp")}
    return ax


def _apply_sublayer(cfg: ModelConfig, sig: LayerSig, p: Params, x: jax.Array,
                    positions: jax.Array) -> jax.Array:
    mixer, ffn = sig
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        h = L.attention_fwd(p["mixer"], h, cfg, positions)
    elif mixer == "mla":
        h = L.mla_fwd(p["mixer"], h, cfg, positions)
    else:
        h = SSM.ssm_fwd(p["mixer"], h, cfg)
    x = x + h
    if ffn != "none":
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            h2 = _moe(p["ffn"], h2, cfg)
        else:
            h2 = L.mlp_fwd(p["ffn"], h2, cfg)
        x = x + h2
    return shard_as(x, "batch", "seq", "embed_act")


def _moe(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.moe_impl == "ep":
        from .moe_ep import moe_fwd_ep
        return moe_fwd_ep(p, x, cfg)
    return L.moe_fwd(p, x, cfg)


def _decode_sublayer(cfg: ModelConfig, sig: LayerSig, p: Params, cache: Params,
                     x: jax.Array, pos: jax.Array) -> Tuple[jax.Array, Params]:
    mixer, ffn = sig
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    if mixer == "attn":
        h, new_cache = L.attention_decode(p["mixer"], h, cache, cfg, pos)
    elif mixer == "mla":
        h, new_cache = L.mla_decode(p["mixer"], h, cache, cfg, pos)
    else:
        h, new_cache = SSM.ssm_decode(p["mixer"], h, cache, cfg, pos)
    x = x + h
    if ffn != "none":
        h2 = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if ffn == "moe":
            h2 = _moe(p["ffn"], h2, cfg)
        else:
            h2 = L.mlp_fwd(p["ffn"], h2, cfg)
        x = x + h2
    return x, new_cache


def _init_sublayer_cache(cfg: ModelConfig, sig: LayerSig, batch: int,
                         max_len: int, dtype) -> Params:
    mixer, _ = sig
    if mixer == "attn":
        return L.init_attention_cache(cfg, batch, max_len, dtype)[0]
    if mixer == "mla":
        return L.init_mla_cache(cfg, batch, max_len, dtype)[0]
    return SSM.init_ssm_cache(cfg, batch, dtype)[0]


def _sublayer_cache_axes(cfg: ModelConfig, sig: LayerSig) -> Params:
    mixer, _ = sig
    if mixer == "attn":
        axes = ("batch", "decode_cache_seq", "kv_heads", None)
        return {"k": axes, "v": axes}
    if mixer == "mla":
        return {"ckv": ("batch", "decode_cache_seq", None),
                "krope": ("batch", "decode_cache_seq", None)}
    return {"conv_x": ("batch", None, "ssm_inner"),
            "conv_bc": ("batch", None, None),
            "state": ("batch", None, None, None)}


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.dtype)
    plan = build_plan(cfg)
    keys = jax.random.split(key, len(plan) + 3)
    p: Params = {}
    if cfg.input_mode == "tokens":
        p["embed"] = (jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model))
                      * 0.02).astype(dt)
    blocks_p = []
    for bi, blk in enumerate(plan):
        slot_keys = jax.random.split(keys[1 + bi], blk.repeat * len(blk.sigs)
                                     ).reshape(blk.repeat, len(blk.sigs), 2)
        slots_p = []
        for si, sig in enumerate(blk.sigs):
            sp = jax.vmap(lambda k, s=sig: _init_sublayer(cfg, s, k))(slot_keys[:, si])
            slots_p.append(sp)
        blocks_p.append(slots_p)
    p["blocks"] = blocks_p
    p["final_norm"] = L.init_rms_norm(cfg.d_model, dt)[0]
    if not cfg.tie_embeddings:
        p["head"] = (jax.random.normal(keys[-1], (cfg.d_model, cfg.padded_vocab))
                     * 0.02).astype(dt)
    return p


def params_logical_axes(cfg: ModelConfig) -> Params:
    plan = build_plan(cfg)
    ax: Params = {}
    if cfg.input_mode == "tokens":
        ax["embed"] = ("vocab", "embed")
    ax["blocks"] = [
        [jax.tree.map(lambda t: ("layers",) + t, _sublayer_axes(cfg, sig),
                      is_leaf=_is_axes_leaf) for sig in blk.sigs]
        for blk in plan]
    ax["final_norm"] = ("embed",)
    if not cfg.tie_embeddings:
        ax["head"] = ("embed", "vocab")
    return ax


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x)


def forward(params: Params, cfg: ModelConfig, inputs: jax.Array,
            positions: Optional[jax.Array] = None) -> jax.Array:
    """inputs: int tokens [B,S] or embeddings [B,S,d]. Returns logits [B,S,V]."""
    plan = build_plan(cfg)
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = shard_as(x, "batch", "seq", "embed_act")

    for blk, slots in zip(plan, params["blocks"]):
        def body(carry, slot_params, blk=blk):
            for sig, sp in zip(blk.sigs, slot_params):
                carry = _apply_sublayer(cfg, sig, sp, carry, positions)
            return carry, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        if blk.repeat == 1:
            x, _ = body_fn(x, [jax.tree.map(lambda a: a[0], sp) for sp in slots])
        else:
            x, _ = lax.scan(body_fn, x, tuple(slots))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _lm_head(params, cfg, x)


def _lm_head(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Logits over the padded vocab; pad region masked to -1e30."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"])
    logits = shard_as(logits, "batch", "seq", "vocab")
    if cfg.padded_vocab != cfg.vocab_size:
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        logits = jnp.where(iota < cfg.vocab_size, logits, -1e30)
    return logits


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """Causal LM cross-entropy (mean over tokens) + small z-loss.

    Written vocab-shard-friendly: no take_along_axis gather over the (model-
    sharded) vocab dim — the gold logit comes from a masked reduction, so
    GSPMD keeps logits sharded and only psums [B,S] stats.
    """
    logits = forward(params, cfg, batch["inputs"], batch.get("positions"))
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    # stable logsumexp over the (possibly sharded) vocab axis
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1)
    ce = jnp.mean(logz - gold)
    zloss = 1e-4 * jnp.mean(jnp.square(logz))
    return ce + zloss


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dt = jnp.dtype(cfg.dtype)
    plan = build_plan(cfg)
    caches = []
    for blk in plan:
        slots_c = []
        for sig in blk.sigs:
            c = _init_sublayer_cache(cfg, sig, batch, max_len, dt)
            c = jax.tree.map(
                lambda a: jnp.zeros((blk.repeat,) + a.shape, a.dtype), c)
            slots_c.append(c)
        caches.append(slots_c)
    return {"blocks": caches, "pos": jnp.zeros((), jnp.int32)}


def cache_logical_axes(cfg: ModelConfig) -> Params:
    plan = build_plan(cfg)
    axes = [[jax.tree.map(lambda t: ("layers",) + t, _sublayer_cache_axes(cfg, sig),
                          is_leaf=_is_axes_leaf) for sig in blk.sigs]
            for blk in plan]
    return {"blocks": axes, "pos": ()}


def decode_step(params: Params, cache: Params, cfg: ModelConfig,
                inputs: jax.Array) -> Tuple[jax.Array, Params]:
    """One synchronized decode step. inputs: [B,1] tokens or [B,1,d] embeds."""
    plan = build_plan(cfg)
    pos = cache["pos"]
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], inputs, axis=0)
    else:
        x = inputs.astype(jnp.dtype(cfg.dtype))
    x = shard_as(x, "batch", None, "embed_act")

    new_blocks = []
    for blk, slots, cslots in zip(plan, params["blocks"], cache["blocks"]):
        def body(carry, xs, blk=blk):
            slot_params, slot_caches = xs
            new_caches = []
            for sig, sp, sc in zip(blk.sigs, slot_params, slot_caches):
                carry, nc = _decode_sublayer(cfg, sig, sp, sc, carry, pos)
                new_caches.append(nc)
            return carry, new_caches

        if blk.repeat == 1:
            sp0 = [jax.tree.map(lambda a: a[0], sp) for sp in slots]
            sc0 = [jax.tree.map(lambda a: a[0], sc) for sc in cslots]
            x, ncs = body(x, (sp0, sc0))
            ncs = [jax.tree.map(lambda a: a[None], nc) for nc in ncs]
        else:
            x, ncs = lax.scan(body, x, (tuple(slots), tuple(cslots)))
        new_blocks.append(list(ncs))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_head(params, cfg, x)
    return logits, {"blocks": new_blocks, "pos": pos + 1}
