"""Mamba2 (SSD — state-space duality) block, chunked scan + O(1) decode.

Implements the SSD algorithm of arXiv:2405.21060: within a chunk of length Q
the recurrence is evaluated as a (masked, decay-weighted) attention-like
quadratic form; across chunks a linear recurrence carries the [H, P, N]
state. Chunks are processed with `lax.scan` so live memory is O(B·H·Q²)
regardless of sequence length, and the decode path is the exact single-step
recurrence (O(1) state — this is why mamba2/jamba run the 500k-context
decode cell).

Single group (ngroups=1): B/C projections are shared across heads.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import _init, rms_norm

Params = Dict[str, Any]


def init_ssm(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    d = cfg.d_model
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    convw = cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    # Fully shard-aligned projections (mathematically identical to the fused
    # in_proj): z / x / BC / dt each get their own matrix so no sharded slice
    # boundary ever crosses a shard — the fused baseline paid 40+ GB/device
    # of collective-permute resharding per step for exactly this
    # (EXPERIMENTS.md §Perf mamba iteration 1). The depthwise conv splits
    # the same way (per-channel, so splitting is exact).
    p: Params = {
        "z_proj": _init(ks[0], (d, di), d, dt),
        "x_proj": _init(ks[3], (d, di), d, dt),
        "bc_proj": _init(ks[1], (d, 2 * n), d, dt),
        "dt_proj": _init(ks[2], (d, h), d, dt),
        "conv_wx": _init(ks[0], (convw, di), convw, dt),
        "conv_bx": jnp.zeros((di,), dt),
        "conv_wbc": _init(ks[1], (convw, 2 * n), convw, dt),
        "conv_bbc": jnp.zeros((2 * n,), dt),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.zeros((di,), dt),
        "out_proj": _init(ks[2], (di, d), di, dt),
    }
    ax: Params = {
        "z_proj": ("embed_fsdp", "ssm_inner"),
        "x_proj": ("embed_fsdp", "ssm_inner"),
        "bc_proj": ("embed_fsdp", None),
        "dt_proj": ("embed_fsdp", None),
        "conv_wx": ("conv", "ssm_inner"),
        "conv_bx": ("ssm_inner",),
        "conv_wbc": ("conv", None),
        "conv_bbc": (None,),
        "a_log": (None,),
        "d_skip": (None,),
        "dt_bias": (None,),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed_fsdp"),
    }
    return p, ax


def _project(p: Params, x: jax.Array):
    z = jnp.einsum("bsd,de->bse", x, p["z_proj"])
    xc = jnp.einsum("bsd,de->bse", x, p["x_proj"])
    bc = jnp.einsum("bsd,de->bse", x, p["bc_proj"])
    dt = jnp.einsum("bsd,de->bse", x, p["dt_proj"])
    return z, xc, bc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with kernel [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + pad[:, i:i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def ssm_fwd(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training / prefill path. x: [B, S, d] with S % ssm_chunk == 0."""
    bsz, s, _ = x.shape
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    nc = s // q

    z, xc, bc, dt_raw = _project(p, x)
    xc = _causal_conv(xc, p["conv_wx"], p["conv_bx"])
    bc = _causal_conv(bc, p["conv_wbc"], p["conv_bbc"])
    # bf16 operands for the quadratic forms (2x HBM traffic saved; the decay
    # math — dt, cumsums, state carry — stays fp32 for stability):
    xs = xc.reshape(bsz, s, h, hp)
    bmat = bc[..., :n]
    cmat = bc[..., n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])      # [B,S,H]
    a = -jnp.exp(p["a_log"])                                             # [H]

    # chunk
    xs_c = xs.reshape(bsz, nc, q, h, hp)
    b_c = bmat.reshape(bsz, nc, q, n)
    c_c = cmat.reshape(bsz, nc, q, n)
    dt_c = dt.reshape(bsz, nc, q, h)
    da_c = dt_c * a                                                      # [B,nc,Q,H]
    cs = jnp.cumsum(da_c, axis=2)                                        # inclusive

    def chunk_step(state, inp):
        xs_i, b_i, c_i, dt_i, cs_i = inp                                 # [B,Q,...]
        # intra-chunk (masked quadratic form); decay in f32, dots accumulate
        # in f32 from bf16 operands
        li = jnp.exp(cs_i[:, :, None, :] - cs_i[:, None, :, :])          # [B,Q,Q,H]
        tri = jnp.tril(jnp.ones((q, q), bool))
        li = jnp.where(tri[None, :, :, None], li, 0.0)
        scores = jnp.einsum("bin,bjn->bij", c_i, b_i,
                            preferred_element_type=jnp.float32)          # [B,Q,Q]
        wmat = scores[..., None] * li * dt_i[:, None, :, :]              # [B,Q,Q,H]
        intra = jnp.einsum("bijh,bjhp->bihp", wmat.astype(xs_i.dtype), xs_i,
                           preferred_element_type=jnp.float32)
        # inter-chunk (carry-in state read at every position)
        inter = jnp.einsum("bin,bhpn,bih->bihp", c_i.astype(jnp.float32),
                           state, jnp.exp(cs_i))
        y_i = intra + inter
        # update carried state
        decay_out = jnp.exp(cs_i[:, -1:, :] - cs_i)                      # [B,Q,H]
        s_chunk = jnp.einsum("bjn,bjh,bjhp->bhpn", b_i.astype(jnp.float32),
                             decay_out * dt_i, xs_i.astype(jnp.float32))
        state = state * jnp.exp(cs_i[:, -1, :])[..., None, None] + s_chunk
        return state, y_i

    state0 = jnp.zeros((bsz, h, hp, n), jnp.float32)
    xs_t = jnp.moveaxis(xs_c, 1, 0)
    _, ys = lax.scan(chunk_step, state0,
                     (xs_t, jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0),
                      jnp.moveaxis(dt_c, 1, 0), jnp.moveaxis(cs, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, hp)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"])


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    di, n, h, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cache = {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * n), dtype),
        "state": jnp.zeros((batch, h, hp, n), jnp.float32),
    }
    axes = {
        "conv_x": ("batch", None, "ssm_inner"),
        "conv_bc": ("batch", None, None),
        "state": ("batch", None, None, None),
    }
    return cache, axes


def ssm_decode(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig,
               pos: jax.Array) -> Tuple[jax.Array, Params]:
    """Single-token recurrence. x: [B, 1, d]."""
    del pos  # SSM state is position-free
    bsz = x.shape[0]
    di, n, h, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xc_raw, bc_raw, dt_raw = _project(p, x)
    win_x = jnp.concatenate([cache["conv_x"], xc_raw], axis=1)           # [B,K,di]
    win_bc = jnp.concatenate([cache["conv_bc"], bc_raw], axis=1)         # [B,K,2n]
    xc = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_x, p["conv_wx"]) + p["conv_bx"])
    bcv = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_bc, p["conv_wbc"]) + p["conv_bbc"])
    xs = xc.reshape(bsz, h, hp).astype(jnp.float32)
    bmat = bcv[:, :n].astype(jnp.float32)
    cmat = bcv[:, n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)                                                  # [B,H]
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", bmat, dt, xs)
    y = jnp.einsum("bn,bhpn->bhp", cmat, state) + p["d_skip"][None, :, None] * xs
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_cache = {"conv_x": win_x[:, 1:, :], "conv_bc": win_bc[:, 1:, :],
                 "state": state}
    return out, new_cache
