"""Transformer building blocks: norms, RoPE/M-RoPE, attention (GQA/MQA,
qk-norm, sliding-window, MLA with absorbed decode), gated MLP, and MoE with
sort-based capacity dispatch.

Everything is functional: ``init_*`` returns ``(params, axes)`` where ``axes``
mirrors the params tree with logical-axis tuples consumed by
``repro.distributed.sharding``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard_as
from .config import ModelConfig

Params = Dict[str, Any]
NEG_INF = -1e30


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale_dim, dtype):
    return (jax.random.normal(key, shape) / math.sqrt(scale_dim)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype) -> Tuple[jax.Array, Tuple]:
    return jnp.zeros((d,), dtype), ("embed",)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions: [...] int -> cos/sin [..., head_dim//2] (float32)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                  sections: Tuple[int, ...]):
    """M-RoPE (qwen2-vl): positions [..., 3] (t,h,w); per-section frequencies.

    Text-only stub feeds identical t=h=w positions, which reduces to 1D RoPE
    (the qwen2-vl property).
    """
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # section s of the half-dim uses positions[..., s]
    sec_id = jnp.zeros((half,), jnp.int32)
    off = 0
    for i, s in enumerate(sections):
        sec_id = sec_id.at[off:off + s].set(i)
        off += s
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1)
    ang = pos * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, ..., D]; cos/sin: [B, S, D/2] — rotate-half convention."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    while cos.ndim < x.ndim:
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def positions_cos_sin(cfg: ModelConfig, positions: jax.Array, head_dim: int):
    if cfg.rope_kind == "mrope":
        if positions.ndim == 2:  # text stub: same position per section
            positions = jnp.broadcast_to(positions[..., None], positions.shape + (3,))
        return mrope_cos_sin(positions, head_dim, cfg.rope_theta, cfg.mrope_sections)
    return rope_cos_sin(positions, head_dim, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA / MHA) with chunked-flash prefill & cached decode
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {
        "wq": _init(ks[0], (d, h, hd), d, dt),
        "wk": _init(ks[1], (d, kv, hd), d, dt),
        "wv": _init(ks[2], (d, kv, hd), d, dt),
        "wo": _init(ks[3], (h, hd, d), h * hd, dt),
    }
    ax: Params = {
        "wq": ("embed_fsdp", "heads", "head_dim_tp"),
        "wk": ("embed_fsdp", "kv_heads", "head_dim_tp"),
        "wv": ("embed_fsdp", "kv_heads", "head_dim_tp"),
        "wo": ("heads", "head_dim_tp", "embed_fsdp"),
    }
    if cfg.qk_norm:
        p["q_norm"], ax["q_norm"] = jnp.zeros((hd,), dt), ("head_dim",)
        p["k_norm"], ax["k_norm"] = jnp.zeros((hd,), dt), ("head_dim",)
    return p, ax


def _qkv(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
    k = jnp.einsum("bsd,dkq->bskq", x, p["wk"])
    v = jnp.einsum("bsd,dkq->bskq", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = positions_cos_sin(cfg, positions, cfg.head_dim)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             window: int = 0, chunk_q: int = 2048,
                             chunk_kv: int = 2048) -> jax.Array:
    """Flash-style two-level scan: O(chunk_q·chunk_kv) live scores.

    q: [B,S,H,D], k/v: [B,S,K,D] (K | H). Causal; optional sliding window.
    """
    b, s, h, d = q.shape
    dv = v.shape[-1]
    kheads = k.shape[2]
    g = h // kheads
    scale = 1.0 / math.sqrt(d)
    chunk_q = min(chunk_q, s)
    chunk_kv = min(chunk_kv, s)
    nq, nkv = s // chunk_q, s // chunk_kv
    qg = q.reshape(b, s, kheads, g, d)

    def q_block(qi):
        q_blk = lax.dynamic_slice_in_dim(qg, qi * chunk_q, chunk_q, axis=1)
        q_pos = qi * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = lax.dynamic_slice_in_dim(k, kj * chunk_kv, chunk_kv, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, kj * chunk_kv, chunk_kv, axis=1)
            kv_pos = kj * chunk_kv + jnp.arange(chunk_kv)
            s_blk = jnp.einsum("bqkgd,bckd->bkgqc", q_blk, k_blk).astype(jnp.float32) * scale
            mask = kv_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s_blk = jnp.where(mask[None, None, None], s_blk, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            p_blk = jnp.exp(s_blk - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p_blk, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p_blk, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kheads, g, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kheads, g, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, kheads, g, chunk_q, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out  # [b, kheads, g, chunk_q, d]

    # Recompute score blocks in the backward pass instead of stacking them
    # as scan residuals: a [B,H,chunk_q,chunk_kv] f32 probability block per
    # kv-step dominates HBM traffic otherwise (flash-attention semantics;
    # see EXPERIMENTS.md §Perf deepseek iteration 3).
    q_block = jax.checkpoint(q_block)

    if nq == 1:
        out = q_block(0)
        out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, dv)
        return out.astype(q.dtype)

    _, outs = lax.scan(lambda c, qi: (c, q_block(qi)), 0, jnp.arange(nq))
    # outs: [nq, b, kheads, g, chunk_q, dv] -> [b, s, h, dv]
    out = jnp.moveaxis(outs, 0, 3)                # b,kheads,g,nq,chunk_q,dv
    out = out.reshape(b, kheads, g, s, dv)
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, dv)
    return out.astype(q.dtype)


def attention_fwd(p: Params, x: jax.Array, cfg: ModelConfig,
                  positions: jax.Array, *, chunk_q: int = 2048,
                  chunk_kv: int = 2048) -> jax.Array:
    """Training / prefill self-attention."""
    q, k, v = _qkv(p, x, cfg, positions)
    k = shard_as(k, "batch", "seq", "kv_heads", None)
    v = shard_as(v, "batch", "seq", "kv_heads", None)
    out = chunked_causal_attention(q, k, v, window=cfg.sliding_window,
                                   chunk_q=chunk_q, chunk_kv=chunk_kv)
    return jnp.einsum("bshq,hqd->bsd", out, p["wo"])


def init_attention_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """KV cache for one layer. Sliding-window archs keep a ring buffer."""
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    shape = (batch, length, kv, hd)
    axes = ("batch", "decode_cache_seq", "kv_heads", None)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}, \
           {"k": axes, "v": axes}


def attention_decode(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig,
                     pos: jax.Array) -> Tuple[jax.Array, Params]:
    """One-token decode step. x: [B, 1, d]; pos: scalar int32 (synchronized
    batch decode). Ring-buffered when sliding_window is set."""
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q, k, v = _qkv(p, x, cfg, positions)
    length = cache["k"].shape[1]
    slot = pos % length if cfg.sliding_window else pos
    ck = lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)

    kheads = cfg.num_kv_heads
    g = cfg.num_heads // kheads
    qg = q.reshape(b, 1, kheads, g, cfg.head_dim)
    s_all = jnp.einsum("bqkgd,bckd->bkgqc", qg, ck).astype(jnp.float32)
    s_all *= 1.0 / math.sqrt(cfg.head_dim)
    idx = jnp.arange(length)
    if cfg.sliding_window:
        age = (slot - idx) % length
        mask = age <= jnp.minimum(pos, length - 1)
    else:
        mask = idx <= pos
    s_all = jnp.where(mask[None, None, None, None, :], s_all, NEG_INF)
    w = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", w, cv.astype(jnp.float32))
    out = out.reshape(b, 1, cfg.num_heads, cfg.head_dim).astype(x.dtype)
    y = jnp.einsum("bshq,hqd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): low-rank q/kv compression, absorbed decode
# ---------------------------------------------------------------------------

def init_mla(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    d, h = cfg.d_model, cfg.num_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    dt = _dtype(cfg)
    ks = jax.random.split(key, 8)
    p = {
        "wdq": _init(ks[0], (d, qr), d, dt),
        "q_norm": jnp.zeros((qr,), dt),
        "wuq": _init(ks[1], (qr, h, nd + rd), qr, dt),
        "wdkv": _init(ks[2], (d, kr + rd), d, dt),
        "kv_norm": jnp.zeros((kr,), dt),
        "wuk": _init(ks[3], (kr, h, nd), kr, dt),
        "wuv": _init(ks[4], (kr, h, vd), kr, dt),
        "wo": _init(ks[5], (h, vd, d), h * vd, dt),
    }
    ax = {
        "wdq": ("embed_fsdp", "q_lora"),
        "q_norm": ("q_lora",),
        "wuq": ("q_lora", "heads", None),
        "wdkv": ("embed_fsdp", "kv_lora"),
        "kv_norm": ("kv_lora",),
        "wuk": ("kv_lora", "heads", None),
        "wuv": ("kv_lora", "heads", None),
        "wo": ("heads", None, "embed_fsdp"),
    }
    return p, ax


def _mla_qkv_compressed(p: Params, x: jax.Array, cfg: ModelConfig,
                        positions: jax.Array):
    """Returns (q_nope, q_rope, c_kv, k_rope)."""
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    kr = cfg.kv_lora_rank
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhq->bshq", cq, p["wuq"])
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    dkv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    c_kv = rms_norm(dkv[..., :kr], p["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., kr:]
    cos, sin = positions_cos_sin(cfg, positions, rd)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
            *, chunk_q: int = 2048, chunk_kv: int = 2048) -> jax.Array:
    """Training / prefill: expand k/v per head and run chunked attention."""
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_compressed(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, p["wuk"])
    v = jnp.einsum("bsr,rhv->bshv", c_kv, p["wuv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (cfg.qk_rope_head_dim,))],
        axis=-1)
    out = chunked_causal_attention(q, k, v, window=0, chunk_q=chunk_q, chunk_kv=chunk_kv)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"])


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    kr, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    return ({"ckv": jnp.zeros((batch, max_len, kr), dtype),
             "krope": jnp.zeros((batch, max_len, rd), dtype)},
            {"ckv": ("batch", "decode_cache_seq", None),
             "krope": ("batch", "decode_cache_seq", None)})


def mla_decode(p: Params, x: jax.Array, cache: Params, cfg: ModelConfig,
               pos: jax.Array) -> Tuple[jax.Array, Params]:
    """Absorbed-matrix MLA decode: attention runs entirely in the compressed
    kv space — W_uk is folded into the query, W_uv into the output."""
    b = x.shape[0]
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    q_nope, q_rope, c_kv, k_rope = _mla_qkv_compressed(p, x, cfg, positions)
    ckv = lax.dynamic_update_slice_in_dim(cache["ckv"], c_kv, pos, axis=1)
    krp = lax.dynamic_update_slice_in_dim(cache["krope"], k_rope, pos, axis=1)
    # absorb: q' = q_nope @ W_uk -> [b,1,h,kr]
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, p["wuk"])
    s_c = jnp.einsum("bshr,bcr->bhsc", q_abs, ckv)
    s_r = jnp.einsum("bshr,bcr->bhsc", q_rope, krp)
    scale = 1.0 / math.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    scores = (s_c + s_r).astype(jnp.float32) * scale
    mask = jnp.arange(ckv.shape[1]) <= pos
    scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhsc,bcr->bshr", w, ckv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bshr,rhv->bshv", ctx, p["wuv"])
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, {"ckv": ckv, "krope": krp}


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Tuple[Params, Params]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    p = {"wi_gate": _init(ks[0], (d, ff), d, dt),
         "wi_up": _init(ks[1], (d, ff), d, dt),
         "wo": _init(ks[2], (ff, d), ff, dt)}
    ax = {"wi_gate": ("embed_fsdp", "ff"), "wi_up": ("embed_fsdp", "ff"),
          "wo": ("ff", "embed_fsdp")}
    return p, ax


def _act(cfg: ModelConfig):
    return jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu


def mlp_fwd(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = _act(cfg)(jnp.einsum("bsd,df->bsf", x, p["wi_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["wi_up"])
    h = shard_as(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# MoE: top-k routing, sort-based capacity dispatch, optional shared experts
# ---------------------------------------------------------------------------

def init_moe(cfg: ModelConfig, key) -> Tuple[Params, Params]:
    d, e, ff = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": _init(ks[0], (d, e), d, jnp.float32),
        "wi_gate": _init(ks[1], (e, d, ff), d, dt),
        "wi_up": _init(ks[2], (e, d, ff), d, dt),
        "wo": _init(ks[3], (e, ff, d), ff, dt),
    }
    ax: Params = {
        "router": ("embed", None),
        "wi_gate": ("expert", "embed_fsdp", "ff"),
        "wi_up": ("expert", "embed_fsdp", "ff"),
        "wo": ("expert", "ff", "embed_fsdp"),
    }
    if cfg.moe_shared_experts:
        sp, sax = init_mlp(cfg, ks[4], d_ff=cfg.moe_d_ff * cfg.moe_shared_experts)
        p["shared"], ax["shared"] = sp, sax
    return p, ax


def moe_fwd(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.moe_num_experts, cfg.moe_top_k
    cap = int(math.ceil(t * k / e * cfg.capacity_factor))
    cap = max(cap, 1)

    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, k)                      # [t, k]
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # sort-based rank-within-expert (dropless up to capacity)
    e_flat = idx.reshape(t * k)
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = jnp.take(e_flat, order)
    ones = jnp.ones_like(e_sorted, jnp.int32)
    counts = jax.ops.segment_sum(ones, e_sorted, num_segments=e)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - jnp.take(starts, e_sorted)
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)

    keep = rank < cap
    dest = jnp.where(keep, e_flat * cap + rank, e * cap)   # drop slot at the end
    tok_of = jnp.arange(t * k, dtype=jnp.int32) // k

    buf = jnp.zeros((e * cap + 1, d), xt.dtype)
    buf = buf.at[dest].add(jnp.take(xt, tok_of, axis=0))
    buf = buf[:-1].reshape(e, cap, d)
    buf = shard_as(buf, "expert", "moe_capacity", None)

    h = _act(cfg)(jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_buf = shard_as(out_buf, "expert", "moe_capacity", None)

    flat_out = jnp.concatenate(
        [out_buf.reshape(e * cap, d), jnp.zeros((1, d), out_buf.dtype)], axis=0)
    y_assign = jnp.take(flat_out, dest, axis=0)            # [t*k, d]
    y = jnp.sum(y_assign.reshape(t, k, d)
                * gates.astype(y_assign.dtype)[..., None], axis=1)
    if cfg.moe_shared_experts:
        y = y + mlp_fwd(p["shared"], x, cfg).reshape(t, d)
    return y.reshape(b, s, d)


def moe_aux_loss(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (fraction × probability)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = lax.top_k(probs, cfg.moe_top_k)
    e = cfg.moe_num_experts
    frac = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1))
    imp = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac * imp)
