"""Accuracy telemetry: sketch fill ratios and live error-interval gauges.

ProbGraph's value proposition is a speed/accuracy *tradeoff*, but until now
the accuracy side was only ever evaluated inside tests. These helpers record
it at runtime into a :class:`~repro.obs.metrics.MetricsRegistry`:

- :func:`fill_ratio` — how saturated a sketch is (Bloom bit density, or the
  fraction of occupied MinHash/KMV slots). A Bloom filter past ~0.5 fill is
  the leading indicator of estimate inflation.
- :func:`record_pair_error` — per-answered-query error-interval estimates
  from ``core.bounds`` (RMSE for Bloom AND-cardinality, the
  Chernoff-style multiplicative scale for MinHash-family), as gauges next
  to the serving counters.
- :func:`record_maintenance` — the ``ErrorBudgetPolicy`` dirty-row /
  rebuild counters from ``SketchMaintainer.stats()``, so accuracy
  degradation under streaming deletions is observable, not test-asserted.

Everything here is cheap host-side numpy on values the caller already has;
nothing touches the device.
"""
from __future__ import annotations

import numpy as np

from .metrics import REGISTRY, MetricsRegistry


def fill_ratio(sketch) -> float:
    """Mean occupancy of a ``SketchSet`` in [0, 1].

    Bloom (``bf``): mean set-bit density over all rows. MinHash family
    (``kh``/``1h``): fraction of slots holding a real vertex id (< n).
    KMV: fraction of slots below the pad sentinel.
    """
    data = np.asarray(sketch.data)
    if sketch.kind == "bf":
        # uint32 words -> mean bit density
        bits = np.unpackbits(data.view(np.uint8), axis=-1)
        return float(bits.mean())
    if sketch.kind in ("kh", "1h"):
        return float((data < sketch.n).mean())
    if sketch.kind == "kmv":
        from repro.core.sketches import KMV_PAD
        return float((data < KMV_PAD).mean())
    return 0.0


def record_fill(sketch, registry: MetricsRegistry = REGISTRY) -> float:
    """Record :func:`fill_ratio` as ``sketch_fill_ratio{kind=...}``."""
    ratio = fill_ratio(sketch)
    registry.gauge("sketch_fill_ratio", kind=sketch.kind).set(ratio)
    return ratio


def record_pair_error(sketch, cards, du, dv,
                      registry: MetricsRegistry = REGISTRY) -> dict:
    """Record live error-interval estimates for a batch of pair answers.

    ``cards`` are the estimated intersection cardinalities just served;
    ``du``/``dv`` the endpoint degrees. Emits, labelled by sketch kind:

    - ``accuracy_err_rmse`` — mean absolute error estimate (Bloom: Thm IV.2
      RMSE at the answered cardinality; MinHash family: epsilon·min-degree
      from the multiplicative concentration bound).
    - ``accuracy_err_rel`` — the same normalized by ``max(card, 1)``.

    Returns the recorded ``{"rmse", "rel"}`` dict (handy for tests).
    """
    from repro.core import bounds

    cards = np.asarray(cards, dtype=np.float64)
    du = np.asarray(du, dtype=np.float64)
    dv = np.asarray(dv, dtype=np.float64)
    if cards.size == 0:
        return {"rmse": 0.0, "rel": 0.0}
    if sketch.kind == "bf":
        err = bounds.bf_and_rmse(cards, sketch.total_bits, sketch.num_hashes)
        err = np.asarray(err, dtype=np.float64)
    else:
        eps = bounds.minhash_error_scale(np.minimum(du, dv),
                                         max(int(sketch.k), 1))
        err = np.asarray(eps, dtype=np.float64) * np.minimum(du, dv)
    rmse = float(np.mean(err))
    rel = float(np.mean(err / np.maximum(cards, 1.0)))
    registry.gauge("accuracy_err_rmse", kind=sketch.kind).set(rmse)
    registry.gauge("accuracy_err_rel", kind=sketch.kind).set(rel)
    return {"rmse": rmse, "rel": rel}


def record_maintenance(stats: dict,
                       registry: MetricsRegistry = REGISTRY) -> None:
    """Mirror ``SketchMaintainer.stats()`` into the registry.

    Emits ``sketch_rows_dirty`` / ``sketch_stale_total`` gauges and keeps
    ``sketch_rows_rebuilt`` / ``sketch_rows_incremental`` /
    ``sketch_deltas_applied`` counters in sync (set, not inc — the
    maintainer's plain-int counters stay the source of truth so
    checkpoint restore keeps working).
    """
    kind = str(stats.get("kind", "?"))
    registry.gauge("sketch_rows_dirty", kind=kind).set(
        float(stats.get("rows_dirty", 0)))
    registry.gauge("sketch_stale_total", kind=kind).set(
        float(stats.get("stale_total", 0.0)))
    for field in ("rows_rebuilt", "rows_incremental", "deltas_applied"):
        registry.counter(f"sketch_{field}", kind=kind).set(
            int(stats.get(field, 0)))


__all__ = ["fill_ratio", "record_fill", "record_maintenance",
           "record_pair_error"]
