"""Low-overhead structured span tracer with Chrome-trace/Perfetto export.

One process-wide :class:`Tracer` records named spans into a thread-safe
bounded ring buffer. Spans are context managers (``with span("apply_delta",
vertices=...)``) or decorators (:func:`traced`) and nest through a
thread-local stack, so the export reconstructs the call tree without any
global locking on the hot path.

Attribution under JAX's async dispatch: a span can *fence* a device value
(``sp.fence(out)``), and span exit then calls ``jax.block_until_ready`` on
it **before** reading the clock — device work is charged to the span that
launched it instead of leaking into whichever span happens to synchronize
next. Fencing only happens while tracing is enabled; the disabled path is a
single flag check returning a shared no-op span, so instrumented code keeps
async dispatch and pays no measurable cost (the smoke-bench overhead gate
holds the line).

Export is the Chrome trace-event JSON format (``ph: "X"`` complete events,
microsecond timestamps), which loads directly in Perfetto / chrome://tracing;
``aggregate()`` gives per-span-name count/total wall time for benchmark
breakdowns.
"""
from __future__ import annotations

import collections
import functools
import json
import os
import threading
import time
from typing import Dict, List, Optional


class _NullSpan:
    """The shared no-op span returned while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        """No-op attribute update (tracing disabled)."""
        return self

    def fence(self, value):
        """Pass the value through without blocking (tracing disabled)."""
        return value


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records name/attrs/parent and times its ``with`` body."""

    __slots__ = ("_tracer", "name", "attrs", "_fenced", "_t0", "_parent",
                 "_depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._fenced = None

    def set(self, **attrs):
        """Attach/overwrite span attributes from inside the body."""
        self.attrs.update(attrs)
        return self

    def fence(self, value):
        """Register a device value to ``block_until_ready`` at span exit, so
        its device work is attributed to this span; returns the value."""
        self._fenced = value
        return value

    def __enter__(self):
        stack = self._tracer._stack()
        self._parent = stack[-1].name if stack else None
        self._depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._fenced is not None:
            try:
                import jax
                jax.block_until_ready(self._fenced)
            except Exception:  # noqa: BLE001 - tracers/aborted buffers
                pass
            self._fenced = None
        t1 = time.perf_counter()
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._record(self.name, self._t0, t1, self._parent,
                             self._depth, self.attrs,
                             error=exc_type is not None)
        return False


class Tracer:
    """Thread-safe bounded ring buffer of completed spans.

    Most callers use the module-level singleton through :func:`span` /
    :func:`enable` / :func:`export`; independent tracers exist mainly for
    tests.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self.enabled = False
        self._events: "collections.deque[dict]" = collections.deque(
            maxlen=self.capacity)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._origin = time.perf_counter()
        self.recorded = 0          # total spans ever recorded (ring may drop)

    # -- hot path -----------------------------------------------------------

    def span(self, name: str, **attrs):
        """Open a span context manager (no-op singleton while disabled)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, name: str, t0: float, t1: float,
                parent: Optional[str], depth: int, attrs: dict,
                error: bool = False) -> None:
        event = {
            "name": name,
            "ts": (t0 - self._origin) * 1e6,      # µs since tracer origin
            "dur": (t1 - t0) * 1e6,
            "tid": threading.get_ident(),
            "parent": parent,
            "depth": depth,
            "args": attrs,
        }
        if error:
            event["error"] = True
        with self._lock:
            self._events.append(event)
            self.recorded += 1

    # -- control ------------------------------------------------------------

    def enable(self, capacity: Optional[int] = None) -> None:
        """Turn tracing on (optionally resizing the ring buffer)."""
        if capacity is not None and int(capacity) != self.capacity:
            self.capacity = int(capacity)
            with self._lock:
                self._events = collections.deque(self._events,
                                                 maxlen=self.capacity)
        self.enabled = True

    def disable(self) -> None:
        """Turn tracing off (recorded spans are kept until ``clear``)."""
        self.enabled = False

    def clear(self) -> None:
        """Drop every recorded span and reset the time origin."""
        with self._lock:
            self._events.clear()
            self.recorded = 0
            self._origin = time.perf_counter()

    # -- reads --------------------------------------------------------------

    def events(self) -> List[dict]:
        """A snapshot list of the recorded span events (oldest first)."""
        with self._lock:
            return list(self._events)

    def aggregate(self) -> Dict[str, dict]:
        """Per-span-name ``{"count", "total_s", "mean_s"}`` breakdown."""
        out: Dict[str, dict] = {}
        for ev in self.events():
            agg = out.setdefault(ev["name"], {"count": 0, "total_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += ev["dur"] * 1e-6
        for agg in out.values():
            agg["mean_s"] = agg["total_s"] / agg["count"]
        return out

    def export(self, path: Optional[str] = None) -> dict:
        """Chrome trace-event JSON (loads in Perfetto); optionally written
        to ``path``.

        Every span becomes one complete event (``ph: "X"``) with
        microsecond ``ts``/``dur``; span attributes plus the recorded
        parent/depth land under ``args`` so tools (and tests) can rebuild
        the span tree without timestamp containment heuristics.
        """
        pid = os.getpid()
        tids: Dict[int, int] = {}
        trace_events = []
        for ev in self.events():
            tid = tids.setdefault(ev["tid"], len(tids))
            args = dict(ev["args"])
            args["parent"] = ev["parent"]
            args["depth"] = ev["depth"]
            trace_events.append({
                "name": ev["name"], "cat": "repro", "ph": "X",
                "ts": round(ev["ts"], 3), "dur": round(ev["dur"], 3),
                "pid": pid, "tid": tid, "args": args,
            })
        doc = {"traceEvents": trace_events, "displayTimeUnit": "ms",
               "otherData": {"recorded": self.recorded,
                             "capacity": self.capacity}}
        if path:
            with open(path, "w") as fh:
                json.dump(doc, fh)
        return doc


#: the process-wide tracer every instrumented seam records into
TRACER = Tracer()


def span(name: str, **attrs):
    """Open a span on the global tracer: ``with trace.span("x", k=v) as sp``.

    Returns a shared no-op object while tracing is disabled — safe (and
    near-free) to leave in hot paths. Keep attribute expressions cheap at
    call sites: they are evaluated even when disabled.
    """
    if not TRACER.enabled:
        return _NULL_SPAN
    return _Span(TRACER, name, attrs)


def traced(name: Optional[str] = None, **attrs):
    """Decorator form: ``@traced("engine.refresh")`` wraps calls in a span."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with span(label, **attrs):
                return fn(*args, **kwargs)
        return wrapper
    return deco


def enable(capacity: Optional[int] = None) -> None:
    """Enable the global tracer (see :meth:`Tracer.enable`)."""
    TRACER.enable(capacity)


def disable() -> None:
    """Disable the global tracer (recorded spans kept)."""
    TRACER.disable()


def enabled() -> bool:
    """Is the global tracer currently recording?"""
    return TRACER.enabled


def clear() -> None:
    """Drop the global tracer's recorded spans."""
    TRACER.clear()


def events() -> List[dict]:
    """Snapshot of the global tracer's span events."""
    return TRACER.events()


def aggregate() -> Dict[str, dict]:
    """Per-span-name breakdown of the global tracer's events."""
    return TRACER.aggregate()


def export(path: Optional[str] = None) -> dict:
    """Chrome-trace export of the global tracer (see :meth:`Tracer.export`)."""
    return TRACER.export(path)


__all__ = ["TRACER", "Tracer", "aggregate", "clear", "disable", "enable",
           "enabled", "events", "export", "span", "traced"]
