"""Unified observability layer: span tracing, metrics, accuracy telemetry.

- :mod:`repro.obs.trace` — structured span tracer with Chrome-trace/Perfetto
  export, instrumented through the engine/stream/serving hot seams.
- :mod:`repro.obs.metrics` — labelled counter/gauge/histogram registry; the
  ad-hoc stat dicts (``TrafficMeter``, ``server.stats()``) are views over it.
- :mod:`repro.obs.accuracy` — sketch fill-ratio and live error-bound gauges.

Import rule: ``obs`` depends only on numpy/stdlib (plus a lazy ``jax``
import for span fencing), so every other layer may import it freely without
cycles.
"""
from . import accuracy, metrics, trace
from .metrics import REGISTRY, MetricsRegistry
from .trace import span, traced

__all__ = ["REGISTRY", "MetricsRegistry", "accuracy", "metrics", "span",
           "trace", "traced"]
