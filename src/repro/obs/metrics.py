"""Labelled metrics registry: counters, gauges, and windowed histograms.

One :class:`MetricsRegistry` owns every metric; instruments are created (or
fetched) by ``registry.counter(name, **labels)`` and friends, keyed on
``(name, sorted-labels)`` so the same call site always returns the same
instrument. ``snapshot()`` flattens everything to one JSON-serializable dict
(``name{k=v,...}`` keys, Prometheus-style), which is what ``--metrics`` CLI
flags and the bench harness embed.

The pre-existing ad-hoc stat surfaces (``TrafficMeter.stats()``,
``BatchedQueryServer.stats()``) are now *views* over instruments in a
registry — same public dict shapes, bit-compatible values — so there is
exactly one place a number lives. Histograms keep a bounded deque window
and expose the raw values so those views can reproduce their original
``np.percentile`` math exactly.
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, Optional, Tuple

import numpy as np

LabelKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def _key(name: str, labels: dict) -> LabelKey:
    return (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))


def _flat_name(key: LabelKey) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic (but resettable) integer counter.

    Mutations hold a per-instrument lock: ``self._value += amount`` is a
    read-modify-write spanning several bytecodes, so unlocked concurrent
    increments lose updates (the background flush worker and the delta
    thread both hit serving counters).
    """

    # pgcheck PG001: the count moves only under the per-instrument lock;
    # reads are free (a torn read of an int is impossible in CPython, and
    # the `value` property is an intentionally unlocked snapshot)
    _GUARDED_BY = {"_value": "write:_lock"}

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, amount: int = 1) -> int:
        """Add ``amount`` (default 1); returns the new value."""
        with self._lock:
            self._value += amount
            return self._value

    def set(self, value: int) -> None:
        """Overwrite the count (checkpoint restore / view-backed attrs)."""
        with self._lock:
            self._value = int(value)

    @property
    def value(self) -> int:
        """Current count."""
        return self._value


class Gauge:
    """Last-write-wins scalar (``add`` is locked: it is a read-modify-write)."""

    _GUARDED_BY = {"_value": "write:_lock"}  # pgcheck PG001; see Counter

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> float:
        """Record the latest value; returns it."""
        with self._lock:
            self._value = value
        return value

    def add(self, amount: float) -> float:
        """Adjust the gauge by ``amount``; returns the new value."""
        with self._lock:
            self._value += amount
            return self._value

    @property
    def value(self) -> float:
        """Most recently recorded value."""
        return self._value


class Histogram:
    """Sliding-window histogram over the last ``window`` observations.

    Keeps raw values (bounded deque) rather than buckets so consumers can
    apply their own summary math — the serving-stats view recomputes
    ``mean``/``np.percentile`` from :meth:`values` and stays bit-compatible
    with the pre-registry implementation.
    """

    # pgcheck PG001: deque mutation and iteration must not race (appending
    # past maxlen while iterating raises); `count` reads are free snapshots
    _GUARDED_BY = {"_window": "_lock", "count": "write:_lock"}

    __slots__ = ("_window", "count", "_lock")

    def __init__(self, window: Optional[int] = 4096):
        self._window = collections.deque(maxlen=window)
        self.count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        """Record one observation (locked: ``count += 1`` is a
        read-modify-write, and deque mutation must not race readers)."""
        with self._lock:
            self._window.append(float(value))
            self.count += 1

    def values(self) -> np.ndarray:
        """The retained window as a float64 array (oldest first).

        Locked against :meth:`observe`: iterating a deque while another
        thread appends past ``maxlen`` raises ``RuntimeError``.
        """
        with self._lock:
            return np.asarray(self._window, dtype=np.float64)

    def summary(self) -> dict:
        """``{"count", "mean", "p50", "p95", "max"}`` over the window."""
        vals = self.values()
        if vals.size == 0:
            return {"count": self.count, "mean": 0.0, "p50": 0.0,
                    "p95": 0.0, "max": 0.0}
        return {
            "count": self.count,
            "mean": float(vals.mean()),
            "p50": float(np.percentile(vals, 50)),
            "p95": float(np.percentile(vals, 95)),
            "max": float(vals.max()),
        }


class MetricsRegistry:
    """Thread-safe, label-aware home for counters/gauges/histograms."""

    # pgcheck PG001: fetch-or-create and enumeration both hold the lock —
    # an unlocked fast path could observe a registration mid-flight
    _GUARDED_BY = {"_metrics": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[LabelKey, object] = {}

    def _get(self, name: str, labels: dict, factory):
        # fully locked — an unlocked fast path over the dict could observe
        # another thread's registration mid-flight; fetch-or-create is cheap
        # enough that call sites which care hold the instrument instead
        key = _key(name, labels)
        with self._lock:
            inst = self._metrics.get(key)
            if inst is None:
                inst = self._metrics[key] = factory()
        return inst

    def counter(self, name: str, **labels) -> Counter:
        """Fetch-or-create the counter for ``(name, labels)``."""
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        """Fetch-or-create the gauge for ``(name, labels)``."""
        return self._get(name, labels, Gauge)

    def histogram(self, name: str, window: Optional[int] = 4096,
                  **labels) -> Histogram:
        """Fetch-or-create the histogram for ``(name, labels)``.

        ``window`` only applies on first creation.
        """
        return self._get(name, labels, lambda: Histogram(window))

    def value(self, name: str, **labels):
        """Current value of a counter/gauge (None if never created)."""
        with self._lock:
            inst = self._metrics.get(_key(name, labels))
        return None if inst is None else inst.value

    def labelled(self, name: str) -> Dict[Tuple[Tuple[str, str], ...], object]:
        """Every instrument registered under ``name``, keyed by its sorted
        label tuple (``dict(key)`` recovers the label dict).

        The enumeration view the stat facades use to rebuild per-label
        dicts (e.g. served-by-kind) straight from the registry.
        """
        with self._lock:
            items = list(self._metrics.items())
        return {labels: inst for (n, labels), inst in items if n == name}

    def snapshot(self) -> dict:
        """Flatten every instrument to one ``{flat_name: number}`` dict.

        Histograms expand to ``_count``/``_mean``/``_p50``/``_p95``/``_max``
        suffixed entries. Keys are Prometheus-style ``name{k=v,...}``.
        """
        with self._lock:
            items = list(self._metrics.items())
        out: dict = {}
        for key, inst in sorted(items, key=lambda kv: _flat_name(kv[0])):
            flat = _flat_name(key)
            if isinstance(inst, Histogram):
                for suffix, val in inst.summary().items():
                    out[f"{flat}_{suffix}"] = val
            else:
                out[flat] = inst.value
        return out

    def reset(self) -> None:
        """Drop every instrument (tests / fresh bench suites)."""
        with self._lock:
            self._metrics.clear()


#: process-global registry — CLI ``--metrics`` and benches snapshot this
REGISTRY = MetricsRegistry()

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY"]
