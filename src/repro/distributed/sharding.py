"""Logical-axis sharding: rules mapping logical tensor axes -> mesh axes.

Params and activations are annotated with *logical* axis names ("embed",
"heads", "ff", "expert", "batch", "seq", ...). A rule-set maps those to
physical mesh axes ("pod", "data", "model"). This is the MaxText/Flax
partitioning pattern, kept dependency-free.

The active rule-set + mesh are installed via `use_rules(...)`; model code
calls `shard_as(x, "batch", "seq", "embed")` which becomes a
`with_sharding_constraint` when a mesh is active and a no-op otherwise
(single-device smoke tests).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

# Default production rule-set for a ("pod", "data", "model") or
# ("data", "model") mesh. "fsdp" is the param shard axis for ZeRO-3-style
# fully-sharded params (maps to "data").
BASE_RULES: Dict[str, Axis] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "decode_cache_seq": "model",
    "embed_act": None,
    # params
    "vocab": "model",
    "embed": None,
    "embed_fsdp": "data",          # FSDP shard dim for 2D+ params
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "head_dim_tp": "model",        # fallback TP when heads % model != 0
    "ff": "model",
    "expert": "model",
    "q_lora": None,
    "kv_lora": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,
    "moe_capacity": None,
    # graph mining (repro.engine): edge lists shard over every mesh axis —
    # fixed-size sketches make per-edge work uniform, so any split balances
    "edge": ("pod", "data", "model"),
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Axis] = dict(BASE_RULES)


_CTX = _Ctx()


def active_mesh() -> Optional[Mesh]:
    """The mesh installed by ``use_rules`` (None outside any context)."""
    return _CTX.mesh


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], overrides: Optional[Dict[str, Axis]] = None):
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    rules = dict(BASE_RULES)
    if overrides:
        rules.update(overrides)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def _filter_axes(mesh: Mesh, phys: Axis, dim_size: int, used: set) -> Axis:
    """Drop mesh axes that don't divide the dim or are already used."""
    if phys is None:
        return None
    axes = (phys,) if isinstance(phys, str) else tuple(phys)
    kept = []
    size = 1
    for a in axes:
        if a not in mesh.shape or a in used:
            continue
        asize = mesh.shape[a]
        if dim_size % (size * asize) != 0:
            continue
        kept.append(a)
        size *= asize
    if not kept:
        return None
    for a in kept:
        used.add(a)
    return tuple(kept) if len(kept) > 1 else kept[0]


def spec_for(logical_axes: Sequence[Optional[str]],
             dim_sizes: Optional[Sequence[int]] = None,
             mesh: Optional[Mesh] = None,
             rules: Optional[Dict[str, Axis]] = None) -> P:
    """Logical axes -> PartitionSpec under the active (or given) rules."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    used: set = set()
    parts = []
    for i, name in enumerate(logical_axes):
        phys = rules.get(name) if name else None
        if mesh is not None and phys is not None:
            size = dim_sizes[i] if dim_sizes is not None else None
            if size is not None:
                phys = _filter_axes(mesh, phys, size, used)
            else:
                axes = (phys,) if isinstance(phys, str) else tuple(phys)
                axes = tuple(a for a in axes if a in mesh.shape and a not in used)
                for a in axes:
                    used.add(a)
                phys = axes if len(axes) > 1 else (axes[0] if axes else None)
        parts.append(phys)
    return P(*parts)


def shard_as(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain activation sharding (no-op without an active mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(logical_axes, dim_sizes=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for_tree(axes_tree, shapes_tree, mesh: Mesh,
                      overrides: Optional[Dict[str, Axis]] = None):
    """NamedSharding pytree for a params tree given its logical-axes tree."""
    rules = dict(BASE_RULES)
    if overrides:
        rules.update(overrides)

    def one(axes, shape):
        spec = spec_for(axes, dim_sizes=shape.shape if hasattr(shape, "shape") else shape,
                        mesh=mesh, rules=rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))
