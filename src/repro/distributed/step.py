"""Train / decode step factories and sharding assembly.

The distribution strategy is GSPMD: one jit per step with explicit
`in_shardings`/`out_shardings` derived from logical-axis trees
(params_logical_axes / cache_logical_axes / optimizer.state_logical_axes),
plus `with_sharding_constraint` hints inside the model (shard_as).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (ModelConfig, ShapeConfig, init_params, loss_fn,
                          init_cache, decode_step, params_logical_axes,
                          cache_logical_axes)
from repro.optim import error_feedback_compress
from . import sharding as SH


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer, compress_grads: bool = False):
    """state = {"params", "opt", ["ef"]}; returns (state, metrics)."""

    def train_step(state, batch):
        lossval, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch))(state["params"])
        if compress_grads:
            grads, new_ef = error_feedback_compress(grads, state["ef"])
        new_params, new_opt = optimizer.update(grads, state["opt"], state["params"])
        new_state = {"params": new_params, "opt": new_opt}
        if compress_grads:
            new_state["ef"] = new_ef
        from repro.optim.adamw import global_norm
        metrics = {"loss": lossval, "grad_norm": global_norm(grads)}
        return new_state, metrics

    return train_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, cache, inputs):
        return decode_step(params, cache, cfg, inputs)
    return serve_step


def init_train_state(cfg: ModelConfig, optimizer, key,
                     compress_grads: bool = False) -> Dict[str, Any]:
    params = init_params(cfg, key)
    state = {"params": params, "opt": optimizer.init(params)}
    if compress_grads:
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _shard_tree(axes_tree, shapes_tree, mesh, overrides=None):
    return SH.sharding_for_tree(axes_tree, shapes_tree, mesh, overrides)


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def train_state_shapes(cfg: ModelConfig, optimizer, compress_grads=False):
    p = param_shapes(cfg)
    shapes = {"params": p, "opt": jax.eval_shape(optimizer.init, p)}
    if compress_grads:
        shapes["ef"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p)
    return shapes


def train_state_shardings(cfg: ModelConfig, optimizer, mesh: Mesh,
                          overrides=None, compress_grads=False):
    p_axes = params_logical_axes(cfg)
    p_shapes = param_shapes(cfg)
    shard = {"params": _shard_tree(p_axes, p_shapes, mesh, overrides)}
    opt_axes = optimizer.state_logical_axes(p_axes, p_shapes)
    opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
    shard["opt"] = _shard_tree(opt_axes, opt_shapes, mesh, overrides)
    if compress_grads:
        shard["ef"] = shard["params"]
    return shard


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    overrides=None):
    with SH.use_rules(mesh, overrides):
        tok_spec = SH.spec_for(("batch", "seq"),
                               (shape.global_batch, shape.seq_len), mesh)
        out = {"inputs": NamedSharding(mesh, tok_spec),
               "labels": NamedSharding(mesh, tok_spec)}
        if cfg.input_mode == "embeddings":
            emb_spec = SH.spec_for(("batch", "seq", "embed_act"),
                                   (shape.global_batch, shape.seq_len, cfg.d_model), mesh)
            out["inputs"] = NamedSharding(mesh, emb_spec)
        if cfg.rope_kind == "mrope":
            pos_spec = SH.spec_for(("batch", "seq", None),
                                   (shape.global_batch, shape.seq_len, 3), mesh)
            out["positions"] = NamedSharding(mesh, pos_spec)
    return out


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def cache_shardings(cfg: ModelConfig, batch: int, max_len: int, mesh: Mesh,
                    overrides=None):
    c_axes = cache_logical_axes(cfg)
    c_shapes = cache_shapes(cfg, batch, max_len)
    return _shard_tree(c_axes, c_shapes, mesh, overrides)


def logits_sharding(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                    overrides=None):
    with SH.use_rules(mesh, overrides):
        spec = SH.spec_for(("batch", None, "vocab"),
                           (batch, seq, cfg.padded_vocab), mesh)
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
