from . import fault, sharding

# NOTE: `step` imports repro.models (which imports distributed.sharding);
# import it explicitly as `repro.distributed.step` to avoid a cycle here.

__all__ = ["sharding", "fault"]
