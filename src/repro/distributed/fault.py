"""Fault tolerance & straggler mitigation for the training driver.

On a real 1000-node deployment, failures surface as (a) raised exceptions /
process death on the coordinator, (b) missing heartbeats from workers,
(c) stragglers (steps far above the running median). The primitives here are
deliberately host-level (pure Python around the jit'd step) so they apply to
any backend:

  * `run_with_recovery`: catch -> restore-from-latest-checkpoint -> resume,
    with bounded restarts and exponential backoff. A `FaultInjector` hook
    exists purely so tests can exercise the path deterministically.
  * `StepMonitor`: per-step wall-time tracking; flags stragglers at
    `factor ×` the trailing median. On TPU pods the remediation is
    re-dispatching the slice / excluding the host (the monitor exposes the
    decision; the actuator is deployment-specific).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Callable, Optional

log = logging.getLogger("repro.fault")


class FaultInjector:
    """Deterministic fault injection for tests: raise at listed steps."""

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected fault at step {step}")


@dataclasses.dataclass
class StepMonitor:
    window: int = 32
    straggler_factor: float = 3.0

    def __post_init__(self):
        self.times = deque(maxlen=self.window)
        self.stragglers = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            if seconds > self.straggler_factor * med:
                is_straggler = True
                self.stragglers.append((step, seconds, med))
                log.warning("straggler: step %d took %.3fs (median %.3fs) — "
                            "would re-dispatch slice on a real pod", step, seconds, med)
        self.times.append(seconds)
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        if not self.times:
            return None
        return sorted(self.times)[len(self.times) // 2]


def run_with_recovery(train_loop: Callable[[int], int], *,
                      restore_step: Callable[[], int],
                      max_restarts: int = 3, backoff_s: float = 0.1) -> int:
    """Drive `train_loop(start_step) -> final_step`, restarting from the last
    checkpoint on failure. Returns the final step reached."""
    restarts = 0
    start = restore_step()
    while True:
        try:
            return train_loop(start)
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any worker failure
            restarts += 1
            if restarts > max_restarts:
                log.error("exceeded %d restarts; giving up", max_restarts)
                raise
            wait = backoff_s * (2 ** (restarts - 1))
            log.warning("failure %r — restart %d/%d from checkpoint in %.2fs",
                        e, restarts, max_restarts, wait)
            time.sleep(wait)
            start = restore_step()
