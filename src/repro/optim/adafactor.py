"""Adafactor (factored second moments, optional momentum-free mode).

The optimizer-state footprint for a 671B-param model drops from 2×N fp32
(AdamW) to ~N/r + N/c (row/col factors) — the difference between fitting and
not fitting v5e HBM at 256 chips (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Adafactor:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-2
    decay: float = 0.8          # t^-decay second-moment decay schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 128

    def _factored(self, shape) -> bool:
        return (len(shape) >= 2 and shape[-1] >= self.min_dim_size_to_factor
                and shape[-2] >= self.min_dim_size_to_factor)

    def init(self, params):
        def one(p):
            if self._factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)
                                  or hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self._lr(step)
        beta2 = 1.0 - step.astype(jnp.float32) ** (-self.decay)

        def upd(p, g, v):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + self.eps
            if "vr" in v:
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                # standard adafactor preconditioner: V ≈ vr·vc / mean(vr)
                mean_vr = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                denom = (jnp.sqrt(vr / mean_vr)[..., None]
                         * jnp.sqrt(vc)[..., None, :] + self.eps)
                precond = g / denom
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * g2
                precond = g * jax.lax.rsqrt(vv + self.eps)
                new_v = {"v": vv}
            # update clipping (RMS of update <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms / self.clip_threshold)
            newp = p.astype(jnp.float32) - lr * (precond + self.weight_decay * p.astype(jnp.float32))
            return newp.astype(p.dtype), new_v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_params, {"v": new_v, "step": step}

    def state_logical_axes(self, params_axes, params_shapes):
        """Axes tree for the optimizer state; `params_shapes` (eval_shape tree)
        decides per-leaf whether the second moment is factored."""
        def one(ax, shp):
            if self._factored(shp.shape):
                return {"vr": tuple(ax[:-1]), "vc": tuple(ax[:-2]) + (ax[-1],)}
            return {"v": tuple(ax)}
        return {"v": jax.tree.map(one, params_axes, params_shapes,
                                  is_leaf=lambda x: isinstance(x, tuple)),
                "step": ()}
