from .adamw import AdamW
from .adafactor import Adafactor
from .schedule import cosine_warmup
from .compress import error_feedback_compress

__all__ = ["AdamW", "Adafactor", "cosine_warmup", "error_feedback_compress"]
