"""AdamW with fp32 master weights for low-precision params.

Self-contained (no optax). State is a pytree mirroring params, so the same
logical-axis sharding applies to optimizer state (ZeRO-style: when params are
FSDP-sharded over 'data', the moments shard identically for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    keep_master: bool = True   # fp32 master copy when params are bf16

    def init(self, params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        state = {"mu": zeros,
                 "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                 "step": jnp.zeros((), jnp.int32)}
        if self.keep_master:
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return state

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self._lr(step)
        grads = clip_by_global_norm(grads, self.grad_clip)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        base = state.get("master", params)

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            return (p.astype(jnp.float32)
                    - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                            + self.weight_decay * p.astype(jnp.float32)))

        new_master = jax.tree.map(upd, base, mu, nu)
        new_params = jax.tree.map(lambda nm, p: nm.astype(p.dtype), new_master, params)
        new_state = {"mu": mu, "nu": nu, "step": step}
        if self.keep_master:
            new_state["master"] = new_master
        return new_params, new_state

    def state_logical_axes(self, params_axes, params_shapes=None):
        del params_shapes
        ax = {"mu": params_axes, "nu": params_axes, "step": ()}
        if self.keep_master:
            ax["master"] = params_axes
        return ax


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    if not max_norm:
        return tree
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree)
