"""Gradient compression with error feedback (distributed-optimization trick).

int8 uniform quantization with a persistent error-feedback accumulator: the
quantization residual is carried to the next step, so the compressed update
is unbiased *over time* (Seide et al. / EF-SGD). On a real multi-pod
deployment this wraps the **cross-pod** all-reduce — intra-pod reduction
stays fp32 over fast ICI, only the slow pod-to-pod (DCN) hop moves int8
(4× fewer bytes; see launch/train.py for the hook). Numerics are validated
in tests/test_optim.py (compressed training tracks uncompressed).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def error_feedback_compress(grads, err_state):
    """Returns (decompressed grads, new error state).

    err_state is a pytree like grads (fp32). Pass None to initialize.
    """
    if err_state is None:
        err_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in outs]), tdef.unflatten([o[1] for o in outs])
