"""Optimized-HLO text analyzer: FLOPs, HBM-byte proxy, collective bytes.

Why not `compiled.cost_analysis()`? It counts a `while` body **once**
(verified empirically), and our models scan over layers — so every cost would
be off by ~num_layers×. This analyzer parses `compiled.as_text()`:

  * while ops carry `backend_config={"known_trip_count":{"n":"61"}}` — exact
    trip counts, which we propagate through the call graph (body/condition/
    calls/to_apply), so nested scans (layers × attention kv-chunks × ssm
    chunks) each get their own multiplier.
  * FLOPs: every `dot` instruction, 2·prod(out)·prod(lhs contracting dims),
    looked up in a per-computation symbol table (operand types are not
    printed inline for plain refs).
  * HBM bytes (proxy): Σ over *top-level* instructions of control
    computations (entry + while bodies) of operand+output buffer sizes.
    Fusion internals never touch HBM and are skipped; this matches the
    post-fusion buffer-traffic model TPU roofline math wants.
  * Collectives: operand bytes of all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute (+ async -start forms), with ring-model
    cost factors using the parsed replica-group size. SPMD shapes are
    per-device, so these are per-device bytes on the wire.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> float:
    """Bytes of one (possibly tuple) HLO type string."""
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    param_types: Dict[str, str]
    instructions: List[Instruction]


_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)(?:\.clone)?\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*(\(.*?\)|[^\s(]+)\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                is_entry, name, params = m.group(1), m.group(2), m.group(3)
                param_types = {}
                for pm in re.finditer(r"([\w\.\-_]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))", params):
                    param_types[pm.group(1)] = pm.group(2)
                cur = Computation(name, param_types, [])
                comps[name] = cur
                if is_entry:
                    entry_name = name
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, type_str, opcode, rest = im.groups()
        # operands = refs before the closing paren of the op call (heuristic:
        # refs in `rest` up to "), " suffix markers work because attribute
        # values reference computations with %, which we filter by kind later)
        call_part = rest.split("), ")[0] if "), " in rest else rest.rstrip(")")
        operands = _OPERAND_RE.findall(call_part)
        cur.instructions.append(Instruction(name, type_str.strip(), opcode, operands, line))
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _symbol_table(comp: Computation) -> Dict[str, str]:
    table = dict(comp.param_types)
    for ins in comp.instructions:
        table[ins.name] = ins.type_str
    return table


def _trip_count(raw: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', raw)
    return int(m.group(1)) if m else 1


def _called_computations(ins: Instruction) -> List[Tuple[str, str]]:
    """(kind, computation_name) refs in attributes."""
    out = []
    for attr in ("body", "condition", "calls", "to_apply", "branch_computations"):
        for m in re.finditer(attr + r"=\{?%?([\w\.\-_]+)", ins.raw):
            out.append((attr, m.group(1)))
        for m in re.finditer(attr + r"=\{([^}]*)\}", ins.raw):
            for name in _OPERAND_RE.findall(m.group(1)):
                out.append((attr, name))
    return out


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """computation name -> execution-count multiplier from the entry."""
    mult: Dict[str, float] = defaultdict(float)
    entry = comps.get("__entry__")
    if entry is None:
        return mult

    def visit(comp: Computation, m: float, seen_stack=()):
        if comp.name in seen_stack:
            return
        mult[comp.name] += m
        for ins in comp.instructions:
            trip = _trip_count(ins.raw) if ins.opcode == "while" else 1
            for kind, cname in _called_computations(ins):
                child = comps.get(cname)
                if child is None:
                    continue
                child_m = m * (trip if kind in ("body", "condition") else 1)
                visit(child, child_m, seen_stack + (comp.name,))

    visit(entry, 1.0)
    return dict(mult)


_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DOT_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(raw: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(raw)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(raw)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_proxy: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_link_bytes: float = 0.0   # ring-model bytes over the slowest link
    collective_ops: Dict[str, int] = dataclasses.field(default_factory=dict)
    dot_flops_by_meta: Dict[str, float] = dataclasses.field(default_factory=dict)
    while_trip_counts: List[int] = dataclasses.field(default_factory=list)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "iota"}


def analyze(text: str) -> HloStats:
    comps = parse_hlo(text)
    mult = _multipliers(comps)
    stats = HloStats()
    entry = comps.get("__entry__")
    control = {comps[k].name: v for k, v in mult.items() if k in comps}

    # control computations: entry + while bodies/conds (top-level buffers)
    control_names = set()
    if entry is not None:
        control_names.add(entry.name)
    for key, comp in comps.items():
        if key == "__entry__":
            continue
        for ins in comp.instructions:
            if ins.opcode == "while":
                for kind, cname in _called_computations(ins):
                    if kind in ("body", "condition"):
                        control_names.add(cname)

    for key, comp in comps.items():
        if key == "__entry__":
            continue
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        table = _symbol_table(comp)
        is_control = comp.name in control_names
        for ins in comp.instructions:
            # ---- FLOPs from dots (anywhere in the call graph)
            if ins.opcode == "dot":
                _, out_dims = _shape_dims(ins.type_str)
                cm = _DOT_DIMS_RE.search(ins.raw)
                contracting = [int(d) for d in cm.group(1).split(",")] if cm and cm.group(1) else []
                lhs_type = table.get(ins.operands[0], "") if ins.operands else ""
                _, lhs_dims = _shape_dims(lhs_type)
                k = 1
                for d in contracting:
                    if d < len(lhs_dims):
                        k *= lhs_dims[d]
                out_n = 1
                for d in out_dims:
                    out_n *= d
                flops = 2.0 * out_n * k
                stats.flops += m * flops
                meta = re.search(r'op_name="([^"]*)"', ins.raw)
                key = meta.group(1) if meta else ins.name
                stats.dot_flops_by_meta[key] = stats.dot_flops_by_meta.get(key, 0.0) + m * flops
            elif ins.opcode == "while":
                stats.while_trip_counts.append(_trip_count(ins.raw))

            # ---- collective bytes
            base_op = ins.opcode.replace("-start", "")
            if base_op in _COLLECTIVES:
                in_bytes = sum(_shape_bytes(table.get(op, "")) for op in ins.operands)
                out_bytes = _shape_bytes(ins.type_str)
                payload = max(in_bytes, out_bytes)
                g = _group_size(ins.raw)
                if base_op == "all-reduce":
                    link = 2.0 * (g - 1) / g * in_bytes
                elif base_op in ("all-gather", "reduce-scatter"):
                    link = (g - 1) / g * payload
                elif base_op in ("all-to-all", "ragged-all-to-all"):
                    link = (g - 1) / g * in_bytes
                else:  # collective-permute / broadcast
                    link = in_bytes
                stats.collective_bytes[base_op] = \
                    stats.collective_bytes.get(base_op, 0.0) + m * payload
                stats.collective_link_bytes += m * link
                stats.collective_ops[base_op] = \
                    stats.collective_ops.get(base_op, 0) + int(m)

            # ---- HBM byte proxy (top-level control computations only)
            if is_control and ins.opcode not in _SKIP_BYTES_OPS \
                    and ins.opcode != "while" \
                    and not ins.opcode.endswith("-done"):
                stats.bytes_proxy += m * _instruction_bytes(ins, table, comps)
    return stats


def _instruction_bytes(ins: Instruction, table: Dict[str, str],
                       comps: Dict[str, Computation]) -> float:
    """HBM traffic of one top-level instruction, slice-aware.

    dynamic-slice/gather read only their output-sized window of the operand;
    dynamic-update-slice writes only the update region (loop-aliased buffer);
    fusions bill each parameter at its *effective* size: if every use inside
    the fused computation is a (dynamic-)slice/gather, only the sliced window
    is read per invocation. This matters enormously for scan-over-layers:
    the stacked [L, ...] parameter is touched 1/L per iteration.
    """
    out_bytes = _shape_bytes(ins.type_str)
    if ins.opcode in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_bytes
    if ins.opcode == "dynamic-update-slice":
        upd = _shape_bytes(table.get(ins.operands[1], "")) if len(ins.operands) > 1 else out_bytes
        return 2.0 * upd
    if ins.opcode == "fusion":
        called = [c for k, c in _iter_called(ins, comps) if k == "calls"]
        body = called[0] if called else None
        if body is None:
            return out_bytes + sum(_shape_bytes(table.get(op, ""))
                                   for op in dict.fromkeys(ins.operands))
        # scan accumulators: a dynamic-update-slice whose target is a fusion
        # parameter writes only the update window; the big buffer is aliased
        # in place (this is exactly how XLA lowers scan ys / carries).
        names = list(body.param_types.keys())
        btable = _symbol_table(body)
        producers = {bi.name: bi for bi in body.instructions}

        def resolve(ref: str, depth: int = 8) -> str:
            """Chase bitcast/copy/reshape/transpose chains back to the source."""
            while depth > 0:
                prod = producers.get(ref)
                if prod is None or prod.opcode not in (
                        "bitcast", "copy", "reshape", "transpose", "convert"):
                    return ref
                if not prod.operands:
                    return ref
                ref = prod.operands[0]
                depth -= 1
            return ref

        aliased_params = set()
        dus_update_bytes = 0.0
        for bi in body.instructions:
            if bi.opcode == "dynamic-update-slice" and bi.operands:
                tgt = resolve(bi.operands[0])
                if tgt in names:
                    aliased_params.add(tgt)
                    if len(bi.operands) > 1:
                        dus_update_bytes += 2.0 * _shape_bytes(btable.get(bi.operands[1], ""))
        total = 0.0
        if aliased_params:
            total += dus_update_bytes  # output buffer counted via its window
        else:
            total += out_bytes
        for i, op in enumerate(dict.fromkeys(ins.operands)):
            if i < len(names) and names[i] in aliased_params:
                continue
            full = _shape_bytes(table.get(op, ""))
            total += _effective_param_bytes(body, i, full)
        return total
    in_bytes = sum(_shape_bytes(table.get(op, "")) for op in dict.fromkeys(ins.operands))
    return out_bytes + in_bytes


def _iter_called(ins: Instruction, comps: Dict[str, Computation]):
    for kind, cname in _called_computations(ins):
        comp = comps.get(cname)
        if comp is not None:
            yield kind, comp


_SLICING_OPS = {"dynamic-slice", "slice", "gather"}


def _effective_param_bytes(body: Computation, param_idx: int, full: float) -> float:
    """Bytes actually read from fusion parameter #param_idx per invocation."""
    # find the parameter's name: headers keep declaration order
    names = list(body.param_types.keys())
    if param_idx >= len(names):
        return full
    pname = names[param_idx]
    uses = [i for i in body.instructions if pname in i.operands]
    if not uses:
        return 0.0
    if all(u.opcode in _SLICING_OPS and u.operands and u.operands[0] == pname
           for u in uses):
        return sum(_shape_bytes(u.type_str) for u in uses)
    return full
