"""Live roofline accounting: HLO cost of a compiled fn vs. measured wall time.

First runtime consumer of ``analysis/``: :func:`record_roofline` takes a
compiled (``.lower().compile()``-ed) JAX callable plus a measured wall time
from a traced span or bench, analyzes its optimized HLO with
:func:`repro.analysis.hlo.analyze`, computes the roofline lower bound
``max(flops / PEAK_FLOPS_BF16, bytes_proxy / HBM_BW)``, and publishes the
achieved-vs-roofline fraction as ``roofline_fraction{op=...}`` gauges in the
metrics registry. Benches append the fraction to their emitted records, so
the nightly perf trajectory carries "how far from the hardware ceiling"
alongside raw latency.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.metrics import REGISTRY, MetricsRegistry

from .hlo import HloStats, analyze
from .roofline import HBM_BW, PEAK_FLOPS_BF16


def hlo_cost(compiled) -> HloStats:
    """HLO cost stats for a compiled JAX callable (``.as_text()`` parse)."""
    return analyze(compiled.as_text())


def roofline_bound_s(stats: HloStats) -> float:
    """Roofline lower-bound runtime (s): compute-bound vs. memory-bound."""
    return max(stats.flops / PEAK_FLOPS_BF16, stats.bytes_proxy / HBM_BW)


def record_roofline(name: str, compiled, wall_s: float,
                    registry: Optional[MetricsRegistry] = None) -> dict:
    """Gauge the achieved-vs-roofline fraction for one measured op.

    ``fraction = bound_s / wall_s`` — 1.0 means running at the roofline
    envelope, small values mean overhead-dominated. Emits
    ``roofline_fraction{op=name}`` and ``roofline_bound_s{op=name}`` gauges
    and returns ``{"flops", "bytes_proxy", "bound_s", "wall_s",
    "fraction"}``.
    """
    reg = REGISTRY if registry is None else registry
    stats = hlo_cost(compiled)
    bound = roofline_bound_s(stats)
    fraction = bound / wall_s if wall_s > 0 else 0.0
    reg.gauge("roofline_fraction", op=name).set(fraction)
    reg.gauge("roofline_bound_s", op=name).set(bound)
    return {"flops": stats.flops, "bytes_proxy": stats.bytes_proxy,
            "bound_s": bound, "wall_s": wall_s, "fraction": fraction}


__all__ = ["hlo_cost", "record_roofline", "roofline_bound_s"]
