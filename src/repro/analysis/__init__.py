from . import hlo, live, roofline

__all__ = ["hlo", "live", "roofline"]
