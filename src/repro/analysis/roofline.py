"""Roofline terms for TPU v5e from dry-run artifacts (EXPERIMENTS.md §Roofline).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = ring-model link bytes / link_bw

All quantities are *per device* (post-SPMD HLO shapes are per-device), so no
further division by chip count is needed. MODEL_FLOPS = 6·N·D (dense) or
6·N_active·D (MoE) over the **global** batch, divided by chips for the
per-device "useful" FLOPs; the ratio against HLO FLOPs exposes remat /
padding / masked-attention waste.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.models.config import ModelConfig, ShapeConfig
from .hlo import HloStats

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (~, one direction)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    link_bytes_per_device: float
    model_flops_global: float
    useful_ratio: float           # model_flops / (hlo_flops × chips)
    bottleneck: str
    per_device_memory_gb: Optional[float] = None
    peak_fraction: float = 0.0    # compute_s / max(all terms): roofline fraction

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·D for training; 2·N_active·tokens for inference steps."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def attention_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Quadratic attention term (excluded from 6ND; reported separately)."""
    if cfg.num_heads == 0:
        return 0.0
    n_attn_layers = sum(
        1 for i in range(cfg.num_layers)
        if (not cfg.layer_pattern) or cfg.layer_pattern[i % len(cfg.layer_pattern)] == "attn")
    if cfg.ssm and not cfg.layer_pattern:
        n_attn_layers = 0
    hd = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) if cfg.mla else cfg.head_dim
    s, b = shape.seq_len, shape.global_batch
    ctx = min(s, cfg.sliding_window) if cfg.sliding_window else s
    if shape.kind == "decode":
        # one query against the cached context (ring buffer for SWA)
        return n_attn_layers * b * cfg.num_heads * (2.0 * 2 * ctx * hd)
    mult = 3 if shape.kind == "train" else 1  # fwd+bwd
    if cfg.sliding_window and cfg.sliding_window < s:
        per_q = cfg.sliding_window
    else:
        per_q = 0.5 * s  # causal
    return mult * n_attn_layers * b * cfg.num_heads * (2.0 * 2 * per_q * s * hd)


def build(arch: str, shape_cfg: ShapeConfig, cfg: ModelConfig, mesh_name: str,
          chips: int, stats: HloStats,
          per_device_memory_bytes: Optional[float] = None) -> Roofline:
    compute_s = stats.flops / PEAK_FLOPS_BF16
    memory_s = stats.bytes_proxy / HBM_BW
    collective_s = stats.collective_link_bytes / ICI_LINK_BW
    mf = model_flops(cfg, shape_cfg) + attention_flops(cfg, shape_cfg)
    total_hlo = stats.flops * chips
    useful = mf / total_hlo if total_hlo else 0.0
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    dominant = max(terms.values()) or 1.0
    return Roofline(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops_per_device=stats.flops,
        hlo_bytes_per_device=stats.bytes_proxy,
        link_bytes_per_device=stats.collective_link_bytes,
        model_flops_global=mf,
        useful_ratio=useful,
        bottleneck=bottleneck,
        per_device_memory_gb=(per_device_memory_bytes / 2**30
                              if per_device_memory_bytes else None),
        peak_fraction=compute_s / dominant,
    )
