import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend init. 512 host devices let jax.make_mesh build the
# production meshes (16×16 single-pod, 2×16×16 multi-pod) for compile-only
# dry-runs — no real allocation happens (inputs are ShapeDtypeStructs).
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
  * proof of a coherent distribution config (`.lower().compile()` succeeds)
  * `memory_analysis()` — per-device bytes (does it fit 16 GB v5e HBM?)
  * `cost_analysis()` + parsed-HLO roofline terms (analysis.hlo/roofline)

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and are
summarized by benchmarks/roofline.py into EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_v3 --shape train_4k
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro import configs as CFG
from repro.analysis import hlo as hlo_an
from repro.analysis import roofline as rl
from repro.distributed import sharding as SH
from repro.distributed import step as STEP
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.models import SHAPES
from repro.optim import AdamW, Adafactor

# Giant models: factored second moments (AdamW state would not fit v5e HBM;
# see EXPERIMENTS.md §Dry-run).
ADAFACTOR_ARCHS = {"deepseek_v3", "jamba15_large"}

# Beyond-baseline optimization profiles (EXPERIMENTS.md §Perf):
#   * shard_map expert-parallel MoE (kills the GSPMD scatter-dispatch ARs)
#   * gemma: MQA head_dim TP is a pessimization (score-block psums) — the
#     8-head attention runs data-parallel only
OPT_PROFILES = {
    "deepseek_v3": ({"moe_impl": "ep"}, None),
    "phi35_moe": ({"moe_impl": "ep"}, None),
    "jamba15_large": ({"moe_impl": "ep"}, None),
    "gemma_2b": (None, {"head_dim_tp": None}),
}

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def make_optimizer(arch: str):
    if arch in ADAFACTOR_ARCHS:
        return Adafactor(learning_rate=1e-3)
    return AdamW(learning_rate=1e-3, keep_master=True)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rule_overrides: Optional[Dict] = None,
             save: bool = True, tag: str = "",
             cfg_overrides: Optional[Dict] = None) -> Dict:
    import dataclasses as _dc
    cfg = CFG.get(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    optimizer = make_optimizer(arch)
    t0 = time.perf_counter()

    with SH.use_rules(mesh, rule_overrides):
        if shape.kind == "decode":
            serve = STEP.make_decode_step(cfg)
            p_shard = STEP.train_state_shardings(cfg, optimizer, mesh,
                                                 rule_overrides)["params"]
            c_shard = STEP.cache_shardings(cfg, shape.global_batch,
                                           shape.seq_len, mesh, rule_overrides)
            in_specs = input_specs(cfg, shape)
            in_shard = jax.tree.map(
                lambda _: STEP.batch_shardings(cfg, shape, mesh, rule_overrides)["inputs"],
                in_specs)
            p_sds = STEP.param_shapes(cfg)
            c_sds = STEP.cache_shapes(cfg, shape.global_batch, shape.seq_len)
            logits_shard = STEP.logits_sharding(cfg, mesh, shape.global_batch, 1,
                                                overrides=rule_overrides)
            jitted = jax.jit(serve,
                             in_shardings=(p_shard, c_shard, in_shard["inputs"]),
                             out_shardings=(logits_shard, c_shard))
            lowered = jitted.lower(p_sds, c_sds, in_specs["inputs"])
        else:
            train = STEP.make_train_step(cfg, optimizer)
            s_shard = STEP.train_state_shardings(cfg, optimizer, mesh, rule_overrides)
            b_shard = STEP.batch_shardings(cfg, shape, mesh, rule_overrides)
            s_sds = STEP.train_state_shapes(cfg, optimizer)
            b_sds = input_specs(cfg, shape)
            b_shard = {k: b_shard[k] for k in b_sds}
            jitted = jax.jit(train, in_shardings=(s_shard, b_shard),
                             out_shardings=(s_shard, None))
            lowered = jitted.lower(s_sds, b_sds)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    stats = hlo_an.analyze(text)
    per_dev_bytes = None
    mem_dict = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            mem_dict[attr] = int(getattr(mem, attr))
    if mem_dict:
        per_dev_bytes = (mem_dict.get("argument_size_in_bytes", 0)
                         - mem_dict.get("alias_size_in_bytes", 0)
                         + mem_dict.get("output_size_in_bytes", 0)
                         + mem_dict.get("temp_size_in_bytes", 0))

    roof = rl.build(arch, shape, cfg, mesh_name, chips, stats, per_dev_bytes)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem_dict,
        "cost_analysis_flops": float(cost.get("flops", 0.0)),
        "cost_analysis_bytes": float(cost.get("bytes accessed", 0.0)),
        "hlo_stats": stats.to_json(),
        "roofline": roof.to_json(),
    }
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf optimization profiles")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    if args.opt and not args.tag:
        args.tag = "opt"

    archs = CFG.registry() if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        shapes = CFG.shapes_for(arch) if args.shape == "all" else [args.shape]
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                out = os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}"
                                   + (f"__{args.tag}" if args.tag else "") + ".json")
                if args.skip_existing and os.path.exists(out):
                    print(f"[skip] {arch} {shape_name} {mesh_name}")
                    continue
                label = f"{arch:16s} {shape_name:12s} {mesh_name}"
                cfg_over, rule_over = (OPT_PROFILES.get(arch, (None, None))
                                       if args.opt else (None, None))
                try:
                    t0 = time.perf_counter()
                    r = run_cell(arch, shape_name, multi, tag=args.tag,
                                 cfg_overrides=cfg_over,
                                 rule_overrides=rule_over)
                    roof = r["roofline"]
                    print(f"[ ok ] {label} compile={r['compile_s']:.0f}s "
                          f"mem/dev={roof['per_device_memory_gb']:.2f}GB "
                          f"terms(c/m/n)=({roof['compute_s']:.3f}/"
                          f"{roof['memory_s']:.3f}/{roof['collective_s']:.3f})s "
                          f"bottleneck={roof['bottleneck']} "
                          f"useful={roof['useful_ratio']:.2f} "
                          f"({time.perf_counter()-t0:.0f}s)")
                except Exception as e:  # noqa: BLE001
                    failures.append((label, repr(e)))
                    print(f"[FAIL] {label}: {e!r}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for lbl, err in failures:
            print(" ", lbl, err)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
