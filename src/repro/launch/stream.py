"""Streaming replay driver: timestamped edge deltas + interleaved queries.

Generates a Kronecker power-law graph, withholds a fraction of its edges as
a timestamped arrival stream, and replays them in delta batches against a
:class:`repro.stream.StreamSession` — interleaving each delta with a batched
query flush (similarity / membership / link prediction / triangle count /
local clustering)
through :class:`repro.stream.BatchedQueryServer`. Per batch it reports what
incremental maintenance saved (rows updated in place vs selectively rebuilt
vs the full-rebuild alternative), the host → device bytes the delta uploaded
(the device-resident path's contract: proportional to the delta, never a
full-graph snapshot) and the servers' latency/staleness stats;
``--verify`` additionally checks every answer against a from-scratch
``engine.session`` on the equivalent static graph (exact match under the
default strict policy).

  PYTHONPATH=src python -m repro.launch.stream --scale 10 --batches 12 --verify
  PYTHONPATH=src python -m repro.launch.stream --checkpoint-dir /tmp/ck --restore

The last line printed is a machine-readable JSON summary.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro import engine as ENG
from repro.core import graph as G
from repro.core import sketches as SK
from repro.obs import metrics, trace
from repro.stream import (BatchedQueryServer, DynamicGraph, ErrorBudgetPolicy,
                          StreamSession)


def build_stream(scale: int, edge_factor: int, stream_frac: float, seed: int):
    """Kronecker edges split into (initial graph, timestamped arrivals)."""
    g = G.kronecker(scale, edge_factor, seed=seed)
    rng = np.random.default_rng(seed + 1)
    edges = np.asarray(g.edges)
    order = rng.permutation(edges.shape[0])  # arrival order == timestamp
    split = int((1.0 - stream_frac) * edges.shape[0])
    return g.n, edges[order[:split]], edges[order[split:]]


def verify_against_static(st: StreamSession, pairs: np.ndarray,
                          lc_seed: int | None = None) -> dict:
    """From-scratch engine.session on the equivalent static graph."""
    gs = G.from_edge_array(st.dyn.n, st.dyn.edge_array())
    mt = st.maintainer
    sk = None
    if mt is not None:
        sk = SK.build(gs, mt.kind, words=mt.words or None, k=mt.k or None,
                      num_hashes=mt.num_hashes, seed=mt.seed)
    sess = ENG.session(gs, sk, plan=st.session.plan)
    tc_static = float(sess.triangle_count())
    tc_stream = float(st.triangle_count())
    sim_static = np.asarray(sess.similarity(pairs, "jaccard"))
    sim_stream = np.asarray(st.similarity(pairs, "jaccard"))
    exact = (tc_stream == tc_static
             and np.array_equal(sim_stream, sim_static))
    out = {
        "tc_abs_err": abs(tc_stream - tc_static),
        "sim_max_err": float(np.max(np.abs(sim_stream - sim_static)))
        if pairs.size else 0.0,
    }
    if lc_seed is not None:
        lc_static = sess.local_cluster(np.array([lc_seed], np.int32),
                                       alpha=0.15, eps=1e-3)
        lc_stream = st.local_cluster(np.array([lc_seed], np.int32),
                                     alpha=0.15, eps=1e-3)
        out["lc_phi_abs_err"] = abs(
            float(lc_static.best_conductance[0])
            - float(lc_stream.best_conductance[0]))
        exact = exact and np.array_equal(
            np.asarray(lc_static.conductance), np.asarray(lc_stream.conductance))
    out["exact_match"] = exact
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10, help="Kronecker scale")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--kind", default="bf",
                    choices=["bf", "kh", "1h", "kmv", "exact"])
    ap.add_argument("--budget", type=float, default=0.25)
    ap.add_argument("--batches", type=int, default=12)
    ap.add_argument("--stream-frac", type=float, default=0.3,
                    help="fraction of edges withheld as the arrival stream")
    ap.add_argument("--delete-frac", type=float, default=0.1,
                    help="deletions per batch as a fraction of its inserts")
    ap.add_argument("--queries", type=int, default=64,
                    help="similarity pairs per interleaved query batch")
    ap.add_argument("--tolerance", type=float, default=0.0,
                    help="error-budget rel_tolerance (0 = strict/bit-exact)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the serving-tier result cache")
    ap.add_argument("--async-serving", action="store_true",
                    help="run the server's background flush worker: deltas "
                         "overlap query service on snapshot-isolated views")
    ap.add_argument("--verify", action="store_true",
                    help="check answers against a from-scratch static session")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--restore", action="store_true",
                    help="resume from the latest checkpoint in --checkpoint-dir")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record spans and write a Chrome-trace/Perfetto "
                         "JSON of the replay to this path")
    ap.add_argument("--metrics", action="store_true",
                    help="embed metric-registry snapshots in the summary")
    args = ap.parse_args()

    if args.trace:
        trace.enable()
        trace.clear()
    n, initial, arrivals = build_stream(args.scale, args.edge_factor,
                                        args.stream_frac, args.seed)
    kind = None if args.kind == "exact" else args.kind
    # the stream is regenerated from these parameters on restore — any drift
    # would silently replay wrong/duplicate arrival chunks, so they are
    # stored with every checkpoint and validated here
    stream_cfg = {"scale": args.scale, "edge_factor": args.edge_factor,
                  "stream_frac": args.stream_frac, "batches": args.batches,
                  "seed": args.seed, "kind": args.kind}

    if args.restore:
        if not args.checkpoint_dir:
            raise SystemExit("--restore requires --checkpoint-dir")
        st = StreamSession.restore(args.checkpoint_dir)
        if st.extra and st.extra != stream_cfg:
            raise SystemExit(
                f"checkpoint stream config {st.extra} does not match the "
                f"requested flags {stream_cfg}; rerun with matching flags")
        print(f"restored: version={st.version} n={st.dyn.n} m={st.dyn.m}")
    else:
        st = StreamSession(
            DynamicGraph.from_edges(n, initial), kind=kind,
            storage_budget=args.budget,
            policy=ErrorBudgetPolicy(rel_tolerance=args.tolerance))
    # admission policy: the five per-batch queries below auto-flush on the
    # fifth submit (max_batch) — no hand-rolled flush loop; max_wait_s keeps
    # a straggler batch from waiting forever under other traffic shapes
    server = BatchedQueryServer(st, max_batch=5, max_wait_s=0.25,
                                cache=not args.no_cache,
                                async_flush=args.async_serving)
    chunks = np.array_split(arrivals, args.batches)
    print(f"stream: n={n} initial_m={st.dyn.m} arrivals={arrivals.shape[0]} "
          f"batches={args.batches} kind={args.kind}")

    _ = st.session.edge_cardinalities()  # warm the shared pass
    batch_rows = []
    for b in range(st.version, args.batches):
        # per-batch rng keyed on (seed, b): a restored run draws the same
        # deletions/queries the uninterrupted run would have at this batch
        rng = np.random.default_rng([args.seed + 2, b])
        ins = chunks[b]
        cur = st.dyn.edge_array()
        n_del = min(int(args.delete_frac * max(len(ins), 1)), cur.shape[0])
        dels = cur[rng.choice(cur.shape[0], size=n_del, replace=False)] \
            if n_del else None
        t0 = time.perf_counter()
        info = st.apply_delta(ins, dels)
        dt_delta = time.perf_counter() - t0

        qpairs = rng.integers(0, n, size=(args.queries, 2)).astype(np.int32)
        t0 = time.perf_counter()
        server.submit_similarity(qpairs, "jaccard")
        server.submit_membership(int(rng.integers(0, n)),
                                 rng.integers(0, n, size=16))
        server.submit_link_prediction(int(rng.integers(0, n)), top_k=4)
        lc_seed = int(rng.integers(0, n))
        lc_rid = server.submit_local_cluster(lc_seed, alpha=0.15, eps=1e-3)
        tc_rid = server.submit_triangle_count()  # 5th submit -> auto-flush
        answers = server.flush()                 # already answered; drains
        dt_query = time.perf_counter() - t0

        lc = answers[lc_rid].value
        row = {"batch": b, "m": st.dyn.m, "delta_s": round(dt_delta, 4),
               "query_s": round(dt_query, 4),
               "tc": answers[tc_rid].value,
               "localcluster": {"size": lc["size"],
                                "conductance": lc["conductance"]},
               **info}
        if args.verify:
            row["verify"] = verify_against_static(st, qpairs, lc_seed)
        batch_rows.append(row)
        print(f"[{b:03d}] m={row['m']} +{info['inserted']} -{info['deleted']} "
              f"tc={row['tc']:.1f} recomputed={info['cards_recomputed']}"
              f"/carried={info['cards_carried']} "
              f"rebuilt={info['rows_rebuilt_now']} "
              f"lc(|C|={lc['size']},phi={lc['conductance']:.3f}) "
              f"upload={info['bytes_uploaded'] / 1024:.1f}KiB "
              f"delta={dt_delta*1e3:.1f}ms query={dt_query*1e3:.1f}ms"
              + (f" exact={row['verify']['exact_match']}" if args.verify
                 else ""))
        if args.checkpoint_dir and (b + 1) % args.checkpoint_every == 0:
            path = st.save(args.checkpoint_dir, extra=stream_cfg)
            print(f"      checkpoint -> {path}")

    server_stats = server.stats()   # before close(), which drops the cache
    server.close()                  # flush-then-detach
    summary = {"event": "stream_replay", "n": n, "final_m": st.dyn.m,
               "batches": len(batch_rows), "stream": st.stats(),
               "server": server_stats,
               # null (not a vacuous true) when no batch was verified
               "verify_all_exact": all(r["verify"]["exact_match"]
                                       for r in batch_rows)
               if args.verify and batch_rows else None}
    if args.metrics:
        summary["metrics"] = {"global": metrics.REGISTRY.snapshot(),
                              "stream": st.metrics.snapshot(),
                              "server": server.metrics.snapshot()}
    if args.trace:
        trace.export(args.trace)
        trace.disable()
        summary["trace"] = args.trace
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
